"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs for the
production mesh (DESIGN.md §5).

Strategy ``tp`` (default): megatron-style tensor parallel over ``model``
(q-heads / ffn-hidden / vocab / experts), FSDP over ``data`` on the
complementary matrix dim, batch over (``pod``, ``data``).

Strategy ``dp_only`` (hillclimb option for small archs): replicate params,
shard batch over every mesh axis — avoids padding waste when heads % 16
!= 0 at the price of replicated optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.moe import MoEMeshArgs


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Any
    dp_axes: Tuple[str, ...]
    fsdp_axis: Optional[str]
    model_axis: Optional[str]
    strategy: str = "tp"
    moe_weight_mode: str = "gather"   # gather | stationary (see moe.py)

    def moe_args(self) -> Optional[MoEMeshArgs]:
        if self.mesh is None:
            return None
        if self.strategy == "dp_only" or self.model_axis is None:
            return None
        return MoEMeshArgs(self.mesh, self.dp_axes, self.fsdp_axis,
                           self.model_axis,
                           weight_mode=self.moe_weight_mode)

    # -- helpers -----------------------------------------------------------
    def ns(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def batch_spec(self) -> P:
        if self.strategy == "dp_only":
            axes = tuple(self.dp_axes) + ((self.model_axis,)
                                          if self.model_axis else ())
            return P(axes)
        return P(tuple(self.dp_axes))


def make_plan(mesh, *, multi_pod: bool = False, strategy: str = "tp",
              moe_weight_mode: str = "gather") -> ShardingPlan:
    if mesh is None:
        return ShardingPlan(None, (), None, None, strategy)
    names = mesh.axis_names
    if strategy == "fsdp":
        # ZeRO-3: batch over EVERY axis, parameters fully sharded over
        # ("data", "model") (one divisible dim each; GSPMD all-gathers
        # just-in-time), no tensor parallelism.  The win over "tp" for
        # archs whose head counts don't divide the model axis (e.g.
        # qwen2's 12 heads vs 16): no replicated attention compute and a
        # 16x smaller per-device activation footprint (§Perf cell A).
        dp = tuple(a for a in ("pod", "data", "model") if a in names)
        return ShardingPlan(mesh, dp, None, None, strategy)
    dp = tuple(a for a in ("pod", "data") if a in names)
    model = "model" if "model" in names else None
    fsdp = "data" if "data" in names and mesh.shape.get("data", 1) > 1 \
        else None
    return ShardingPlan(mesh, dp or names[:1], fsdp, model, strategy,
                        moe_weight_mode)


# --------------------------------------------------------------------------
# Parameter specs, by tree-path name matching
# --------------------------------------------------------------------------
def _param_spec(path: str, ndim: int, plan: ShardingPlan,
                divisible: Dict[str, bool]) -> P:
    if plan.strategy == "dp_only":
        return P()
    f = plan.fsdp_axis
    m = plan.model_axis
    leaf = path.split("/")[-1]
    stacked = path.startswith("layers/")
    pre: Tuple = (None,) if stacked else ()

    def spec(*s):
        full = pre + s
        assert len(full) == ndim, (path, ndim, full)
        return P(*full)

    if path == "embed":
        return P(m, f)
    if path == "unembed":
        return P(f, m)
    if leaf in ("final_norm", "ln1", "ln2", "out_norm", "b", "b_if", "beta",
                "dt_bias", "A_log", "D", "q_norm", "k_norm"):
        return P(*([None] * ndim))
    if leaf in ("wq", "wk", "wv") and ndim == 4:       # (P, d|i, H, Dh)
        return spec(f, m, None)
    if leaf == "wo":                                   # (P, H, Dh, d)
        return spec(m, None, f)
    if leaf in ("bq", "bk", "bv"):                     # (P, H, Dh)
        return spec(m, None)
    if leaf in ("w1", "w3"):
        if ndim == 4:                                  # moe (P, E, d, f)
            if plan.moe_weight_mode == "stationary":
                return spec(m, None, f)                # f-dim sharded
            return spec(m, f, None)
        return spec(f, m)                              # dense (P, d, f)
    if leaf == "w2":
        if ndim == 4:                                  # moe (P, E, f, d)
            if plan.moe_weight_mode == "stationary":
                return spec(m, f, None)
            return spec(m, None, f)
        return spec(m, f)                              # dense (P, f, d)
    if leaf == "router":                               # (P, d, E)
        return spec(None, None)
    if leaf in ("up_proj", "in_proj", "wx", "up1", "up2"):  # (P, d, inner)
        return spec(f, m)
    if leaf in ("down_proj", "out_proj", "down"):      # (P, inner, d)
        return spec(m, f)
    if leaf == "r":                                    # (P, nh, dh, 4dh)
        return spec(m, None, None)
    if leaf == "conv":                                 # (P, w, inner)
        return spec(None, m)
    if leaf in ("wBC", "wdt"):                         # (P, inner, k)
        return spec(m, None)
    if leaf == "wif":                                  # (P, inner, nh, 2)
        return spec(f, m, None)
    return P(*([None] * ndim))


def _tree_path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _fsdp_spec(path: str, shape, plan: ShardingPlan) -> P:
    """ZeRO-3 rule: shard the largest divisible dim over ("data","model")
    combined; fall back to a single axis; else replicate.  The stacked
    period dim of layer params (dim 0) is never sharded."""
    sizes = dict(plan.mesh.shape)
    combined = tuple(a for a in ("data", "model") if a in sizes)
    n_comb = int(np.prod([sizes[a] for a in combined]))
    stacked = path.startswith("layers/")
    dims = list(enumerate(shape))
    if stacked:
        dims = dims[1:]
    dims.sort(key=lambda kv: -kv[1])
    for axes, n in ((combined, n_comb),) + tuple(
            ((a,), sizes[a]) for a in combined):
        for i, d in dims:
            if n > 1 and d % n == 0:
                spec = [None] * len(shape)
                spec[i] = axes if len(axes) > 1 else axes[0]
                return P(*spec)
    return P(*([None] * len(shape)))


def param_shardings(params_shape, cfg: ModelConfig, plan: ShardingPlan):
    """Map a params (or ShapeDtypeStruct) tree to NamedShardings."""
    if plan.mesh is None:
        return jax.tree.map(lambda _: None, params_shape)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    if plan.strategy == "fsdp":
        return jax.tree_util.tree_unflatten(treedef, [
            NamedSharding(plan.mesh,
                          _fsdp_spec(_tree_path_str(p), leaf.shape, plan))
            for p, leaf in flat])
    out = []
    for path, leaf in flat:
        spec = _param_spec(_tree_path_str(path), len(leaf.shape), plan, {})
        # explicit input shardings must divide exactly (no GSPMD padding on
        # declared in_shardings) — non-divisible dims fall back to
        # replication and are reported in the roofline notes
        sizes = dict(plan.mesh.shape)
        fixed = []
        for dim, ax in zip(leaf.shape, spec + (None,) * len(leaf.shape)):
            if ax is None:
                fixed.append(None)
                continue
            n = np.prod([sizes[a] for a in (ax if isinstance(ax, tuple)
                                            else (ax,))])
            fixed.append(ax if dim % n == 0 else None)
        out.append(NamedSharding(plan.mesh, P(*fixed)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shape, plan: ShardingPlan):
    if plan.mesh is None:
        return jax.tree.map(lambda _: None, batch_shape)
    bs = plan.batch_spec()
    sizes = dict(plan.mesh.shape)
    n_dp = int(np.prod([sizes[a] for a in (bs[0] if isinstance(bs[0], tuple)
                                           else (bs[0],))])) if bs else 1

    def spec(leaf):
        if len(leaf.shape) == 0 or leaf.shape[0] % n_dp != 0:
            return NamedSharding(plan.mesh, P())   # tiny batch: replicate
        extra = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(plan.mesh, P(*(tuple(bs) + extra)))
    return jax.tree.map(spec, batch_shape)


def cache_shardings(cache_shape, cfg: ModelConfig, plan: ShardingPlan,
                    kv_seq_axis: Optional[str] = None):
    """Cache tree: (period, B, ...) leaves — batch over dp.

    ``kv_seq_axis``: optionally shard the KV-cache sequence dim over this
    axis (flash-decode style; a §Perf hillclimb lever).
    """
    if plan.mesh is None:
        return jax.tree.map(lambda _: None, cache_shape)
    bs = plan.batch_spec()
    # the batch-dim axes as ONE PartitionSpec entry (a flat tuple of axis
    # names; re-wrapping it with tuple(bs) nests tuples and is rejected)
    dp = bs[0] if len(bs) else None
    m = plan.model_axis if plan.strategy != "dp_only" else None
    sizes = dict(plan.mesh.shape)

    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
        n_dp *= sizes.get(a, 1)
    ms = sizes.get(m, 1) if m else 1

    def spec(path, leaf):
        name = _tree_path_str(path).split("/")[-1]
        nd = len(leaf.shape)
        if name in ("k", "v") and nd == 5:     # (Pd, B, S, Hkv, Dh)
            hkv, smax = leaf.shape[3], leaf.shape[2]
            if kv_seq_axis and smax % sizes.get(kv_seq_axis, 1) == 0:
                s = P(None, dp, kv_seq_axis, None, None)
            elif m and hkv % ms == 0:
                s = P(None, dp, None, m, None)
            elif m and smax % ms == 0:
                # flash-decode style: shard cache sequence over model
                s = P(None, dp, m, None, None)
            else:
                s = P(None, dp, None, None, None)
        elif name == "ssm" and nd == 5:        # (Pd, B, nh, hd, st)
            s = P(None, dp, m, None, None)
        elif name == "conv" and nd == 4:       # (Pd, B, w, inner)
            s = P(None, dp, None, m)
        elif name == "H" and nd == 5:          # (Pd, B, nh, dqk, dv+1)
            s = P(None, dp, m, None, None)
        elif nd >= 2:
            s = P(None, dp)
        else:
            s = P(None)
        # divisibility guards: explicit in_shardings must divide exactly
        dims = list(s)
        for i, ax in enumerate(dims):
            if ax is None:
                continue
            if isinstance(ax, tuple):
                if leaf.shape[i] % n_dp != 0:
                    dims[i] = None
            elif leaf.shape[i] % sizes.get(ax, 1) != 0:
                dims[i] = None
        return NamedSharding(plan.mesh, P(*dims))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def opt_shardings(opt_shape, params_sharding, *,
                  zero1_axis: Optional[str] = None):
    """AdamState(step, mu, nu): mu/nu mirror params, step replicated.

    ``zero1_axis``: opt-in ZeRO-1 — mu/nu additionally shard their largest
    still-unsharded divisible dim over that axis (for llama4-400B the fp32
    optimizer state alone is 12.5 GB/device on one pod).  NOTE: with plain
    GSPMD annotations the update gathers state instead of scattering
    grads (measured: +240 s collective on llama4 multi-pod — EXPERIMENTS
    §Perf); a production ZeRO-1 needs the explicit
    reduce-scatter/update/all-gather structure in shard_map, which is why
    this stays opt-in."""
    from repro.optim.adamw import AdamState
    mesh = None
    for s in jax.tree.leaves(params_sharding):
        mesh = s.mesh
        break
    step_s = NamedSharding(mesh, P()) if mesh is not None else None
    mom = params_sharding
    if mesh is not None and zero1_axis in mesh.axis_names \
            and mesh.shape[zero1_axis] > 1:
        n_z = mesh.shape[zero1_axis]

        def zshard(shape_leaf, sharding):
            spec = list(sharding.spec) + [None] * (
                len(shape_leaf.shape) - len(sharding.spec))
            # largest unsharded dim divisible by the pod size
            cands = sorted(
                ((d, i) for i, (d, ax) in
                 enumerate(zip(shape_leaf.shape, spec))
                 if ax is None and d % n_z == 0),
                reverse=True)
            if cands:
                spec[cands[0][1]] = zero1_axis
            return NamedSharding(mesh, P(*spec))

        # opt_shape is AdamState(step, mu, nu); mu mirrors params' tree
        mom = jax.tree.map(zshard, opt_shape.mu, params_sharding)
    return AdamState(step=step_s, mu=mom, nu=mom)
