"""``shard_map`` across jax versions.

``jax.shard_map`` (with ``check_vma``) is the >=0.5 top-level API; on
older jax it lives in ``jax.experimental.shard_map`` and the flag is
named ``check_rep``.  Call sites use this wrapper so the model/pipeline
code reads like the current API everywhere.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
