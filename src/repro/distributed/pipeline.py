"""GPipe-style pipeline parallelism over a ``stage`` mesh axis
(DESIGN.md §5), written with shard_map + collective_permute.

The production dry-run meshes use DP x TP (+pod) because every assigned
shape fits without PP; this module provides the PP building block for
deeper-than-HBM models and is unit-tested on small meshes
(tests/test_pipeline.py).

Schedule: classic GPipe.  M microbatches flow through S stages; step t
(0 <= t < M + S - 1) runs stage s on microbatch t - s.  Activations move
stage s -> s+1 through one ``collective_permute`` per step (forward-shift
by one along the stage axis).  Each device holds only its stage's layer
stack; bubbles are the usual (S-1)/(M+S-1) fraction.

The layer function is arbitrary (it may itself be TP-sharded on an inner
mesh axis) — the pipeline composes with the rest of the sharding plan.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(layer_fn: Callable, params_stacked, x_microbatches, *,
                   mesh, stage_axis: str = "stage"):
    """Run a GPipe forward pass.

    layer_fn(stage_params, x) -> x        (applied once per stage)
    params_stacked: pytree with leading dim = n_stages (stage-sharded).
    x_microbatches: (M, mb, ...) microbatched input, replicated over the
        stage axis.
    Returns (M, mb, ...) outputs (replicated over the stage axis).
    """
    n_stages = mesh.shape[stage_axis]

    def stage_prog(params, xs):
        # params: this stage's slice (leading dim 1); xs: all microbatches
        sp = jax.tree.map(lambda p: p[0], params)
        sid = jax.lax.axis_index(stage_axis)
        M = xs.shape[0]
        T = M + n_stages - 1
        buf = jnp.zeros_like(xs[0])               # current activation
        outs = jnp.zeros_like(xs)
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def step(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            buf = jnp.where(sid == 0,
                            jnp.where(t < M, mb_in, jnp.zeros_like(buf)),
                            buf)
            # every stage processes what it holds
            y = layer_fn(sp, buf)
            # last stage emits microbatch t - (S-1) (if in range)
            emit_idx = t - (n_stages - 1)
            do_emit = (sid == n_stages - 1) & (emit_idx >= 0)
            outs = jax.lax.cond(
                do_emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(emit_idx, 0), 0),
                lambda o: o, outs)
            # shift activations forward one stage
            buf = jax.lax.ppermute(y, stage_axis, fwd)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(T))
        # replicate results to all stages (only the last stage holds them;
        # masked psum acts as a broadcast)
        outs = jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, stage_axis)
        return outs

    from repro.distributed.shardmap_compat import shard_map
    return shard_map(
        stage_prog, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
    )(params_stacked, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1) / (M+S-1)."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
