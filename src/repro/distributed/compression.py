"""Gradient compression with error feedback (distributed-optimization
feature, DESIGN.md §5).

int8 block-quantization: each gradient leaf is quantized per 256-element
block to int8 with an f32 scale (~4x wire reduction vs bf16, ~8x vs f32 on
the cross-pod hop).  ``ef_compress_tree`` applies quantize->dequantize so
the optimizer sees exactly the values the wire would deliver; the
quantization residual is *re-injected* into the next step's gradient via an
error-feedback accumulator when used through ``EFState`` (convergence-safe
per Karimireddy et al.; validated in tests/test_compression.py).

``compressed_psum`` is the shard_map building block that performs the
reduction in the compressed domain over a mesh axis (used by the multi-pod
train-step variant).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat, n


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) -> (int8 blocks (nb, BLOCK), f32 scales (nb,))."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-30)[:, None])
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def ef_compress(x: jax.Array, err: jax.Array = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Quantize-dequantize with error feedback.

    Returns (compressed value, new error residual)."""
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    q, s = quantize(xf)
    out = dequantize(q, s, x.shape, jnp.float32)
    new_err = xf - out
    return out.astype(x.dtype), new_err


def ef_compress_tree(grads: Any) -> Any:
    """Stateless quantize-dequantize over a gradient pytree (the wire
    fidelity model; for stateful error feedback carry the second output
    of ef_compress in the optimizer state)."""
    def one(g):
        if g.size < BLOCK:      # tiny leaves travel uncompressed
            return g
        out, _ = ef_compress(g)
        return out
    return jax.tree.map(one, grads)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """psum in the compressed domain: quantize locally, sum int32 partial
    blocks over the axis, dequantize.  Used inside shard_map for the
    cross-pod gradient hop."""
    q, s = quantize(x)
    # sum of per-shard dequantized blocks == dequantize of int32 sums only
    # when scales match, so reduce (q * s) contributions in two psums of
    # narrow payloads: int8 payload q and f32 scale s.
    qs = jax.lax.psum(q.astype(jnp.int32) * s[:, None], axis_name)
    flat = qs.reshape(-1)
    n = 1
    for d in x.shape:
        n *= d
    return flat[:n].reshape(x.shape).astype(x.dtype)


def wire_bytes(x: jax.Array) -> int:
    """Bytes on the wire for the compressed representation."""
    nb = (x.size + BLOCK - 1) // BLOCK
    return nb * BLOCK + nb * 4
