"""Deterministic sharded synthetic-token data pipeline with host prefetch.

Design points that matter at 1000+ nodes:

- **Statelessness**: the batch for step ``s`` on host ``h`` is a pure
  function of (seed, s, h) — restart/elastic re-mesh needs no pipeline
  state in the checkpoint beyond the step counter.
- **Host sharding**: each host materializes only its slice of the global
  batch; the global batch is recovered by the (pod, data) sharding.
- **Prefetch**: a background thread keeps a bounded queue of ready batches
  (overlap host data work with device compute).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class SyntheticLM:
    """Zipf-ish token stream; labels = next token; frontend embeds for
    vlm/audio stubs."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0):
        assert shape.global_batch % n_hosts == 0 or shape.global_batch < n_hosts
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.local_batch = max(1, shape.global_batch // n_hosts)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host)."""
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S = self.local_batch, shape.seq_len
        out: Dict[str, np.ndarray] = {}
        # zipf-like marginal over the vocab
        if cfg.frontend == "audio":
            out["embeds"] = rng.standard_normal(
                (B, S, cfg.d_model), np.float32).astype(np.float32)
            labels = rng.integers(0, cfg.vocab, (B, S), np.int32)
            out["labels"] = labels
        elif cfg.frontend == "vlm" and cfg.frontend_tokens:
            F = min(cfg.frontend_tokens, S // 2)
            out["embeds"] = rng.standard_normal(
                (B, F, cfg.d_model), np.float32).astype(np.float32)
            out["tokens"] = self._tokens(rng, B, S - F)
            labels = np.concatenate(
                [np.full((B, F), -100, np.int32),
                 rng.integers(0, cfg.vocab, (B, S - F), np.int32)], axis=1)
            out["labels"] = labels
        else:
            toks = self._tokens(rng, B, S + 1)
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:].astype(np.int32)
        return out

    def _tokens(self, rng, B, S) -> np.ndarray:
        z = rng.zipf(1.3, (B, S)).astype(np.int64)
        return ((z - 1) % self.cfg.vocab).astype(np.int32)


class Prefetcher:
    """Bounded background prefetch queue over ``batch_at``."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-prefetch")
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=1.0)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration
                continue

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
