"""AdamW + cosine schedule + global-norm clipping, with an optional
error-feedback int8 gradient-compression hook for the cross-pod reduction
(see repro.distributed.compression).

Kept dependency-free (no optax in the offline container); the interface is
the usual (init, update) pair over pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def schedule(cfg: OptConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: OptConfig, grads, state: AdamState, params):
    """Returns (new_params, new_state, metrics)."""
    with jax.named_scope("clip_by_global_norm"):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    with jax.named_scope("adamw_update"):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm,
                                                  "lr": lr}
