from repro.optim.adamw import (  # noqa: F401
    OptConfig, AdamState, init, update, schedule, global_norm)
