"""Merged trace database — the ``trace.db`` analogue (paper §4.4, §6.1;
"Preparing for Performance Analysis at Exascale" motivates the format).

``hpcprof`` merges N per-rank/per-stream trace files into *one* seekable
database so post-mortem tools never re-open thousands of small files and
never re-sort events.  We do the same:

- one header (JSON, canonical encoding) with an **identity index**: every
  trace line's identity dict plus its (element offset, event count) into
  the data region;
- one int64 data region holding, per line, the three columns
  ``starts | ends | ctx`` contiguously, with starts **sorted at merge
  time** (the writer's out-of-order flag is consumed exactly once, here,
  instead of by every reader — §4.4);
- the data region is 64-byte aligned and read back with ``np.memmap``, so
  opening a multi-GB database touches only the header and each view is a
  zero-copy slice.

Merging is idempotent: rebuilding a database from an existing ``trace.db``
produces byte-identical output (canonical line order + canonical JSON),
which tests/test_traceview.py locks in.

Layout::

    MAGIC "RTDB" | u32 version | u64 header_len | header JSON | pad to 64
    int64 data[]   (per line: count starts, count ends, count ctx)
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import struct
from typing import Iterable, List, Sequence, Tuple, Union

import numpy as np

from repro.core.trace import (DISPATCH_CTX_MASK, TraceData, read_trace,
                              sorted_by_start)

MAGIC = b"RTDB"
VERSION = 1
_ALIGN = 64
_HDR = struct.Struct("<4sIQ")    # magic, version, header json length


def _line_key(identity: dict) -> tuple:
    """Canonical line order: host, rank, CPU threads before GPU streams,
    then thread/stream index (hpctraceviewer's process.thread ordering)."""
    return (str(identity.get("host", "")),
            int(identity.get("rank", 0)),
            0 if identity.get("type", "cpu") == "cpu" else 1,
            int(identity.get("thread", identity.get("stream", 0)) or 0),
            json.dumps(identity, sort_keys=True))


Source = Union[str, TraceData]


def _decode_dispatch(td: TraceData) -> TraceData:
    """A raw GPU-stream trace from ``Profiler.write()`` encodes the
    dispatching thread index in the high ctx bits (repro.core.trace).
    Aggregation consumes that encoding (pipeline.traceconv); a trace.db
    built straight from a measurement directory wants plain local node
    ids, so strip it here — the pre-encoding behavior."""
    if not td.identity.get("dispatch_profiles"):
        return td
    identity = {k: v for k, v in td.identity.items()
                if k != "dispatch_profiles"}
    ctx = np.asarray(td.ctx, np.int64) & DISPATCH_CTX_MASK
    return TraceData(identity, td.starts, td.ends, ctx)


def _load_sources(sources: Union[Source, Sequence[Source]]
                  ) -> List[TraceData]:
    """Expand sources into trace lines.  A source is a measurement
    directory (all ``*.rtrc`` inside), a single ``.rtrc`` file, an
    existing ``trace.db`` (whose lines re-merge unchanged), or an
    in-memory ``TraceData`` line (the database merge hands remapped
    lines straight in — repro.core.merge)."""
    if isinstance(sources, (str, TraceData)):
        sources = [sources]
    lines: List[TraceData] = []
    for src in sources:
        if isinstance(src, TraceData):
            # materialized by the caller when the arrays view a file this
            # build may overwrite (sorted_by_start copies only if unsorted)
            lines.append(_decode_dispatch(src))
        elif os.path.isdir(src):
            for p in sorted(glob.glob(os.path.join(src, "*.rtrc"))):
                lines.append(_decode_dispatch(read_trace(p)))
        elif src.endswith(".rtrc"):
            lines.append(_decode_dispatch(read_trace(src)))
        else:
            # materialize: line_views are zero-copy views into the mapped
            # file, which build_db may be about to overwrite in place
            with TraceDB(src) as db:
                lines.extend(TraceData(td.identity, np.array(td.starts),
                                       np.array(td.ends), np.array(td.ctx))
                             for td in db.line_views())
    return lines


def build_db(sources: Union[Source, Sequence[Source]],
             out_path: str) -> "TraceDB":
    """Merge per-identity trace files into one seekable ``trace.db``."""
    lines = [sorted_by_start(td) for td in _load_sources(sources)]
    lines.sort(key=lambda td: _line_key(td.identity))
    index = []
    offset = 0
    for td in lines:
        n = len(td.starts)
        index.append({"identity": td.identity, "offset": offset, "count": n})
        offset += 3 * n
    t_min = min((int(td.starts[0]) for td in lines if len(td.starts)),
                default=0)
    t_max = max((int(td.ends.max()) for td in lines if len(td.ends)),
                default=0)
    header = json.dumps(
        {"version": VERSION, "n_events": offset // 3,
         "t_min": t_min, "t_max": t_max, "lines": index},
        sort_keys=True, separators=(",", ":")).encode()
    tmp_path = out_path + ".tmp"
    with open(tmp_path, "wb") as f:
        f.write(_HDR.pack(MAGIC, VERSION, len(header)))
        f.write(header)
        pos = _HDR.size + len(header)
        f.write(b"\0" * (-pos % _ALIGN))
        for td in lines:
            f.write(td.starts.astype("<i8").tobytes())
            f.write(td.ends.astype("<i8").tobytes())
            f.write(td.ctx.astype("<i8").tobytes())
    os.replace(tmp_path, out_path)   # atomic; safe for in-place re-merge
    return TraceDB(out_path)


@dataclasses.dataclass
class TraceLine:
    identity: dict
    offset: int       # element offset into the data region
    count: int


class TraceDB:
    """Memory-mapped reader.  ``starts/ends/ctx(i)`` are zero-copy slices
    of the mapped data region; ``view(i)`` wraps them as the same
    ``TraceData`` the pre-merge tools (blame, viewer) consume.

    Context manager: ``close()`` releases the mapping, so tools that
    scan many databases (the fleet daemon, pyramid builds) don't
    accumulate open file mappings; re-merging a database in place is
    safe once its readers are closed.  Accessors raise ``ValueError``
    after close."""

    def __init__(self, path: str):
        self.path = path
        with open(path, "rb") as f:
            magic, version, hdr_len = _HDR.unpack(f.read(_HDR.size))
            if magic != MAGIC:
                raise ValueError(f"{path}: not a trace.db (bad magic)")
            if version != VERSION:
                raise ValueError(f"{path}: unsupported version {version}")
            hdr = json.loads(f.read(hdr_len))
        data_offset = (_HDR.size + hdr_len + _ALIGN - 1) // _ALIGN * _ALIGN
        self.t_min: int = hdr["t_min"]
        self.t_max: int = hdr["t_max"]
        self.n_events: int = hdr["n_events"]
        self.lines: List[TraceLine] = [
            TraceLine(ln["identity"], ln["offset"], ln["count"])
            for ln in hdr["lines"]]
        self._data = np.memmap(path, np.int64, mode="r", offset=data_offset,
                               shape=(3 * self.n_events,)) \
            if self.n_events else np.zeros(0, np.int64)

    def close(self) -> None:
        data, self._data = self._data, None
        if isinstance(data, np.memmap):
            data._mmap.close()

    def __enter__(self) -> "TraceDB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __len__(self) -> int:
        return len(self.lines)

    def _slice(self, lo: int, hi: int) -> np.ndarray:
        if self._data is None:
            raise ValueError(f"{self.path}: trace.db reader is closed")
        return self._data[lo:hi]

    def raw(self) -> np.ndarray:
        """The whole mapped int64 data region — every line's
        ``starts|ends|ctx`` blocks concatenated, addressed via
        ``lines[i].offset``.  The pyramid's batched occupancy gathers
        candidate events of many (line, edge) pairs in one fancy index
        instead of a per-line slice loop."""
        if self._data is None:
            raise ValueError(f"{self.path}: trace.db reader is closed")
        return self._data

    def starts(self, i: int) -> np.ndarray:
        ln = self.lines[i]
        return self._slice(ln.offset, ln.offset + ln.count)

    def ends(self, i: int) -> np.ndarray:
        ln = self.lines[i]
        return self._slice(ln.offset + ln.count, ln.offset + 2 * ln.count)

    def ctx(self, i: int) -> np.ndarray:
        ln = self.lines[i]
        return self._slice(ln.offset + 2 * ln.count,
                           ln.offset + 3 * ln.count)

    def view(self, i: int) -> TraceData:
        return TraceData(self.lines[i].identity, self.starts(i),
                         self.ends(i), self.ctx(i))

    def line_views(self) -> List[TraceData]:
        return [self.view(i) for i in range(len(self.lines))]

    def time_range(self) -> Tuple[int, int]:
        return self.t_min, self.t_max
