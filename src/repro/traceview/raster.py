"""hpctraceviewer-style rendering by *sampling* (paper §7).

The trace view never draws every event: for a W-pixel-wide window it
samples each trace line at W pixel-midpoint times and paints the calling
context active at that instant, projected to a chosen call-stack depth.
That makes rendering cost O(W log E) per line regardless of event count.

Everything here is vectorized: one ``np.searchsorted`` per line resolves
all W samples against the sorted event starts (the merge-time sort in
tracedb.py is what makes this legal), and the depth projection is a table
built once per raster with the same O(max_depth) parent-jump sweep the
aggregator uses — no per-event or per-sample Python loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cct import tree_depths
from repro.core.trace import TraceData

__all__ = ["IDLE", "Raster", "ancestors_at_depth", "line_label",
           "rasterize", "sample_line", "tree_depths"]

IDLE = -1    # pixel value for "no event under this sample"


def sample_line(starts: np.ndarray, ends: np.ndarray, ctx: np.ndarray,
                samples: np.ndarray, *, emax: Optional[np.ndarray] = None,
                nested: Optional[bool] = None) -> np.ndarray:
    """Context id covering each sample midpoint (``IDLE`` where none) —
    the per-line sampling core shared by the per-event raster and the
    pyramid's exact mode.  ``emax`` (running max of ends) and ``nested``
    (whether any event overlaps an earlier one) are recomputed here when
    absent; the pyramid passes its stored copies so an exact re-render
    costs O(W log E) instead of O(E)."""
    starts = np.asarray(starts, np.int64)
    out = np.full(len(samples), IDLE, np.int64)
    if not len(starts):
        return out
    ends = np.asarray(ends, np.int64)
    cur = np.searchsorted(starts, samples, side="right") - 1
    if emax is None:
        emax = np.maximum.accumulate(ends)
    if nested is None:
        nested = len(starts) > 1 and bool((starts[1:] < emax[:-1]).any())
    if nested:
        # nested/overlapping events: when the latest-starting event has
        # ended, walk back to the latest-starting one still covering
        # the sample (the enclosing scope).  emax bounds the walk: no
        # cover exists once samples >= max end of all earlier events.
        while True:
            safe = np.maximum(cur, 0)
            need = (cur >= 0) & (samples >= ends[safe]) \
                & (samples < emax[safe])
            if not need.any():
                break
            cur[need] -= 1
    safe = np.maximum(cur, 0)
    covered = (cur >= 0) & (samples < ends[safe])
    gids = np.asarray(ctx, np.int64)[safe]
    out[covered] = gids[covered]
    return out


def ancestors_at_depth(parents: np.ndarray, depths: np.ndarray,
                       depth: int) -> np.ndarray:
    """gid -> its ancestor at the requested depth.  Nodes at or above the
    requested depth map to themselves — the same projection
    ``viewer.trace_statistic`` applies (chain[-depth], else the node)."""
    parents = np.asarray(parents, np.int64)
    cur = np.arange(len(parents), dtype=np.int64)
    while True:
        mask = depths[cur] > depth
        if not mask.any():
            break
        cur[mask] = parents[cur[mask]]
    return cur


def line_label(identity: dict) -> str:
    kind = identity.get("type", "cpu")
    idx = identity.get("thread" if kind == "cpu" else "stream", 0)
    return f"r{identity.get('rank', 0)}.{'t' if kind == 'cpu' else 's'}{idx}"


@dataclasses.dataclass
class Raster:
    pixels: np.ndarray          # (n_lines, width) int64 gid; IDLE = no event
    times: np.ndarray           # (width,) sample midpoints (ns)
    labels: List[str]           # per rendered line
    line_ids: np.ndarray        # rendered line -> source line index
    t0: int
    t1: int
    depth: int


def _pick_rows(n_lines: int, height: int) -> np.ndarray:
    """Row sampling under a pixel budget: the viewer draws at most
    ``height`` lines, evenly spaced over the identity-ordered lines."""
    if n_lines <= height:
        return np.arange(n_lines)
    return np.unique(np.linspace(0, n_lines - 1, height).round()
                     .astype(np.int64))


def rasterize(lines: Sequence[TraceData], parents: np.ndarray, *,
              t0: Optional[int] = None, t1: Optional[int] = None,
              width: int = 120, height: int = 32, depth: int = 2,
              depths: Optional[np.ndarray] = None) -> Raster:
    """Sample ``lines`` into a (height, width) grid of global ctx ids at
    the given call-stack depth.

    ``lines`` must be start-sorted per line (TraceDB views are); within a
    line, overlapping events resolve to the latest-starting one covering
    the sample, matching a per-thread timeline where nesting is reported
    by the innermost frame (enclosing events show through the gaps after
    a nested event ends).
    """
    parents = np.asarray(parents, np.int64)
    if t0 is None:
        # min, not starts[0]: pre-merge TraceData lines may be unsorted
        t0 = min((int(np.min(td.starts)) for td in lines if len(td.starts)),
                 default=0)
    if t1 is None:
        t1 = max((int(td.ends.max()) for td in lines if len(td.ends)),
                 default=t0 + 1)
    if t1 <= t0:
        t1 = t0 + 1
    if depths is None:
        depths = tree_depths(parents)
    anc = ancestors_at_depth(parents, depths, depth)
    rows = _pick_rows(len(lines), height)
    samples = t0 + (np.arange(width, dtype=np.float64) + 0.5) \
        * (t1 - t0) / width
    pixels = np.full((len(rows), width), IDLE, np.int64)
    for out_row, li in enumerate(rows):
        td = lines[li]
        if not len(td.starts):
            continue
        gids = sample_line(td.starts, td.ends, td.ctx, samples)
        valid = (gids >= 0) & (gids < len(parents))
        pixels[out_row, valid] = anc[gids[valid]]
    return Raster(pixels, samples, [line_label(lines[i].identity)
                                    for i in rows],
                  rows, int(t0), int(t1), depth)
