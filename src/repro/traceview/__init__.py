"""Time-centric trace analysis (paper §4.4, §7): merged ``trace.db``,
hpctraceviewer-style depth×time rendering, and interval statistics across
ranks and streams.

Typical post-mortem flow::

    db = aggregate(profiles, out, trace_paths=traces)   # writes trace.db
    tdb = TraceDB(os.path.join(out, "trace.db"))
    print(render_view(tdb.line_views(), db, width=120, height=16, depth=2))
"""
from repro.traceview.filter import TraceFilter, apply_filter, subtree_mask
from repro.traceview.pyramid import (TracePyramid, build_pyramid,
                                     ensure_pyramid, pyramid_path_for)
from repro.traceview.raster import (IDLE, Raster, ancestors_at_depth,
                                    rasterize, sample_line, tree_depths)
from repro.traceview.render import (depth_selector, render, render_view,
                                    statistic_panel)
from repro.traceview.stats import (blame_over_time, interval_profile,
                                   merge_intervals, occupancy, summary,
                                   top_kernel_counters, top_kernels,
                                   windowed_blame)
from repro.traceview.tracedb import TraceDB, build_db

__all__ = [
    "TraceDB", "build_db",
    "TracePyramid", "build_pyramid", "ensure_pyramid", "pyramid_path_for",
    "Raster", "rasterize", "sample_line", "ancestors_at_depth",
    "tree_depths", "IDLE",
    "render", "render_view", "depth_selector", "statistic_panel",
    "summary", "interval_profile", "occupancy", "top_kernels",
    "top_kernel_counters",
    "blame_over_time", "windowed_blame", "merge_intervals",
    "TraceFilter", "apply_filter", "subtree_mask",
]
