"""Interval statistics over trace windows (paper §7; THAPI-style timeline
summarization).

Three views, all over an arbitrary ``[t0, t1)`` window:

- **Summary** (`summary`, `interval_profile`): the trace view's Summary
  tab — a time-weighted profile of the window.  Each event contributes
  its overlap with the window to its context, projected to a call-stack
  depth.  Over the full time range this reproduces
  ``viewer.trace_statistic`` exactly (event durations are integer ns, so
  float64 accumulation is order-independent) while staying vectorized.
- **Idleness / blame over time** (`blame_over_time`): per rank, the
  fraction of GPU streams idle in each of N bins, plus all-streams-idle
  time split equally across the CPU contexts active during it — the
  binned generalization of ``core.blame.blame_gpu_idleness``; per-context
  totals summed over bins equal the unbinned sweep's output.
- **Top-k kernels** (`top_kernels`): largest GPU contexts by busy time in
  the window.

Per-line occupancy (`occupancy`) exposes the busy-time-per-bin primitive:
for every line, busy + idle sums to the window length (the property test
in tests/test_traceview.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blame import blame_gpu_idleness, idle_segments
from repro.core.trace import TraceData, sorted_by_start
from repro.traceview.raster import ancestors_at_depth, tree_depths


# --------------------------------------------------------------------------
# coverage primitives
# --------------------------------------------------------------------------
def merge_intervals(starts: np.ndarray, ends: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Union of (possibly overlapping) intervals, as disjoint sorted
    intervals — fully vectorized (sort + running max + group reduce)."""
    starts = np.asarray(starts, np.int64)
    ends = np.asarray(ends, np.int64)
    if not len(starts):
        return starts, ends
    order = np.argsort(starts, kind="stable")
    s, e = starts[order], ends[order]
    emax = np.maximum.accumulate(e)
    new_group = np.ones(len(s), bool)
    new_group[1:] = s[1:] > emax[:-1]
    m_start = s[new_group]
    m_end = np.maximum.reduceat(e, np.flatnonzero(new_group))
    return m_start, m_end


def coverage_at(m_start: np.ndarray, m_end: np.ndarray,
                t: np.ndarray) -> np.ndarray:
    """C(t) = total covered time in [-inf, t) for disjoint sorted
    intervals, evaluated at many ``t`` at once."""
    if not len(m_start):
        return np.zeros(len(np.atleast_1d(t)), np.int64)
    dur = m_end - m_start
    cum = np.concatenate([[0], np.cumsum(dur)])
    idx = np.searchsorted(m_start, t, side="right")
    safe = np.maximum(idx - 1, 0)
    partial = np.clip(t - m_start[safe], 0, dur[safe]) * (idx > 0)
    return cum[safe] * (idx > 0) + partial


def occupancy(lines: Sequence[TraceData], t0: int, t1: int,
              nbins: int, *, pyramid=None,
              line_ids: Optional[Sequence[int]] = None) -> np.ndarray:
    """(n_lines, nbins) busy ns per bin.  Busy time is the *union* of the
    line's events, so for any line busy + idle == t1 - t0 exactly.

    With ``pyramid`` (a ``pyramid.TracePyramid``), bin sums come from the
    precomputed busy-ns tiles — bitwise-equal (docs/traceview.md) but
    O(tiles) instead of O(events); ``line_ids`` selects pyramid lines
    (all when None) and ``lines`` is ignored."""
    if pyramid is not None:
        return pyramid.occupancy(t0, t1, nbins, lines=line_ids)
    edges = int(t0) + (int(t1) - int(t0)) \
        * np.arange(nbins + 1, dtype=np.int64) // nbins
    out = np.zeros((len(lines), nbins), np.float64)
    for i, td in enumerate(lines):
        m_s, m_e = merge_intervals(np.clip(td.starts, t0, t1),
                                   np.clip(td.ends, t0, t1))
        out[i] = np.diff(coverage_at(m_s, m_e, edges))
    return out


# --------------------------------------------------------------------------
# Summary view
# --------------------------------------------------------------------------
def interval_profile(lines: Sequence[TraceData], n_ctx: int,
                     t0: int, t1: int) -> np.ndarray:
    """(n_ctx,) time-weighted ns per context over the window — each
    event's overlap with [t0, t1) scatter-added onto its context.

    Lines are expected start-sorted (TraceDB views are); unsorted lines
    are sorted here so pre-merge TraceData gives the same answer.  Both
    window edges prune: events are sliced to [lo, hi) where ``hi`` bounds
    starts < t1 and ``lo`` drops the prefix whose running-max end <= t0,
    so a narrow window touches few events."""
    out = np.zeros(n_ctx, np.float64)
    for td in lines:
        td = sorted_by_start(td)
        starts = td.starts
        if not len(starts):
            continue
        hi = int(np.searchsorted(starts, t1, side="left"))
        lo = int(np.searchsorted(
            np.maximum.accumulate(td.ends[:hi]), t0, side="right"))
        ends = td.ends[lo:hi]
        overlap = np.minimum(ends, t1) - np.maximum(starts[lo:hi], t0)
        sel = overlap > 0
        ctx = td.ctx[lo:hi][sel]
        # out-of-range ctx attributes to root, like viewer.trace_statistic
        # (and aggregate's phase-5 handling of the same condition)
        ctx = np.where((ctx >= 0) & (ctx < n_ctx), ctx, 0)
        np.add.at(out, ctx, overlap[sel].astype(np.float64))
    return out


def summary(lines: Sequence[TraceData], db, *, t0: Optional[int] = None,
            t1: Optional[int] = None, depth: int = 2, top: int = 10,
            depths: Optional[np.ndarray] = None, pyramid=None,
            flt=None) -> List[Tuple[str, float]]:
    """The Summary tab: fraction of window trace-area per routine at the
    given depth.  With the full window this matches
    ``viewer.trace_statistic`` on the same lines.

    With ``pyramid`` (a ``pyramid.TracePyramid``), the profile comes from
    the context tiles — bitwise-equal to the per-event path on the same
    window (docs/traceview.md) — and ``lines`` is ignored (pass None).
    ``flt`` (a ``filter.TraceFilter``) composes at the tile level: line
    predicates prune whole lines, the subtree mask prunes tile entries,
    and the default window is the selected lines' extent intersected
    with the filter window."""
    parents = np.asarray(db.parents, np.int64)
    if pyramid is not None:
        line_ids, ctx_mask, ft0, ft1 = pyramid.select(flt, parents)
        d0, d1 = pyramid.line_range(line_ids)
        t0 = d0 if t0 is None else t0
        t1 = d1 if t1 is None else t1
        if ft0 is not None:
            t0 = max(t0, ft0)
        if ft1 is not None:
            t1 = min(t1, ft1)
        prof = pyramid.interval_profile(len(db.frames), t0, t1,
                                        lines=line_ids, ctx_mask=ctx_mask)
    else:
        if t0 is None:
            # min, not starts[0]: pre-merge lines may be unsorted
            t0 = min((int(np.min(td.starts)) for td in lines
                      if len(td.starts)), default=0)
        if t1 is None:
            t1 = max((int(td.ends.max()) for td in lines if len(td.ends)),
                     default=t0)
        prof = interval_profile(lines, len(db.frames), t0, t1)
    if depths is None:   # aggregate.Database caches its depth array
        depths = db.depths() if hasattr(db, "depths") else \
            tree_depths(parents)
    anc = ancestors_at_depth(parents, depths, depth)
    by_anc = np.zeros(len(prof))
    np.add.at(by_anc, anc, prof)
    # distinct contexts can project to the same routine (one function,
    # many call paths): group by name, like trace_statistic
    area: Dict[str, float] = {}
    for g in np.flatnonzero(by_anc):
        name = db.frames[g].pretty()
        area[name] = area.get(name, 0.0) + by_anc[g]
    total = sum(area.values())
    rows = sorted(area.items(), key=lambda kv: -kv[1])[:top]
    return [(n, v / total if total else 0.0) for n, v in rows]


def top_kernels(lines: Sequence[TraceData], db, *, t0: int, t1: int,
                k: int = 5) -> List[Tuple[str, float]]:
    """Top-k GPU contexts by busy ns inside the window (GPU lines only)."""
    gpu = [td for td in lines if td.identity.get("type") == "gpu"]
    prof = interval_profile(gpu, len(db.frames), t0, t1)
    order = np.argsort(-prof, kind="stable")[:k]
    return [(db.frames[g].pretty(), float(prof[g]))
            for g in order if prof[g] > 0]


def top_kernel_counters(lines: Sequence[TraceData], db, *, t0: int, t1: int,
                        k: int = 5, stat: str = "sum"
                        ) -> List[Tuple[str, float, Dict[str, float]]]:
    """Top-k kernels by windowed busy time, joined with the database's
    hardware-counter derived columns (paper §6; repro.counters): each row
    is ``(name, busy_ns, {occupancy, flop_eff, bytes_per_flop,
    replay_passes})``.  Counter stats are whole-run aggregates (counters
    are kernel-granularity, not time-binned), while busy_ns respects the
    window — the same join the hpcviewer trace view's kernel table shows.
    Requires a ``Database`` with the ``gpu_counter`` kind; rows without
    counter data carry zeros (the derived zero-division policy)."""
    from repro.core.derived import (ACHIEVED_OCCUPANCY, BYTES_PER_FLOP,
                                    FLOP_EFFICIENCY, REPLAY_PASS_COUNT,
                                    database_columns)
    gpu = [td for td in lines if td.identity.get("type") == "gpu"]
    prof = interval_profile(gpu, len(db.frames), t0, t1)
    order = np.argsort(-prof, kind="stable")[:k]
    cols = database_columns(db, stat)
    if "gpu_counter/elapsed_ns" not in cols:
        return [(db.frames[g].pretty(), float(prof[g]), {})
                for g in order if prof[g] > 0]
    derived = {"occupancy": ACHIEVED_OCCUPANCY.evaluate(cols),
               "flop_eff": FLOP_EFFICIENCY.evaluate(cols),
               "bytes_per_flop": BYTES_PER_FLOP.evaluate(cols),
               "replay_passes": REPLAY_PASS_COUNT.evaluate(cols)}
    return [(db.frames[g].pretty(), float(prof[g]),
             {name: float(vals[g]) for name, vals in derived.items()})
            for g in order if prof[g] > 0]


def top_hot_loops(lines: Sequence[TraceData], db, *, t0: Optional[int] = None,
                  t1: Optional[int] = None, k: int = 10, stat: str = "sum"
                  ) -> List[Tuple[str, str, str, str, float, float]]:
    """Kernel-interior hot spots joined with windowed trace time
    (repro.core.kstruct; the traceview face of ``viewer.top_hot_loops``):
    rows ``(kernel, loop, file:line, op, samples, est_busy_ns)``.

    Sample stats are whole-run aggregates (PC samples are not
    time-binned); ``est_busy_ns`` prorates the enclosing GPU placeholder
    context's busy ns inside [t0, t1) over its interior leaves by sample
    share — the same whole-run-stats x windowed-busy join as
    ``top_kernel_counters``."""
    from repro.core.cct import GPU_FUNC, GPU_LOOP, GPU_OP, PLACEHOLDER
    try:
        cols = db.stats[stat]
        samp = cols[:, db.metric_id("gpu_inst/samples")]
    except (KeyError, ValueError):
        return []
    gpu = [td for td in lines if td.identity.get("type") == "gpu"]
    if t0 is None:
        # min, not starts[0]: pre-merge lines may be unsorted
        t0 = min((int(np.min(td.starts)) for td in gpu if len(td.starts)),
                 default=0)
    if t1 is None:
        t1 = max((int(td.ends.max()) for td in gpu if len(td.ends)),
                 default=t0)
    prof = interval_profile(gpu, len(db.frames), t0, t1)
    parents = np.asarray(db.parents, np.int64)
    kids: Dict[int, List[int]] = {}
    for gid, par in enumerate(parents):
        if par >= 0:
            kids.setdefault(int(par), []).append(gid)

    def subtree_sum(vals: np.ndarray, g: int) -> float:
        total, stack = 0.0, [g]
        while stack:
            i = stack.pop()
            total += float(vals[i])
            stack.extend(kids.get(i, []))
        return total

    roots = [g for g, f in enumerate(db.frames)
             if f.kind == GPU_FUNC and parents[g] >= 0
             and db.frames[int(parents[g])].kind == GPU_OP]
    rows: Dict[tuple, float] = {}
    busy_of: Dict[tuple, float] = {}
    for r in roots:
        kernel = db.frames[r].name
        p = int(parents[r])
        while p >= 0 and db.frames[p].kind != PLACEHOLDER:
            p = int(parents[p])
        busy = subtree_sum(prof, p) if p >= 0 else 0.0
        ktotal = samp[r] or 1.0
        stack = [(c, "-") for c in kids.get(r, [])]
        while stack:
            g, loop = stack.pop()
            f = db.frames[g]
            if f.kind == GPU_LOOP:
                loop = f.name
            if f.kind == GPU_OP:
                key = (kernel, loop, f"{f.module}:{f.line}", f.name)
                rows[key] = rows.get(key, 0.0) + float(samp[g])
                busy_of[key] = busy_of.get(key, 0.0) \
                    + busy * float(samp[g]) / float(ktotal)
            stack.extend((c, loop) for c in kids.get(g, []))
    out = [(kk[0], kk[1], kk[2], kk[3], v, busy_of[kk])
           for kk, v in rows.items()]
    out.sort(key=lambda row: (-row[4], row[:4]))
    return out[:k]


# --------------------------------------------------------------------------
# Idleness / blame over time
# --------------------------------------------------------------------------
def _clip_line(td: TraceData, t0: int, t1: int) -> TraceData:
    starts = np.asarray(td.starts, np.int64)
    ends = np.asarray(td.ends, np.int64)
    sel = (starts < t1) & (ends > t0)
    return TraceData(td.identity, np.clip(starts[sel], t0, t1),
                     np.clip(ends[sel], t0, t1),
                     np.asarray(td.ctx, np.int64)[sel])


def split_by_rank(lines: Sequence[TraceData]
                  ) -> Dict[int, List[TraceData]]:
    by_rank: Dict[int, List[TraceData]] = {}
    for td in lines:
        by_rank.setdefault(int(td.identity.get("rank", 0)), []).append(td)
    return by_rank


def blame_over_time(lines: Sequence[TraceData], t0: int, t1: int,
                    nbins: int, *, pyramid=None) -> Dict[int, dict]:
    """Per rank: ``streams_idle_frac`` (nbins,) — 1 - mean busy fraction
    of the rank's GPU streams per bin; ``idle_ns`` (nbins,) — all-streams
    -idle time per bin; ``blame`` {cpu ctx: (nbins,) ns} — idle time split
    equally across CPU contexts active during it, prorated onto the bins
    each idle segment spans.  Summing ``blame`` over bins reproduces
    ``core.blame.blame_gpu_idleness`` on the same (clipped) lines.
    Ranks with no GPU lines are omitted (no streams to be idle).

    With ``pyramid``, the per-stream busy sums come from the busy-ns
    tiles (bitwise-equal); the idle-segment blame split still walks the
    window's clipped events — it needs the set of CPU contexts active
    during each segment, which no additive tile carries.
    """
    edges = t0 + (t1 - t0) * np.arange(nbins + 1, dtype=np.int64) // nbins
    out: Dict[int, dict] = {}
    for rank, rlines in sorted(split_by_rank(lines).items()):
        cpu = [_clip_line(td, t0, t1) for td in rlines
               if td.identity.get("type", "cpu") == "cpu"]
        gpu = [_clip_line(td, t0, t1) for td in rlines
               if td.identity.get("type") == "gpu"]
        if not gpu:
            # no streams -> "fraction of streams idle" is undefined, and
            # blaming the rank's whole CPU runtime would be wrong
            continue
        ids = [pyramid.line_index(td.identity) for td in gpu] \
            if pyramid is not None else None
        busy = occupancy(gpu, t0, t1, nbins, pyramid=pyramid,
                         line_ids=ids)
        widths = np.diff(edges).astype(np.float64)
        frac = 1.0 - busy.sum(0) / np.maximum(widths * max(len(gpu), 1), 1)
        idle_ns = np.zeros(nbins)
        blame: Dict[int, np.ndarray] = {}
        for seg_t0, seg_t1, active in idle_segments(cpu, gpu):
            lo = int(np.searchsorted(edges, seg_t0, side="right")) - 1
            hi = int(np.searchsorted(edges, seg_t1, side="left"))
            for b in range(max(lo, 0), min(hi, nbins)):
                part = min(seg_t1, int(edges[b + 1])) \
                    - max(seg_t0, int(edges[b]))
                if part <= 0:
                    continue
                idle_ns[b] += part
                share = part / len(active)
                for c in active:
                    blame.setdefault(
                        c, np.zeros(nbins))[b] += share
        out[rank] = {"streams_idle_frac": frac, "idle_ns": idle_ns,
                     "blame": blame}
    return out


def windowed_blame(lines: Sequence[TraceData], t0: int, t1: int
                   ) -> Tuple[Dict[int, float], float]:
    """Exact §7.2 blame restricted to a window: clip every line to
    [t0, t1) and delegate to ``core.blame.blame_gpu_idleness``."""
    cpu = [_clip_line(td, t0, t1) for td in lines
           if td.identity.get("type", "cpu") == "cpu"]
    gpu = [_clip_line(td, t0, t1) for td in lines
           if td.identity.get("type") == "gpu"]
    return blame_gpu_idleness(cpu, gpu)


# --------------------------------------------------------------------------
# Per-request attribution (repro.serving measurement windows)
# --------------------------------------------------------------------------
def window_labels(db) -> Tuple[List[Optional[str]], List[Optional[str]]]:
    """Per-context ``(request_id, phase)``: each context inherits the
    nearest enclosing serving-window frames (the ``request:<id>`` /
    ``phase:<p>`` scheme of repro.serving.window).  Contexts outside any
    window carry ``(None, None)``."""
    from repro.serving.window import window_label
    parents = np.asarray(db.parents, np.int64)
    n = len(db.frames)
    req: List[Optional[str]] = [None] * n
    ph: List[Optional[str]] = [None] * n
    done = np.zeros(n, bool)
    for start in range(n):
        if done[start]:
            continue
        chain = []
        i = start
        while i >= 0 and not done[i]:
            chain.append(i)
            i = int(parents[i])
        r, p = (req[i], ph[i]) if i >= 0 else (None, None)
        for j in reversed(chain):
            fr, fp = window_label(db.frames[j])
            if fr is not None:
                r, p = fr, None     # a new request window resets the phase
            if fp is not None:
                p = fp
            req[j], ph[j] = r, p
            done[j] = True
    return req, ph


def request_attribution(lines: Sequence[TraceData], db, *,
                        t0: Optional[int] = None, t1: Optional[int] = None,
                        gpu_only: bool = True
                        ) -> List[Tuple[str, float, Dict[str, float]]]:
    """Which request burned the GPU: time-weighted busy ns per request id
    over the window, split by phase — rows ``(request_id, total_ns,
    {phase: ns})`` sorted by total descending.  ``gpu_only`` restricts to
    GPU stream lines (the question the serving operator asks); pass
    False to attribute host lines too."""
    sel = [td for td in lines
           if not gpu_only or td.identity.get("type") == "gpu"]
    if t0 is None:
        # min, not starts[0]: pre-merge lines may be unsorted
        t0 = min((int(np.min(td.starts)) for td in sel if len(td.starts)),
                 default=0)
    if t1 is None:
        t1 = max((int(td.ends.max()) for td in sel if len(td.ends)),
                 default=t0)
    prof = interval_profile(sel, len(db.frames), t0, t1)
    req, ph = window_labels(db)
    rows: Dict[str, Dict[str, float]] = {}
    for g in np.flatnonzero(prof):
        r = req[g]
        if r is None:
            continue
        by = rows.setdefault(r, {})
        p = ph[g] or "other"
        by[p] = by.get(p, 0.0) + float(prof[g])
    out = [(r, sum(by.values()), by) for r, by in rows.items()]
    out.sort(key=lambda row: (-row[1], row[0]))
    return out


def request_spans(lines: Sequence[TraceData], db
                  ) -> Dict[Tuple[str, str], Tuple[int, int]]:
    """Per ``(request_id, phase)``: the ``[min start, max end)`` envelope
    of every trace event attributed to it — the trace-derived request
    latency (GPU time the request actually occupied, across streams)."""
    req, ph = window_labels(db)
    spans: Dict[Tuple[str, str], Tuple[int, int]] = {}
    for td in lines:
        ctx = np.asarray(td.ctx, np.int64)
        if not len(ctx):
            continue
        starts = np.asarray(td.starts, np.int64)
        ends = np.asarray(td.ends, np.int64)
        valid = (ctx >= 0) & (ctx < len(req))
        ctx_v = ctx[valid]
        if not len(ctx_v):
            continue
        # one group-reduce per line (argsort + reduceat) instead of the
        # old per-unique-ctx re-scan, which was O(unique x events)
        order = np.argsort(ctx_v, kind="stable")
        cs = ctx_v[order]
        grp = np.flatnonzero(np.concatenate(([True], cs[1:] != cs[:-1])))
        gmin = np.minimum.reduceat(starts[valid][order], grp)
        gmax = np.maximum.reduceat(ends[valid][order], grp)
        for g, s0, e1 in zip(cs[grp], gmin, gmax):
            r = req[int(g)]
            if r is None:
                continue
            key = (r, ph[int(g)] or "other")
            cur = spans.get(key)
            s0, e1 = int(s0), int(e1)
            spans[key] = ((min(cur[0], s0), max(cur[1], e1)) if cur
                          else (s0, e1))
    return spans


def request_latency_percentiles(lines: Sequence[TraceData], db, *,
                                qs: Sequence[float] = (50.0, 99.0)
                                ) -> Dict[str, Dict[float, float]]:
    """Per phase: latency percentiles in ms over per-request trace spans
    — the post-hoc cross-check of the live ``ServingStats`` percentiles
    (those are wall-clock windows; these are trace envelopes)."""
    by_phase: Dict[str, List[int]] = {}
    for (_, p), (s, e) in request_spans(lines, db).items():
        by_phase.setdefault(p, []).append(e - s)
    return {p: {float(q): float(np.percentile(
                np.asarray(d, np.int64), q)) / 1e6 for q in qs}
            for p, d in sorted(by_phase.items())}
