"""Multi-resolution trace tile pyramid — O(tile) zoom/pan over a merged
``trace.db`` (ISSUE 9 tentpole; "Preparing for Performance Analysis at
Exascale" and the exascale-diagnostics framework paper make interactivity
at extreme event counts the design goal).

Every traceview query used to re-scan the merged event arrays per render
— O(events) work repeated on every zoom/pan, untenable at billion-event
databases.  The pyramid precomputes, per trace line, depth x time mip
levels over power-of-two time bins and stores them in one mmap-backed
``trace.pyr`` file next to the ``trace.db`` it summarizes:

- **context-profile tiles** (per level, per bin): sparse
  ``(ctx, busy-ns)`` pairs — each event's overlap clipped at bin edges.
  Because per-context occupied time is *additive over any partition of
  the time axis* (and durations are integer ns, exact in float64), any
  ``[t0, t1)`` window decomposes into O(log) whole tiles plus two
  sub-bin residuals refined per-event at the finest level — the answers
  are **bitwise-equal** to the per-event scan.
- **busy tiles** (per level, per bin): union-coverage ns of the line's
  events per bin — the ``stats.occupancy`` / idle-fraction primitive,
  additive the same way.
- **dominant-context tiles** (per call-stack depth, per level, per bin):
  the context (projected to that depth) with the most covered time in
  the bin, or idle — the O(1)-per-pixel overview raster.
- **finest-level refinement data** (per line): the running-max event end
  (``emax``) and the nested-overlap flag, which is exactly the per-render
  O(events) precomputation ``raster.rasterize`` used to redo every call.
  With it stored, *exact* midpoint-sample rasters cost O(width log E).

Determinism contract: ``trace.pyr`` bytes are a pure function of the
``trace.db`` bytes and the CCT parent array (canonical header JSON +
canonically ordered tiles; rebuild == rebuild, pinned in
tests/test_pyramid.py), matching every other artifact in the repo.  The
header records digests of both inputs, so the lazy cache
(``ensure_pyramid``) detects staleness without touching event data.

Layout::

    MAGIC "RPYR" | u32 version | u64 header_len | header JSON | pad to 64
    int64 data[]   (per line: emax, then per level: busy | tile offsets |
                    ctx pairs | ns pairs | dominant[depth x bins])

Exactness contract (docs/traceview.md): ``interval_profile`` / ``summary``
/ ``occupancy`` tile answers are bitwise-equal to the per-event path for
*any* window; rasters are bitwise-equal in ``exact`` mode (and in
``auto`` mode once a pixel is narrower than the finest bin), while
coarse ``auto``/``dominant`` rasters paint the dominant context per
pixel — a deliberate, documented estimator change for zoomed-out views.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cct import tree_depths
from repro.traceview.raster import (IDLE, Raster, ancestors_at_depth,
                                    _pick_rows, line_label, sample_line)
from repro.traceview.tracedb import TraceDB, _HDR as _DB_HDR

MAGIC = b"RPYR"
VERSION = 1
_ALIGN = 64
_HDR = struct.Struct("<4sIQ")    # magic, version, header json length

# default finest-level sizing: one bin per ~TARGET_EVENTS_PER_BIN events,
# clamped to [MIN_BINS, MAX_BINS] — a pure function of the database
TARGET_EVENTS_PER_BIN = 256
MIN_BINS_LOG2 = 4                # 16 bins
MAX_BINS_LOG2 = 12               # 4096 bins
MAX_DOMINANT_DEPTH = 32          # deeper trees fall back to exact rasters


# --------------------------------------------------------------------------
# build helpers
# --------------------------------------------------------------------------
def _default_bins(n_events: int) -> int:
    k = max(1, n_events // TARGET_EVENTS_PER_BIN).bit_length()
    return 1 << max(MIN_BINS_LOG2, min(MAX_BINS_LOG2, k))


def _group_sum(keys_a: np.ndarray, keys_b: np.ndarray, vals: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sum ``vals`` grouped by the (a, b) key pair; groups come back
    lexsorted by (a, b) — the canonical tile order."""
    order = np.lexsort((keys_b, keys_a))
    a, b, v = keys_a[order], keys_b[order], vals[order]
    if not len(a):
        return a, b, v.astype(np.int64)
    new = np.ones(len(a), bool)
    new[1:] = (a[1:] != a[:-1]) | (b[1:] != b[:-1])
    idx = np.flatnonzero(new)
    return a[idx], b[idx], np.add.reduceat(v, idx).astype(np.int64)


def _event_bin_segments(starts: np.ndarray, ends: np.ndarray,
                        ctx: np.ndarray, t_min: int, w0: int, n_bins: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split events at finest-bin boundaries: (bin, ctx, overlap-ns)
    per segment, overlaps clipped at bin edges."""
    dur = ends - starts
    keep = dur > 0
    s, e, c = starts[keep], ends[keep], ctx[keep]
    if not len(s):
        z = np.zeros(0, np.int64)
        return z, z, z
    b_first = (s - t_min) // w0
    b_last = (e - 1 - t_min) // w0
    b_first = np.clip(b_first, 0, n_bins - 1)
    b_last = np.clip(b_last, 0, n_bins - 1)
    counts = b_last - b_first + 1
    total = int(counts.sum())
    rep = np.repeat(np.arange(len(s)), counts)
    base = np.zeros(len(s), np.int64)
    np.cumsum(counts[:-1], out=base[1:])
    seg_bin = b_first[rep] + (np.arange(total) - base[rep])
    bin_lo = t_min + seg_bin * w0
    ov = np.minimum(e[rep], bin_lo + w0) - np.maximum(s[rep], bin_lo)
    sel = ov > 0
    return seg_bin[sel], c[rep][sel], ov[sel]


def _merged_coverage(starts: np.ndarray, ends: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Disjoint union intervals of start-sorted events (the
    ``stats.merge_intervals`` sweep without the re-sort)."""
    if not len(starts):
        return starts, ends
    emax = np.maximum.accumulate(ends)
    new = np.ones(len(starts), bool)
    new[1:] = starts[1:] > emax[:-1]
    idx = np.flatnonzero(new)
    return starts[idx], np.maximum.reduceat(ends, idx)


def _coverage_per_bin(m_s: np.ndarray, m_e: np.ndarray,
                      edges: np.ndarray) -> np.ndarray:
    """Union-covered ns between consecutive ``edges`` (int64 exact)."""
    if not len(m_s):
        return np.zeros(len(edges) - 1, np.int64)
    dur = m_e - m_s
    cum = np.concatenate([[0], np.cumsum(dur)])
    idx = np.searchsorted(m_s, edges, side="right")
    safe = np.maximum(idx - 1, 0)
    partial = np.clip(edges - m_s[safe], 0, dur[safe]) * (idx > 0)
    return np.diff(cum[safe] * (idx > 0) + partial).astype(np.int64)


def _dominant_tiles(bins: np.ndarray, proj: np.ndarray, ns: np.ndarray,
                    busy: np.ndarray, spans: np.ndarray) -> np.ndarray:
    """Per bin: the projected context with the most covered ns, ties to
    the smallest ctx id; ``IDLE`` when the bin's idle time
    (in-data-range span minus union busy) beats every context."""
    n_bins = len(busy)
    dom = np.full(n_bins, IDLE, np.int64)
    best = np.zeros(n_bins, np.int64)
    if len(bins):
        b, p, v = _group_sum(bins, proj, ns)
        first = np.ones(len(b), bool)
        first[1:] = b[1:] != b[:-1]
        starts_idx = np.flatnonzero(first)
        bmax = np.maximum.reduceat(v, starts_idx)
        ub = b[starts_idx]
        best[ub] = bmax
        # winner: first (smallest-proj) group reaching its bin's max
        pos = np.searchsorted(ub, b)
        win = np.flatnonzero(v == bmax[pos])
        wb, wfirst = np.unique(b[win], return_index=True)
        dom[wb] = p[win[wfirst]]
    idle = np.maximum(spans - busy, 0)
    dom[idle > best] = IDLE
    return dom


def _tile_cover(b0: int, b1: int, n_levels: int) -> List[Tuple[int, int]]:
    """Maximal aligned power-of-two tiles covering finest-bin range
    [b0, b1): at most 2*(n_levels-1) tiles, greedily by alignment."""
    out: List[Tuple[int, int]] = []
    while b0 < b1:
        lev = (b0 & -b0).bit_length() - 1 if b0 else n_levels - 1
        lev = min(lev, n_levels - 1)
        while (1 << lev) > b1 - b0:
            lev -= 1
        out.append((lev, b0 >> lev))
        b0 += 1 << lev
    return out


def _db_header_sha(db_path: str) -> str:
    """Digest of the trace.db header block (magic + version + canonical
    JSON): changes whenever the line set, counts, offsets, or time range
    change — the cheap staleness signal for the lazy cache."""
    with open(db_path, "rb") as f:
        raw = f.read(_DB_HDR.size)
        _, _, hdr_len = _DB_HDR.unpack(raw)
        return hashlib.sha256(raw + f.read(hdr_len)).hexdigest()


def _parents_sha(parents: np.ndarray) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(parents, np.int64)
                             .astype("<i8")).tobytes()).hexdigest()


# --------------------------------------------------------------------------
# builder
# --------------------------------------------------------------------------
def pyramid_path_for(db_path: str) -> str:
    base, _ = os.path.splitext(db_path)
    return base + ".pyr"


def build_pyramid(source: Union[str, TraceDB], parents: np.ndarray,
                  out_path: Optional[str] = None, *,
                  bins: Optional[int] = None) -> "TracePyramid":
    """Build ``trace.pyr`` from a merged ``trace.db`` and the database's
    CCT parent array.  Output bytes are a pure function of the two
    inputs (staged temp + atomic rename, like every artifact)."""
    own = isinstance(source, str)
    tdb = TraceDB(source) if own else source
    try:
        parents = np.asarray(parents, np.int64)
        depths = tree_depths(parents)
        max_depth = int(depths.max()) if len(depths) else 0
        dom_depth = min(max_depth, MAX_DOMINANT_DEPTH)
        anc = np.stack([ancestors_at_depth(parents, depths, d)
                        for d in range(dom_depth + 1)]) \
            if len(parents) else np.zeros((1, 0), np.int64)

        t_min, t_max = tdb.t_min, tdb.t_max
        n_bins = bins if bins else _default_bins(tdb.n_events)
        if n_bins & (n_bins - 1):
            raise ValueError(f"bins must be a power of two, got {n_bins}")
        w0 = max(1, -((t_min - t_max) // n_bins))     # ceil(span / n_bins)
        n_levels = n_bins.bit_length()                # levels 0..log2(B0)
        edges0 = t_min + np.arange(n_bins + 1, dtype=np.int64) * w0
        spans0 = np.diff(np.clip(edges0, t_min, max(t_max, t_min)))

        chunks: List[np.ndarray] = []
        offset = 0

        def put(arr: np.ndarray) -> int:
            nonlocal offset
            arr = np.ascontiguousarray(arr, np.int64)
            chunks.append(arr)
            off = offset
            offset += arr.size
            return off

        line_index = []
        n_ctx = len(parents)
        for i in range(len(tdb)):
            s = np.asarray(tdb.starts(i), np.int64)
            e = np.asarray(tdb.ends(i), np.int64)
            c = np.asarray(tdb.ctx(i), np.int64)
            emax = np.maximum.accumulate(e) if len(e) else e
            nested = len(s) > 1 and bool((s[1:] < emax[:-1]).any())
            entry = {
                "identity": tdb.lines[i].identity,
                "count": len(s),
                "t0": int(s[0]) if len(s) else 0,
                "t1": int(emax[-1]) if len(e) else 0,
                "nested": nested,
                "emax": put(emax),
                "levels": [],
            }
            seg_bin, seg_ctx, seg_ns = _event_bin_segments(
                s, e, c, t_min, w0, n_bins)
            pb, pc, pv = _group_sum(seg_bin, seg_ctx, seg_ns)
            m_s, m_e = _merged_coverage(s, e)
            busy = _coverage_per_bin(m_s, m_e, edges0)
            # per-depth projected pairs, coarsened level by level
            valid = (pc >= 0) & (pc < n_ctx)
            dom_pairs = [(pb[valid], anc[d][pc[valid]], pv[valid])
                         for d in range(dom_depth + 1)]
            spans = spans0
            n_l = n_bins
            for lev in range(n_levels):
                if lev:
                    n_l //= 2
                    pb, pc, pv = _group_sum(pb // 2, pc, pv)
                    busy = busy[0::2] + busy[1::2]
                    spans = spans[0::2] + spans[1::2]
                    dom_pairs = [_group_sum(db_ // 2, dc, dv)
                                 for db_, dc, dv in dom_pairs]
                toff = np.zeros(n_l + 1, np.int64)
                np.cumsum(np.bincount(pb, minlength=n_l), out=toff[1:])
                dom = np.concatenate(
                    [_dominant_tiles(db_, dc, dv, busy, spans)
                     for db_, dc, dv in dom_pairs]) \
                    if dom_pairs else np.zeros(0, np.int64)
                entry["levels"].append({
                    "bins": n_l,
                    "busy": put(busy),
                    "toff": put(toff),
                    "ctx": put(pc),
                    "ns": put(pv),
                    "pairs": int(len(pc)),
                    "dom": put(dom),
                })
            line_index.append(entry)

        header = json.dumps(
            {"version": VERSION, "t_min": t_min, "t_max": t_max,
             "bin_ns": int(w0), "n_bins": int(n_bins),
             "n_levels": int(n_levels), "max_depth": int(dom_depth),
             "n_ctx": int(n_ctx),
             "source": {"db_header_sha256": _db_header_sha(tdb.path),
                        "n_events": tdb.n_events},
             "parents_sha256": _parents_sha(parents),
             "lines": line_index},
            sort_keys=True, separators=(",", ":")).encode()
        if out_path is None:
            out_path = pyramid_path_for(tdb.path)
        tmp = out_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_HDR.pack(MAGIC, VERSION, len(header)))
            f.write(header)
            pos = _HDR.size + len(header)
            f.write(b"\0" * (-pos % _ALIGN))
            for arr in chunks:
                f.write(arr.astype("<i8").tobytes())
        os.replace(tmp, out_path)
    finally:
        if own:
            tdb.close()
    return TracePyramid(out_path)


# --------------------------------------------------------------------------
# reader
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PyramidLine:
    identity: dict
    count: int
    t0: int
    t1: int
    nested: bool
    emax: int                 # element offset of the running-max array
    levels: List[dict]


class TracePyramid:
    """Memory-mapped ``trace.pyr`` reader + the tile-backed query layer.

    Opens the sibling ``trace.db`` lazily (only the sub-bin residual
    refinements and exact rasters touch event data).  Context manager:
    ``close()`` releases both mappings."""

    def __init__(self, path: str, tracedb: Optional[TraceDB] = None):
        self.path = path
        with open(path, "rb") as f:
            magic, version, hdr_len = _HDR.unpack(f.read(_HDR.size))
            if magic != MAGIC:
                raise ValueError(f"{path}: not a trace.pyr (bad magic)")
            if version != VERSION:
                raise ValueError(f"{path}: unsupported version {version}")
            hdr = json.loads(f.read(hdr_len))
        data_offset = (_HDR.size + hdr_len + _ALIGN - 1) // _ALIGN * _ALIGN
        self.t_min: int = hdr["t_min"]
        self.t_max: int = hdr["t_max"]
        self.bin_ns: int = hdr["bin_ns"]
        self.n_bins: int = hdr["n_bins"]
        self.n_levels: int = hdr["n_levels"]
        self.max_depth: int = hdr["max_depth"]
        self.n_ctx: int = hdr["n_ctx"]
        self.source: dict = hdr["source"]
        self.parents_sha256: str = hdr["parents_sha256"]
        self.lines: List[PyramidLine] = [
            PyramidLine(ln["identity"], ln["count"], ln["t0"], ln["t1"],
                        ln["nested"], ln["emax"], ln["levels"])
            for ln in hdr["lines"]]
        n_elems = (os.path.getsize(path) - data_offset) // 8
        self._data: Optional[np.ndarray] = np.memmap(
            path, np.int64, mode="r", offset=data_offset,
            shape=(n_elems,)) if n_elems else np.zeros(0, np.int64)
        self._tdb = tracedb
        self._own_tdb = tracedb is None
        self._cum_busy: Dict[int, np.ndarray] = {}
        self._occ_idx: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        data, self._data = self._data, None
        if isinstance(data, np.memmap):
            data._mmap.close()
        if self._own_tdb and self._tdb is not None:
            self._tdb.close()
        self._tdb = None
        self._cum_busy.clear()
        self._occ_idx.clear()

    def __enter__(self) -> "TracePyramid":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __len__(self) -> int:
        return len(self.lines)

    @property
    def tdb(self) -> TraceDB:
        if self._tdb is None:
            if self._data is None:
                raise ValueError(f"{self.path}: pyramid is closed")
            self._tdb = TraceDB(os.path.splitext(self.path)[0] + ".db")
        return self._tdb

    def line_index(self, identity: dict) -> int:
        """Pyramid line index of a trace-line identity (KeyError when
        the identity is not in this pyramid)."""
        idx = getattr(self, "_line_idx", None)
        if idx is None:
            idx = {json.dumps(ln.identity, sort_keys=True): i
                   for i, ln in enumerate(self.lines)}
            self._line_idx = idx
        return idx[json.dumps(identity, sort_keys=True)]

    def _arr(self, off: int, n: int) -> np.ndarray:
        if self._data is None:
            raise ValueError(f"{self.path}: pyramid is closed")
        return self._data[off:off + n]

    # -- raw tile access ---------------------------------------------------
    def emax(self, i: int) -> np.ndarray:
        ln = self.lines[i]
        return self._arr(ln.emax, ln.count)

    def busy_tiles(self, i: int, level: int) -> np.ndarray:
        lv = self.lines[i].levels[level]
        return self._arr(lv["busy"], lv["bins"])

    def ctx_tiles(self, i: int, level: int, b0: int,
                  b1: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Sparse (ctx, ns) pairs of the tile range [b0, b1) (one tile
        when ``b1`` is omitted) — one contiguous slice of the level's
        pair arrays."""
        lv = self.lines[i].levels[level]
        toff = self._arr(lv["toff"], lv["bins"] + 1)
        lo, hi = int(toff[b0]), int(toff[b0 + 1 if b1 is None else b1])
        return (self._arr(lv["ctx"] + lo, hi - lo),
                self._arr(lv["ns"] + lo, hi - lo))

    def dominant_tiles(self, i: int, level: int, depth: int) -> np.ndarray:
        lv = self.lines[i].levels[level]
        d = min(max(depth, 0), self.max_depth)
        return self._arr(lv["dom"] + d * lv["bins"], lv["bins"])

    # -- selection ---------------------------------------------------------
    def select(self, flt=None, parents=None
               ) -> Tuple[List[int], Optional[np.ndarray],
                          Optional[int], Optional[int]]:
        """Compose a ``TraceFilter`` with tile selection: line indices
        surviving the identity predicates, the subtree ctx mask (or
        None), and the filter's time window — whole lines and whole tile
        ranges are pruned before any event is touched."""
        if flt is None:
            return list(range(len(self.lines))), None, None, None
        line_ids = [i for i, ln in enumerate(self.lines)
                    if flt.keeps_line(ln.identity)]
        ctx_mask = None
        if flt.subtree is not None:
            from repro.traceview.filter import subtree_mask
            if parents is None:
                raise ValueError("subtree filter requires the CCT parents")
            ctx_mask = subtree_mask(parents, flt.subtree)
        return line_ids, ctx_mask, flt.t0, flt.t1

    def line_range(self, lines: Optional[Sequence[int]] = None
                   ) -> Tuple[int, int]:
        """Default query window over the selected lines: (min first
        start, max end) — what the per-event default windows compute."""
        ids = range(len(self.lines)) if lines is None else lines
        t0 = min((self.lines[i].t0 for i in ids if self.lines[i].count),
                 default=0)
        t1 = max((self.lines[i].t1 for i in ids if self.lines[i].count),
                 default=t0)
        return t0, t1

    # -- window decomposition ---------------------------------------------
    def _window_tiles(self, t0: int, t1: int
                      ) -> Tuple[List[Tuple[int, int]],
                                 List[Tuple[int, int]]]:
        """Decompose [t0, t1) into aligned tiles + sub-bin residual
        ranges.  Clips to the grid; returns (tiles, residuals)."""
        grid_end = self.t_min + self.n_bins * self.bin_ns
        t0 = max(int(t0), self.t_min)
        t1 = min(int(t1), grid_end)
        if t1 <= t0:
            return [], []
        w0 = self.bin_ns
        b_lo = -((self.t_min - t0) // w0)             # ceil
        b_hi = (t1 - self.t_min) // w0                # floor
        if b_lo > b_hi:                                # inside one bin
            return [], [(t0, t1)]
        residuals = []
        a = self.t_min + b_lo * w0
        b = self.t_min + b_hi * w0
        if t0 < a:
            residuals.append((t0, a))
        if b < t1:
            residuals.append((b, t1))
        # coalesce same-level neighbours into runs: one contiguous
        # (ctx, ns) slice per run instead of one read per tile
        runs: List[List[int]] = []
        for lev, tb in _tile_cover(b_lo, b_hi, self.n_levels):
            if runs and runs[-1][0] == lev and runs[-1][2] == tb:
                runs[-1][2] = tb + 1
            else:
                runs.append([lev, tb, tb + 1])
        return [tuple(r) for r in runs], residuals

    def _refine_profile(self, i: int, a: int, b: int, out: np.ndarray,
                        ctx_mask: Optional[np.ndarray]) -> None:
        """Per-event scatter-add of overlaps with [a, b) — the finest-
        level refinement, pruned by the stored running-max ends."""
        tdb = self.tdb
        s = tdb.starts(i)
        if not len(s):
            return
        hi = int(np.searchsorted(s, b, side="left"))
        lo = int(np.searchsorted(self.emax(i)[:hi], a, side="right"))
        e = tdb.ends(i)[lo:hi]
        ov = np.minimum(e, b) - np.maximum(s[lo:hi], a)
        sel = ov > 0
        ctx = tdb.ctx(i)[lo:hi][sel]
        n_ctx = len(out)
        valid = (ctx >= 0) & (ctx < n_ctx)
        if ctx_mask is not None:
            keep = valid & ctx_mask[np.clip(ctx, 0, n_ctx - 1)]
            np.add.at(out, ctx[keep], ov[sel][keep].astype(np.float64))
        else:
            np.add.at(out, np.where(valid, ctx, 0),
                      ov[sel].astype(np.float64))

    # -- queries -----------------------------------------------------------
    def interval_profile(self, n_ctx: int, t0: int, t1: int, *,
                         lines: Optional[Sequence[int]] = None,
                         ctx_mask: Optional[np.ndarray] = None
                         ) -> np.ndarray:
        """(n_ctx,) time-weighted ns per context over [t0, t1) —
        bitwise-equal to ``stats.interval_profile`` on the same lines
        (integer ns are exact in float64, so the tile decomposition sums
        to the per-event answer).  ``ctx_mask`` composes the subtree
        filter at the tile level: non-matching pairs are skipped and
        refinement drops masked events, matching ``apply_filter``."""
        out = np.zeros(n_ctx, np.float64)
        tiles, residuals = self._window_tiles(t0, t1)
        ids = range(len(self.lines)) if lines is None else lines
        for i in ids:
            if not self.lines[i].count:
                continue
            for lev, b0, b1 in tiles:
                ctx, ns = self.ctx_tiles(i, lev, b0, b1)
                if not len(ctx):
                    continue
                valid = (ctx >= 0) & (ctx < n_ctx)
                if ctx_mask is not None:
                    keep = valid & ctx_mask[np.clip(ctx, 0, n_ctx - 1)]
                    np.add.at(out, ctx[keep], ns[keep].astype(np.float64))
                else:
                    # out-of-range ctx attributes to root, matching
                    # stats.interval_profile
                    np.add.at(out, np.where(valid, ctx, 0),
                              np.asarray(ns, np.float64))
            for a, b in residuals:
                self._refine_profile(i, a, b, out, ctx_mask)
        return out

    def _cum_busy_line(self, i: int) -> np.ndarray:
        cum = self._cum_busy.get(i)
        if cum is None:
            cum = np.concatenate(
                [[0], np.cumsum(self.busy_tiles(i, 0))]).astype(np.int64)
            self._cum_busy[i] = cum
        return cum

    def _coverage_many(self, i: int, ts: np.ndarray) -> np.ndarray:
        """C(t) per edge: union-covered ns of line ``i`` in [t_min, t) —
        busy-tile cumsum at the nearest finest-bin edge below each t,
        plus per-event refinement inside the single bin containing it.
        All edges refine in one vectorized sweep: segment expansion of
        the (emax-pruned) candidate events per edge, then one
        ``_merged_coverage`` pass over per-edge offset blocks."""
        grid_end = self.t_min + self.n_bins * self.bin_ns
        ts = np.clip(np.asarray(ts, np.int64), self.t_min, grid_end)
        k = (ts - self.t_min) // self.bin_ns
        edge = self.t_min + k * self.bin_ns
        out = self._cum_busy_line(i)[np.minimum(k, self.n_bins)].copy()
        if not self.lines[i].count:
            return out
        idx = np.flatnonzero(ts > edge)
        if not len(idx):
            return out
        t_n, e_n = ts[idx], edge[idx]
        tdb = self.tdb
        s = np.asarray(tdb.starts(i), np.int64)
        e = np.asarray(tdb.ends(i), np.int64)
        hi = np.searchsorted(s, t_n, side="left")
        # emax is nondecreasing, so the prune lower bound vectorizes on
        # the full array (capped at hi — the scalar path's emax[:hi])
        lo = np.minimum(np.searchsorted(self.emax(i), e_n, side="right"),
                        hi)
        counts = hi - lo
        total = int(counts.sum())
        if not total:
            return out
        grp = np.repeat(np.arange(len(idx)), counts)
        base = np.zeros(len(idx), np.int64)
        np.cumsum(counts[:-1], out=base[1:])
        pos = lo[grp] + (np.arange(total) - base[grp])
        cs = np.clip(s[pos], e_n[grp], t_n[grp]) - self.t_min
        ce = np.clip(e[pos], e_n[grp], t_n[grp]) - self.t_min
        # offset trick: shift each edge's block by grp*BIG so one merged-
        # coverage sweep unions per-edge without merging across edges
        big = (grid_end - self.t_min) + self.bin_ns + 1
        m_s, m_e = _merged_coverage(cs + grp * big, ce + grp * big)
        add = np.bincount(m_s // big, weights=m_e - m_s,
                          minlength=len(idx)).astype(np.int64)
        out[idx] += add
        return out

    def _coverage_before(self, i: int, t: int) -> int:
        """C(t): union-covered ns of line ``i`` in [t_min, t)."""
        return int(self._coverage_many(i, np.asarray([t], np.int64))[0])

    def _occ_index_line(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """Cached per-line refinement index: candidate events for an
        edge inside finest bin ``b`` are ``[ev_lo[b], ev_hi[b])``.
        ``ev_hi`` is relaxed to the bin *end* — events starting between
        the edge and the bin end clip to zero length and contribute
        nothing — so occupancy refinement needs no per-query
        searchsorted, only gathers from this table."""
        cached = self._occ_idx.get(i)
        if cached is None:
            edges = self.t_min + np.arange(self.n_bins + 1,
                                           dtype=np.int64) * self.bin_ns
            ev_hi = np.searchsorted(self.tdb.starts(i), edges[1:],
                                    side="left")
            ev_lo = np.minimum(
                np.searchsorted(self.emax(i), edges[:-1], side="right"),
                ev_hi)
            cached = (ev_lo, ev_hi)
            self._occ_idx[i] = cached
        return cached

    def occupancy(self, t0: int, t1: int, nbins: int, *,
                  lines: Optional[Sequence[int]] = None) -> np.ndarray:
        """(n_lines, nbins) busy ns per bin — bitwise-equal to
        ``stats.occupancy`` on the same lines (differences of the exact
        cumulative coverage).  Batched across lines: per line only the
        two pruning searchsorteds run; gathering candidate events (one
        fancy index into the db's raw data region), clipping, the union
        sweep, and the per-edge sums happen once over every
        (line, edge) segment."""
        ids = list(range(len(self.lines))) if lines is None else list(lines)
        edges = int(t0) + (int(t1) - int(t0)) \
            * np.arange(nbins + 1, dtype=np.int64) // nbins
        grid_end = self.t_min + self.n_bins * self.bin_ns
        ts = np.clip(edges, self.t_min, grid_end)
        k = (ts - self.t_min) // self.bin_ns
        edge_lo = self.t_min + k * self.bin_ns
        kk = np.minimum(k, self.n_bins)
        cov = np.zeros((len(ids), nbins + 1), np.int64)
        for row, i in enumerate(ids):
            cov[row] = self._cum_busy_line(i)[kk]
        idx = np.flatnonzero(ts > edge_lo)    # edges inside a finest bin
        live = [row for row, i in enumerate(ids) if self.lines[i].count]
        if len(idx) and live:
            kb = k[idx]                       # finest bin per edge
            t_n, e_n = ts[idx], edge_lo[idx]
            tdb = self.tdb
            raw = tdb.raw()
            n_e = len(idx)
            hi = np.empty((len(live), n_e), np.int64)
            lo = np.empty_like(hi)
            for j, row in enumerate(live):
                ev_lo, ev_hi = self._occ_index_line(ids[row])
                lo[j] = ev_lo[kb]
                hi[j] = ev_hi[kb]
            counts = (hi - lo).ravel()
            total = int(counts.sum())
            if total:
                s_off = np.array([tdb.lines[ids[r]].offset for r in live],
                                 np.int64)
                cnt = np.array([tdb.lines[ids[r]].count for r in live],
                               np.int64)
                seg = np.repeat(np.arange(len(live) * n_e), counts)
                base = np.zeros(len(live) * n_e, np.int64)
                np.cumsum(counts[:-1], out=base[1:])
                pos = lo.ravel()[seg] + (np.arange(total) - base[seg])
                line_of = seg // n_e
                gpos = s_off[line_of] + pos
                a, b = e_n[seg % n_e], t_n[seg % n_e]
                cs = np.clip(raw[gpos], a, b) - self.t_min
                ce = np.clip(raw[gpos + cnt[line_of]], a, b) - self.t_min
                # offset trick: shift each (line, edge) block by seg*BIG
                # so one merged-coverage sweep unions per-segment
                # without merging across segments
                big = (grid_end - self.t_min) + self.bin_ns + 1
                m_s, m_e = _merged_coverage(cs + seg * big, ce + seg * big)
                add = np.bincount(m_s // big, weights=m_e - m_s,
                                  minlength=len(live) * n_e)
                cov[np.asarray(live, np.int64)[:, None], idx[None, :]] += \
                    add.reshape(len(live), n_e).astype(np.int64)
        return np.diff(cov).astype(np.float64)

    def rasterize(self, parents: np.ndarray, *,
                  t0: Optional[int] = None, t1: Optional[int] = None,
                  width: int = 120, height: int = 32, depth: int = 2,
                  depths: Optional[np.ndarray] = None,
                  lines: Optional[Sequence[int]] = None,
                  mode: str = "auto") -> Raster:
        """Tile-backed raster.  ``mode``:

        - ``"exact"`` — midpoint sampling, bitwise-equal to
          ``raster.rasterize`` on the same lines, O(width log E) per
          line via the stored ``emax``/nested refinement data;
        - ``"dominant"`` — each pixel paints the dominant context of the
          nearest-resolution tile under its midpoint, O(width) per line
          with no event touched;
        - ``"auto"`` — dominant while a pixel spans at least one finest
          bin, exact once zoomed past the finest level.
        """
        parents = np.asarray(parents, np.int64)
        ids = list(range(len(self.lines))) if lines is None else list(lines)
        if t0 is None or t1 is None:
            d0, d1 = self.line_range(ids)
            t0 = d0 if t0 is None else t0
            t1 = d1 if t1 is None else t1
        t0, t1 = int(t0), int(t1)
        if t1 <= t0:
            t1 = t0 + 1
        if depths is None:
            depths = tree_depths(parents)
        rows = _pick_rows(len(ids), height)
        samples = t0 + (np.arange(width, dtype=np.float64) + 0.5) \
            * (t1 - t0) / width
        pixel_ns = (t1 - t0) / width
        use_dom = mode == "dominant" or \
            (mode == "auto" and pixel_ns >= self.bin_ns)
        if use_dom and depth > self.max_depth \
                and self.max_depth < int(depths.max() if len(depths) else 0):
            use_dom = False          # tree deeper than the stored tiles
        if mode not in ("auto", "exact", "dominant"):
            raise ValueError(f"unknown raster mode {mode!r}")
        pixels = np.full((len(rows), width), IDLE, np.int64)
        if use_dom:
            # largest level whose bins are no wider than a pixel (level
            # 0 when forced dominant on a zoomed-in window)
            lev = min(max(int(pixel_ns // self.bin_ns).bit_length() - 1, 0),
                      self.n_levels - 1)
            w_lev = self.bin_ns << lev
            bins = ((samples - self.t_min) // w_lev).astype(np.int64)
            n_lev = self.lines[0].levels[lev]["bins"] if self.lines else 0
            inside = (bins >= 0) & (bins < n_lev) & (samples >= self.t_min)
            safe = np.clip(bins, 0, max(n_lev - 1, 0))
            for out_row, r in enumerate(rows):
                i = ids[r]
                if not self.lines[i].count:
                    continue
                dom = self.dominant_tiles(i, lev, depth)
                vals = dom[safe]
                pixels[out_row, inside & (vals != IDLE)] = \
                    vals[inside & (vals != IDLE)]
        else:
            tdb = self.tdb
            anc = ancestors_at_depth(parents, depths, depth)
            for out_row, r in enumerate(rows):
                i = ids[r]
                ln = self.lines[i]
                if not ln.count:
                    continue
                gids = sample_line(tdb.starts(i), tdb.ends(i), tdb.ctx(i),
                                   samples, emax=self.emax(i),
                                   nested=ln.nested)
                valid = (gids >= 0) & (gids < len(parents))
                pixels[out_row, valid] = anc[gids[valid]]
        return Raster(pixels, samples,
                      [line_label(self.lines[ids[r]].identity)
                       for r in rows],
                      np.asarray([ids[r] for r in rows], np.int64),
                      t0, t1, depth)


# --------------------------------------------------------------------------
# lazy cache
# --------------------------------------------------------------------------
def ensure_pyramid(db, parents: Optional[np.ndarray] = None, *,
                   rebuild: bool = False) -> TracePyramid:
    """Open the ``trace.pyr`` next to a database's ``trace.db``,
    building (or rebuilding) it when missing or stale.  ``db`` is a
    ``pipeline.Database`` (parents implied) or a ``trace.db`` path with
    explicit ``parents``.  Staleness = the recorded trace.db header
    digest or parents digest no longer matches — checked without
    touching event data."""
    if hasattr(db, "trace_db_path"):
        db_path = db.trace_db_path()
        if parents is None:
            parents = db.parents
    else:
        db_path = db
        if parents is None:
            raise ValueError("ensure_pyramid needs the CCT parents when "
                             "given a bare trace.db path")
    pyr_path = pyramid_path_for(db_path)
    if not rebuild and os.path.exists(pyr_path):
        pyr = TracePyramid(pyr_path)
        if (pyr.source.get("db_header_sha256") == _db_header_sha(db_path)
                and pyr.parents_sha256 == _parents_sha(parents)):
            return pyr
        pyr.close()
    return build_pyramid(db_path, parents, pyr_path)
