"""Pre-raster trace filters (hpctraceviewer's filter dialog): keep only
selected ranks / threads / streams, a time window, and/or the events
whose calling context lies under a chosen subtree of the global CCT.

Filters narrow the line set and event arrays *before* sampling, so a
filtered raster of a 1M-event database costs only the surviving events.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set

import numpy as np

from repro.core.trace import TraceData
from repro.traceview.raster import ancestors_at_depth, tree_depths


@dataclasses.dataclass
class TraceFilter:
    ranks: Optional[Set[int]] = None       # keep these ranks
    types: Optional[Set[str]] = None       # {"cpu", "gpu"}
    threads: Optional[Set[int]] = None     # CPU thread indices
    streams: Optional[Set[int]] = None     # GPU stream ids
    t0: Optional[int] = None               # window start (inclusive)
    t1: Optional[int] = None               # window end (exclusive)
    subtree: Optional[int] = None          # global ctx id: keep descendants

    def keeps_line(self, identity: dict) -> bool:
        if self.ranks is not None \
                and int(identity.get("rank", 0)) not in self.ranks:
            return False
        kind = identity.get("type", "cpu")
        if self.types is not None and kind not in self.types:
            return False
        if kind == "cpu" and self.threads is not None \
                and int(identity.get("thread", 0)) not in self.threads:
            return False
        if kind == "gpu" and self.streams is not None \
                and int(identity.get("stream", 0)) not in self.streams:
            return False
        return True


def subtree_mask(parents: np.ndarray, root_gid: int) -> np.ndarray:
    """Boolean (n_ctx,) — True for ``root_gid`` and its descendants,
    via the same vectorized ancestor projection the raster uses."""
    parents = np.asarray(parents, np.int64)
    depths = tree_depths(parents)
    anc = ancestors_at_depth(parents, depths, int(depths[root_gid]))
    return anc == root_gid


def apply_filter(lines: Sequence[TraceData], flt: TraceFilter,
                 parents: Optional[np.ndarray] = None) -> List[TraceData]:
    """Filtered per-line TraceData views.  Lines failing the identity
    predicates are dropped; events outside the window or subtree are
    masked out (a subtree filter needs ``parents``), and events
    straddling a window edge are *clipped* to [t0, t1) — so a
    downstream default-window ``summary``/``rasterize`` stays inside
    the filter window instead of expanding over a straddler's full
    extent (the pre-clip behavior silently counted out-of-window time).
    """
    keep_ctx = None
    if flt.subtree is not None:
        if parents is None:
            raise ValueError("subtree filter requires the CCT parents")
        keep_ctx = subtree_mask(parents, flt.subtree)
    out: List[TraceData] = []
    for td in lines:
        if not flt.keeps_line(td.identity):
            continue
        starts = np.asarray(td.starts, np.int64)
        ends = np.asarray(td.ends, np.int64)
        ctx = np.asarray(td.ctx, np.int64)
        sel = np.ones(len(starts), bool)
        if flt.t0 is not None:
            sel &= ends > flt.t0
        if flt.t1 is not None:
            sel &= starts < flt.t1
        if keep_ctx is not None:
            valid = (ctx >= 0) & (ctx < len(keep_ctx))
            sel &= valid & keep_ctx[np.clip(ctx, 0, len(keep_ctx) - 1)]
        clip_lo = flt.t0 if flt.t0 is not None else np.iinfo(np.int64).min
        clip_hi = flt.t1 if flt.t1 is not None else np.iinfo(np.int64).max
        if sel.all() and (not len(starts) or (
                starts.min() >= clip_lo and ends.max() <= clip_hi)):
            out.append(td)
        else:
            out.append(TraceData(td.identity,
                                 np.clip(starts[sel], clip_lo, clip_hi),
                                 np.clip(ends[sel], clip_lo, clip_hi),
                                 ctx[sel]))
    return out
