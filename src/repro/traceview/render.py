"""Text-mode hpctraceviewer (paper §7): the depth×time trace view, a
depth selector line, and the Statistic panel, rendered as aligned text so
tests and examples can assert on it (same philosophy as core/viewer.py).

Each distinct context in the raster gets a glyph, assigned by descending
on-screen area so ``a`` is always the dominant context; idle pixels are
``.``.  The legend doubles as the Statistic panel when ``summary`` rows
are attached.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.trace import TraceData
from repro.traceview.raster import IDLE, Raster, rasterize

GLYPHS = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"
OTHER = "#"       # contexts beyond the glyph alphabet


def _glyph_map(pixels: np.ndarray) -> dict:
    """gid -> glyph, by descending pixel area (ties: ascending gid)."""
    gids, counts = np.unique(pixels[pixels != IDLE], return_counts=True)
    order = np.lexsort((gids, -counts))
    return {int(gids[i]): (GLYPHS[rank] if rank < len(GLYPHS) else OTHER)
            for rank, i in enumerate(order)}


def render(raster: Raster, db, *, legend: bool = True,
           max_legend: int = 12) -> str:
    """The trace view: one row per line, one glyph per sample."""
    glyphs = _glyph_map(raster.pixels)
    span = raster.t1 - raster.t0
    lines = [f"TRACEVIEW  [{raster.t0}, {raster.t1})  span={span}ns  "
             f"depth={raster.depth}  {raster.pixels.shape[0]}x"
             f"{raster.pixels.shape[1]}"]
    label_w = max((len(s) for s in raster.labels), default=0)
    for row, label in enumerate(raster.labels):
        body = "".join(glyphs.get(int(g), ".") for g in raster.pixels[row])
        lines.append(f"{label:>{label_w}} |{body}|")
    if legend and glyphs:
        total = int((raster.pixels != IDLE).sum()) or 1
        lines.append("legend:")
        by_area = sorted(glyphs.items(),
                         key=lambda kv: (kv[1] == OTHER, GLYPHS.find(kv[1])))
        for gid, g in by_area[:max_legend]:
            area = int((raster.pixels == gid).sum())
            name = (db.frames[gid].pretty() if 0 <= gid < len(db.frames)
                    else f"ctx{gid}")
            lines.append(f"  {g} {area / total * 100:5.1f}%  {name}")
    return "\n".join(lines)


def depth_selector(max_depth: int, depth: int) -> str:
    """The depth selector widget: ``depth: 0 1 [2] 3 ...``."""
    cells = [f"[{d}]" if d == depth else f" {d} "
             for d in range(max_depth + 1)]
    return "depth: " + "".join(cells)


def statistic_panel(rows: Sequence[Tuple[str, float]],
                    title: str = "Statistic") -> str:
    """The trace view's Statistic tab as text (name, % of trace area)."""
    lines = [f"{title}:"]
    for name, frac in rows:
        lines.append(f"  {frac * 100:5.1f}%  {name}")
    return "\n".join(lines)


def render_view(lines: Sequence[TraceData], db, *,
                t0: Optional[int] = None, t1: Optional[int] = None,
                width: int = 120, height: int = 32, depth: int = 2,
                top: int = 8, max_depth: Optional[int] = None,
                pyramid=None, mode: str = "auto") -> str:
    """One-stop view: depth selector + raster + Statistic panel, the text
    analogue of one hpctraceviewer screen.

    With ``pyramid`` (a ``pyramid.TracePyramid``), both the raster and
    the Summary rows come from the tiles — O(tiles-touched) per
    zoom/pan instead of O(events) — and ``lines`` is ignored (pass
    None).  ``mode`` selects the raster estimator (``auto`` / ``exact``
    / ``dominant``, see ``TracePyramid.rasterize``)."""
    from repro.traceview.raster import tree_depths
    from repro.traceview.stats import summary
    depths = db.depths() if hasattr(db, "depths") else \
        tree_depths(np.asarray(db.parents, np.int64))
    if pyramid is not None:
        raster = pyramid.rasterize(db.parents, t0=t0, t1=t1, width=width,
                                   height=height, depth=depth,
                                   depths=depths, mode=mode)
    else:
        raster = rasterize(lines, db.parents, t0=t0, t1=t1, width=width,
                           height=height, depth=depth, depths=depths)
    if max_depth is None:
        max_depth = int(depths.max()) if len(depths) else 0
    rows = summary(lines, db, t0=raster.t0, t1=raster.t1, depth=depth,
                   top=top, depths=depths, pyramid=pyramid)
    return "\n".join([depth_selector(max_depth, depth),
                      render(raster, db),
                      statistic_panel(rows, title="Statistic (Summary)")])
