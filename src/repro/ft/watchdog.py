"""Fault tolerance: straggler watchdog, restart policy, elastic re-mesh.

At 1000+ nodes the failure model is: hosts disappear (hardware), hosts
straggle (thermal / network / noisy neighbors), and the job must resume
from the last atomic checkpoint on whatever healthy capacity remains.

- ``StragglerWatchdog`` consumes per-host step heartbeats (in production:
  a side channel or the coordination service; in tests: direct calls) and
  flags hosts whose progress lags the fleet median by more than a
  threshold, or whose heartbeat went stale.
- ``RestartPolicy`` is exponential-backoff with a restart budget per
  rolling window — the supervisor decides *whether* to relaunch.
- ``plan_elastic_mesh`` maps surviving device counts to the largest
  supported (pod, data, model) mesh <= capacity, keeping the model axis
  fixed (TP degree is baked into layer shapes) and shrinking data/pod —
  with the checkpoint manager's elastic restore, training resumes on the
  new mesh with a reduced global batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Heartbeat:
    host: str
    step: int
    t: float


class StragglerWatchdog:
    def __init__(self, *, stale_s: float = 300.0, lag_steps: int = 10,
                 clock=time.monotonic):
        self.stale_s = stale_s
        self.lag_steps = lag_steps
        self.clock = clock
        self._last: Dict[str, Heartbeat] = {}
        self._step_times: Dict[str, List[float]] = {}

    def beat(self, host: str, step: int, t: Optional[float] = None):
        t = self.clock() if t is None else t
        prev = self._last.get(host)
        if prev is not None and step > prev.step:
            self._step_times.setdefault(host, []).append(
                (t - prev.t) / (step - prev.step))
            self._step_times[host] = self._step_times[host][-32:]
        self._last[host] = Heartbeat(host, step, t)

    def median_step(self) -> int:
        steps = sorted(h.step for h in self._last.values())
        return steps[len(steps) // 2] if steps else 0

    def stragglers(self, now: Optional[float] = None) -> List[str]:
        """Hosts stale or >= lag_steps behind the fleet median."""
        now = self.clock() if now is None else now
        med = self.median_step()
        out = []
        for host, hb in self._last.items():
            if now - hb.t > self.stale_s:
                out.append(host)
            elif med - hb.step >= self.lag_steps:
                out.append(host)
        return sorted(out)

    def slow_hosts(self, factor: float = 1.5) -> List[str]:
        """Hosts whose mean step time exceeds factor x fleet median —
        the mitigation driver (e.g. exclude from the next elastic plan)."""
        means = {h: sum(v) / len(v) for h, v in self._step_times.items() if v}
        if not means:
            return []
        med = sorted(means.values())[len(means) // 2]
        return sorted(h for h, m in means.items() if m > factor * med)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    window_s: float = 3600.0
    backoff_base_s: float = 10.0
    backoff_max_s: float = 600.0

    def __post_init__(self):
        self._events: List[float] = []

    def record_failure(self, t: float) -> None:
        self._events.append(t)

    def should_restart(self, t: float) -> bool:
        recent = [e for e in self._events if t - e <= self.window_s]
        return len(recent) <= self.max_restarts

    def backoff_s(self) -> float:
        n = len(self._events)
        return min(self.backoff_base_s * (2 ** max(n - 1, 0)),
                   self.backoff_max_s)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    excluded_hosts: Tuple[str, ...]
    global_batch_scale: float        # new_global_batch / old_global_batch
    resume_step: Optional[int]


def plan_elastic_mesh(n_devices: int, *, model: int = 16,
                      devices_per_host: int = 4,
                      excluded_hosts: Sequence[str] = (),
                      old_data: int = 16, pods: int = 1,
                      resume_step: Optional[int] = None) -> ElasticPlan:
    """Largest (pod, data, model) mesh that fits the surviving devices.

    The model axis stays fixed (TP degree is shape-baked); data shrinks to
    the largest power of two <= capacity / (model * pods); if even data=1
    does not fit, pods collapse first.
    """
    assert n_devices >= model, "cannot keep TP degree on surviving devices"
    while pods > 1 and n_devices < pods * model:
        pods //= 2
    data = 1
    while pods * model * data * 2 <= n_devices:
        data *= 2
    shape: Tuple[int, ...]
    if pods > 1:
        shape, axes = (pods, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return ElasticPlan(
        mesh_shape=shape, mesh_axes=axes,
        excluded_hosts=tuple(sorted(excluded_hosts)),
        global_batch_scale=(pods * data) / max(old_data, 1),
        resume_step=resume_step,
    )
