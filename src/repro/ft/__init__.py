from repro.ft.watchdog import (ElasticPlan, RestartPolicy, StragglerWatchdog,  # noqa: F401
                               plan_elastic_mesh)
