from repro.ft.watchdog import (ElasticPlan, RestartPolicy, StragglerWatchdog,  # noqa: F401
                               plan_elastic_mesh)
from repro.ft.inject import (InjectedCrash, arm_from_env, fault_point,  # noqa: F401
                             injected, register_points, registered_points)
