"""Deterministic fault injection: labeled crash points for the fleet
aggregation path (ISSUE 6).

Crash-tolerance claims are only as good as the schedule of crashes a
test can actually produce.  This module threads **labeled fault points**
through the daemon's stage/fold/commit path, the client's stage/send
path, and the merge commit itself (``repro.core.merge``), so a test can
kill either process at *every* point and assert the system invariant:
after any crash/restart/redelivery schedule, the final database is
byte-identical to a one-shot ``aggregate()`` over the union of
acknowledged shards (tests/test_fleet_crash.py sweeps the full matrix).

Usage::

    from repro.ft import inject

    inject.fault_point("daemon.fold.pre_merge")   # in production code

    with inject.injected("daemon.fold.pre_merge"):   # in a test
        with pytest.raises(inject.InjectedCrash):
            daemon.poll_once()

Two trigger modes:

- ``raise`` (default): raises ``InjectedCrash`` — a ``BaseException``
  subclass, so ordinary ``except Exception`` recovery code cannot
  swallow it.  The code under test must not clean up on the way out for
  this to model a real kill; the fleet modules are written that way
  (all crash-sensitive state lives on disk, committed by rename).
- ``exit``: ``os._exit(EXIT_CODE)`` — a genuine no-cleanup process
  death for subprocess tests and the CI chaos job.

Activation is either programmatic (``arm`` / ``injected``) or via the
environment (``arm_from_env``): ``REPRO_FAULT_POINTS`` is a
comma-separated list of ``label`` or ``label:N`` (trigger on the Nth
hit), or ``all`` (every registered point armed — the process dies at
the first one it reaches); ``REPRO_FAULT_MODE`` is ``raise`` or
``exit``.  The CI chaos job runs the fleet soak test with
``REPRO_FAULT_POINTS=all``.

Disabled cost: one falsy dict check per ``fault_point`` call.
"""
from __future__ import annotations

import contextlib
import os
import sys
from typing import Dict, Iterable, List, Optional, Tuple

ENV_POINTS = "REPRO_FAULT_POINTS"
ENV_MODE = "REPRO_FAULT_MODE"
EXIT_CODE = 86          # distinctive: "killed by an injected fault"

ALL = "all"


class InjectedCrash(BaseException):
    """An injected process death (``raise`` mode).

    Deliberately *not* an ``Exception``: recovery code that catches
    broad ``Exception`` (quarantine paths, retry loops) must not be able
    to absorb an injected crash — a real SIGKILL would not be caught
    either.
    """

    def __init__(self, label: str):
        super().__init__(f"injected crash at fault point {label!r}")
        self.label = label


# label -> remaining hits before triggering (1 = trigger on next hit)
_armed: Dict[str, int] = {}
_mode: str = "raise"
# every label any module ever declared (see register_points); "all" arms
# these.  Sorted views are what the crash-matrix tests sweep.
_registry: List[str] = []


def register_points(*labels: str) -> Tuple[str, ...]:
    """Declare fault-point labels (idempotent).  Modules call this at
    import time so tests and ``all`` can enumerate every point without
    executing the code paths first; returns the labels for re-export."""
    for lb in labels:
        if lb not in _registry:
            _registry.append(lb)
    return labels


def registered_points() -> List[str]:
    return sorted(_registry)


def fault_point(label: str) -> None:
    """A labeled crash point.  No-op unless armed for ``label``."""
    if not _armed:
        return
    left = _armed.get(label)
    if left is None:
        return
    if left > 1:
        _armed[label] = left - 1
        return
    del _armed[label]
    if _mode == "exit":
        sys.stderr.write(f"[inject] os._exit({EXIT_CODE}) at {label}\n")
        sys.stderr.flush()
        os._exit(EXIT_CODE)
    raise InjectedCrash(label)


def parse_spec(spec: str) -> Dict[str, int]:
    """``"a,b:3"`` -> ``{"a": 1, "b": 3}``; ``"all"`` -> every registered
    point at count 1."""
    plan: Dict[str, int] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if part == ALL:
            for lb in _registry:
                plan.setdefault(lb, 1)
            continue
        label, _, count = part.partition(":")
        n = int(count) if count else 1
        if n < 1:
            raise ValueError(f"fault spec {spec!r}: count must be >= 1")
        plan[label] = n
    return plan


def arm(spec: str, *, mode: str = "raise") -> None:
    """Arm fault points from a spec string (see ``parse_spec``)."""
    global _mode
    if mode not in ("raise", "exit"):
        raise ValueError(f"fault mode {mode!r}: expected raise|exit")
    _mode = mode
    _armed.clear()
    _armed.update(parse_spec(spec))


def clear() -> None:
    _armed.clear()


def armed() -> Dict[str, int]:
    return dict(_armed)


def arm_from_env(environ=os.environ) -> bool:
    """Arm from ``$REPRO_FAULT_POINTS`` / ``$REPRO_FAULT_MODE``; returns
    whether anything was armed.  Subprocess crash tests and the CI chaos
    job activate injection this way."""
    spec = environ.get(ENV_POINTS)
    if not spec:
        return False
    arm(spec, mode=environ.get(ENV_MODE, "raise"))
    return bool(_armed)


@contextlib.contextmanager
def injected(spec: str, *, mode: str = "raise"):
    """Arm for the duration of a ``with`` block, then disarm — the
    crash-matrix tests' idiom."""
    arm(spec, mode=mode)
    try:
        yield
    finally:
        clear()
