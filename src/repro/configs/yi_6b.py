"""Yi-6B: llama-architecture dense transformer with GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11_008,
    vocab=64_000,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652; hf",
))
