"""Config system for repro.

Every assigned architecture is a ``ModelConfig`` registered under its public id
(e.g. ``"qwen3-32b"``).  Configs are plain frozen dataclasses so they are
hashable (usable as jit static args) and trivially serializable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds — per-layer building blocks a model may stack.
# ---------------------------------------------------------------------------
ATTN = "attn"            # full causal attention (GQA)
SWA = "swa"              # sliding-window causal attention
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
HYBRID = "hybrid"        # parallel attention + mamba heads (Hymba)
MAMBA = "mamba"          # selective SSM block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert: bool = False  # llama4-style always-on shared expert
    moe_every: int = 1           # every Nth layer is MoE (llama4: 2)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int
    # --- block structure -----------------------------------------------
    block_pattern: Tuple[str, ...] = (ATTN,)   # tiled over n_layers
    window: int = 0             # sliding window size for SWA blocks
    # --- attention details ----------------------------------------------
    qk_norm: bool = False       # qwen3
    qkv_bias: bool = False      # qwen2
    rope_theta: float = 10_000.0
    # --- MoE --------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- SSM / recurrent ---------------------------------------------------
    ssm_state: int = 0          # mamba state size (hymba) / mlstm uses head_dim
    # --- modality frontend (stub): extra embedded inputs ------------------
    frontend: str = "none"      # none | vlm | audio
    frontend_tokens: int = 0    # number of stub embedding positions prepended
    # --- numerics ----------------------------------------------------------
    dtype: str = "bfloat16"
    # --- citation ----------------------------------------------------------
    source: str = ""

    @property
    def blocks(self) -> Tuple[str, ...]:
        """Per-layer block kinds, tiling block_pattern over n_layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def is_subquadratic(self) -> bool:
        """True if no block requires full quadratic attention."""
        return all(b != ATTN for b in self.blocks)

    @property
    def has_kv_cache(self) -> bool:
        return any(b in (ATTN, SWA, HYBRID) for b in self.blocks)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        qd = self.n_heads * self.head_dim
        kvd = self.n_kv_heads * self.head_dim
        total = v * d * 2  # embed + unembed (untied)
        for i, b in enumerate(self.blocks):
            if b in (ATTN, SWA):
                total += d * (qd + 2 * kvd) + qd * d          # qkv + o
                total += self._ffn_params(i)
            elif b == MLSTM:
                # up-proj 2x, qkv over inner dim, gates, down-proj
                inner = 2 * d
                total += d * inner * 2 + inner * d + 3 * inner * self.head_dim
            elif b == SLSTM:
                inner = d
                total += 4 * d * inner + inner * d + d * (4 * d) // 3
            elif b == MAMBA:
                inner = 2 * d
                total += d * inner * 2 + inner * d + inner * (2 * self.ssm_state + 2)
            elif b == HYBRID:
                total += d * (qd + 2 * kvd) + qd * d
                inner = qd  # mamba path sized like attention path
                total += d * inner * 2 + inner * d + inner * (2 * self.ssm_state + 2)
                total += self._ffn_params(i)
            total += 2 * d  # norms
        return total

    def moe_layers(self) -> Tuple[int, ...]:
        """Layer indices whose FFN is MoE."""
        if self.moe is None:
            return ()
        ev = self.moe.moe_every
        return tuple(i for i in range(self.n_layers)
                     if i % ev == ev - 1 and self.blocks[i] in (ATTN, SWA))

    def _ffn_params(self, layer: int = 0) -> int:
        d, f = self.d_model, self.d_ff
        if self.moe is not None and layer in self.moe_layers():
            e = self.moe.n_experts
            p = e * 3 * d * f + d * e  # experts (gated mlp) + router
            if self.moe.shared_expert:
                p += 3 * d * f
            return p
        if f == 0:
            return 0
        return 3 * d * f  # gated (swiglu) mlp

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        e, k = self.moe.n_experts, self.moe.top_k
        d, f = self.d_model, self.d_ff
        inactive = len(self.moe_layers()) * (e - k) * 3 * d * f
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k))
        return dataclasses.replace(
            self,
            n_layers=min(2, self.n_layers) if len(self.block_pattern) <= 2
            else len(self.block_pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(2, self.n_kv_heads) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            window=min(self.window, 64) if self.window else 0,
            frontend_tokens=min(self.frontend_tokens, 8),
            moe=moe,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes.  decode_*/long_* lower ``serve_step`` (one token against a KV
# cache of ``seq_len``); train_* lower ``train_step``; prefill_* lower the
# prefill half of ``serve_step``.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k requires sub-quadratic sequence mixing."""
    if shape.name == "long_500k":
        quad = [b for b in set(model.blocks) if b == ATTN]
        if quad:
            return False, ("SKIP: pure full-attention blocks are quadratic/"
                           "O(S) KV at 512k; per DESIGN.md only sub-quadratic "
                           "archs run long_500k")
    return True, ""


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def _load_all() -> None:
    # import side effect registers each config
    from repro.configs import (  # noqa: F401
        xlstm_125m, yi_6b, qwen2_1_5b, starcoder2_15b, qwen3_32b,
        llava_next_mistral_7b, llama4_maverick_400b_a17b,
        granite_moe_1b_a400m, musicgen_large, hymba_1_5b)
