"""StarCoder2-15B: dense GQA with RoPE [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24_576,
    vocab=49_152,
    head_dim=128,
    block_pattern=(ATTN,),
    qkv_bias=True,
    rope_theta=100_000.0,
    source="arXiv:2402.19173; hf",
))
