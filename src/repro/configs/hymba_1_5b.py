"""Hymba-1.5B: hybrid-head — parallel attention + mamba heads in every layer
[arXiv:2411.13676].

Attention path uses sliding-window attention (Hymba uses SWA in all but 3
layers; we model the SWA majority => sub-quadratic, long_500k runs).
ssm_state=16 for the mamba path.  25 q heads, GQA kv=5, head_dim=64.
"""
from repro.configs.base import ModelConfig, HYBRID, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    head_dim=64,
    block_pattern=(HYBRID,),
    window=1024,
    ssm_state=16,
    source="arXiv:2411.13676; hf",
))
