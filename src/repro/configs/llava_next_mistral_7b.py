"""LLaVA-NeXT (Mistral-7B backbone): VLM with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision frontend is a STUB per spec: ``input_specs()`` supplies
precomputed patch embeddings (anyres => up to 2880 patch positions) that the
backbone consumes alongside text tokens.
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=32_000,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=1_000_000.0,
    frontend="vlm",
    frontend_tokens=2880,  # anyres: 5 tiles x 576 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
