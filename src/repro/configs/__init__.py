from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, ShapeConfig, SHAPES,
    get_config, list_configs, shape_applicable,
    ATTN, SWA, MLSTM, SLSTM, HYBRID, MAMBA,
)
