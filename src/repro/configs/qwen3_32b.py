"""Qwen3-32B: dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25_600,
    vocab=151_936,
    head_dim=128,
    block_pattern=(ATTN,),
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B; hf",
))
