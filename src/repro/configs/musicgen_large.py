"""MusicGen-large: decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

The EnCodec frontend is a STUB per spec: ``input_specs()`` supplies
precomputed frame embeddings; the backbone is a plain decoder-only
transformer (kv=32 => full MHA) over vocab=2048 codebook entries.
"""
from repro.configs.base import ModelConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    block_pattern=(ATTN,),
    frontend="audio",
    frontend_tokens=0,  # frame embeddings replace token embeddings
    source="arXiv:2306.05284; hf",
))
