"""Granite-3.0 1B-A400M: MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ModelConfig, MoEConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49_155,
    head_dim=64,
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=32, top_k=8, capacity_factor=1.25),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
