"""xLSTM-125M: sLSTM + mLSTM blocks [arXiv:2405.04517].

12 layers, d_model=768, 4 heads, no FFN (xLSTM blocks carry their own
projections).  xLSTM[x:1]-style mix: every 6th layer is sLSTM (layers 5, 11),
the rest mLSTM.  GQA kv=4 applies to the mLSTM q/k/v heads.
Sub-quadratic (recurrent) => long_500k runs.
"""
from repro.configs.base import ModelConfig, MLSTM, SLSTM, register

CONFIG = register(ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    head_dim=192,
    block_pattern=(MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, SLSTM),
    source="arXiv:2405.04517; unverified",
))
