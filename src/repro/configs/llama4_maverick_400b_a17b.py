"""Llama-4 Maverick 400B-A17B: MoE 128 experts top-1 + shared expert,
early-fusion multimodal [hf:meta-llama/Llama-4-Scout-17B-16E family].

Early-fusion frontend is a STUB (precomputed patch embeddings via
``input_specs()``).  Every layer's FFN is MoE (128 routed top-1 + 1 shared).
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, capacity_factor=1.25,
                  shared_expert=True, moe_every=2),
    frontend="vlm",
    frontend_tokens=0,  # early fusion: image tokens share the text stream
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
