"""The always-on serving profiler (ISSUE 7 tentpole): one object a
serving process keeps next to its model.

Wraps the measurement ``Profiler`` with the three production layers:

- **windows** — ``request(rid, phase)`` stamps per-request/per-phase
  identities into every dispatch (repro.serving.window) and feeds the
  latency stats;
- **governor** — an ``OverheadGovernor`` throttles sampling fidelity to
  the configured overhead budget, fed per request by ``tick()``
  (repro.serving.governor), with fleet backpressure composed in;
- **telemetry** — a ``TelemetryExporter`` periodically ships
  epoch-tagged ``ServingStats`` snapshots through a ``ShardProducer``
  for exactly-once fleet aggregation (repro.serving.telemetry).

Minimal loop::

    sp = ServingProfiler(out_dir, producer=producer)
    with sp:
        for rid, prompt in requests:
            with sp.request(rid, "prefill", tokens=len(prompt)):
                with sp.profiler.dispatch("kernel", "prefill", ...):
                    ...
    print(sp.status())
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Union

from repro.core.profiler import Profiler
from repro.serving.governor import GovernorConfig, OverheadGovernor
from repro.serving.stats import ServingStats
from repro.serving.telemetry import TelemetryExporter
from repro.serving.window import DECODE, PREFILL, RequestWindow


class ServingProfiler:
    def __init__(self, out_dir: str, *,
                 governor: Union[bool, GovernorConfig] = True,
                 producer=None, export_every_s: float = 5.0,
                 stats_window_s: float = 60.0, rank: int = 0,
                 tag: Optional[str] = None, rng_seed: Optional[int] = 0,
                 wall: Callable[[], float] = time.monotonic,
                 **profiler_kwargs):
        self.profiler = Profiler(out_dir, tracing=True, rank=rank,
                                 tag=tag, rng_seed=rng_seed,
                                 **profiler_kwargs)
        self.stats = ServingStats(window_s=stats_window_s, clock=wall)
        self.governor: Optional[OverheadGovernor] = None
        if governor:
            cfg = governor if isinstance(governor, GovernorConfig) else None
            self.governor = OverheadGovernor(self.profiler, cfg)
        self.producer = producer
        self.exporter = (TelemetryExporter(producer, rank=rank)
                         if producer is not None else None)
        self.export_every_s = export_every_s
        self.wall = wall
        self._last_export = wall()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ServingProfiler":
        self.profiler.start()
        return self

    def stop(self) -> None:
        self.profiler.flush()
        self.profiler.stop()

    def __enter__(self) -> "ServingProfiler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def write(self):
        return self.profiler.write()

    # -- the per-request surface --------------------------------------------
    def request(self, request_id, phase: str, *, tokens: int = 0
                ) -> "_TrackedWindow":
        """A measurement window that also records latency/throughput and
        runs one governor/export tick on close."""
        return _TrackedWindow(self, request_id, phase, tokens)

    def tick(self) -> None:
        """One cheap control step: poll backpressure into the governor,
        run a governor observation, export telemetry when due.  Called
        automatically when a ``request()`` window closes; long-running
        loops without windows may call it directly."""
        if self.producer is not None:
            poll = getattr(self.producer, "poll_backpressure", None)
            if poll is not None:
                poll()
            if self.governor is not None:
                self.governor.note_backpressure(self.producer.throttled)
        if self.governor is not None:
            # SLO feed: the worst current rolling p99 across phases (0.0
            # — no requests in the window yet — means no signal)
            p99 = max(self.stats.percentile_ms(PREFILL, 99),
                      self.stats.percentile_ms(DECODE, 99))
            self.governor.observe(p99_ms=p99 if p99 > 0 else None)
        if self.exporter is not None and \
                self.wall() - self._last_export >= self.export_every_s:
            self.export_now()

    def export_now(self) -> Optional[str]:
        """Export one telemetry epoch immediately; returns the shard id
        (None without a producer)."""
        if self.exporter is None:
            return None
        self._last_export = self.wall()
        return self.exporter.export(self.status())

    # -- the status surface -------------------------------------------------
    def status(self) -> dict:
        """The live health snapshot (ServingStats columns + governor
        state + export progress)."""
        snap = self.stats.snapshot(governor=self.governor,
                                   profiler=self.profiler,
                                   producer=self.producer)
        snap["epochs_exported"] = float(
            self.exporter.exported if self.exporter else 0)
        return snap


class _TrackedWindow(RequestWindow):
    """RequestWindow that reports into the owning ServingProfiler."""

    def __init__(self, owner: ServingProfiler, request_id, phase: str,
                 tokens: int):
        super().__init__(owner.profiler, request_id, phase)
        self._owner = owner
        self.tokens = tokens

    def __exit__(self, *exc) -> None:
        super().__exit__(*exc)
        self._owner.stats.record_window(self, tokens=self.tokens)
        self._owner.tick()
