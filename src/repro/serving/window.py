"""Per-request / per-phase measurement windows (ISSUE 7 tentpole).

The paper's always-on claim (§4, §8.1) only pays off in production if
the measurement can answer *which request burned the GPU*.  A
``RequestWindow`` stamps ``request:<id>`` and ``phase:<prefill|decode>``
frames into every dispatch issued while it is open — riding
``Profiler.window``, which splices the frames between the unwound host
stack and the dispatch placeholder.  The window identities are ordinary
host frames, so they survive the canonical-database contract unchanged:
aggregation, ``merge_databases``, retention, and the fleet fold all see
per-request contexts as plain tree paths (byte-deterministic; pinned in
tests/test_serving.py), and ``traceview.stats.request_attribution``
reads them back out of any database or trace window.

Frame scheme (docs/serving.md)::

    ... host stack ... -> request:<id> -> phase:<phase> -> <placeholder>

with ``module="<serving>"`` marking window frames unambiguously.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.cct import Frame, HOST

WINDOW_MODULE = "<serving>"
REQUEST_PREFIX = "request:"
PHASE_PREFIX = "phase:"

PREFILL = "prefill"
DECODE = "decode"


def request_frames(request_id: str, phase: Optional[str] = None
                   ) -> Tuple[Frame, ...]:
    """The window frames for one request (+ optional phase), in the
    order they nest in the CCT."""
    frames = [Frame(HOST, f"{REQUEST_PREFIX}{request_id}",
                    WINDOW_MODULE, 0)]
    if phase is not None:
        frames.append(Frame(HOST, f"{PHASE_PREFIX}{phase}",
                            WINDOW_MODULE, 0))
    return tuple(frames)


def window_label(frame) -> Tuple[Optional[str], Optional[str]]:
    """Decode one frame back into ``(request_id, phase)`` — exactly one
    side is non-None for a window frame, both None otherwise."""
    if getattr(frame, "module", None) != WINDOW_MODULE:
        return None, None
    name = frame.name
    if name.startswith(REQUEST_PREFIX):
        return name[len(REQUEST_PREFIX):], None
    if name.startswith(PHASE_PREFIX):
        return None, name[len(PHASE_PREFIX):]
    return None, None


class RequestWindow:
    """Context manager: every dispatch (and cpu_region) issued inside is
    attributed to ``request_id``/``phase``, and the wall-clock span of
    the window is captured for latency percentiles::

        with RequestWindow(prof, "r42", phase="decode") as w:
            with prof.dispatch("kernel", "decode_step", ...):
                ...
        latency_ns = w.duration_ns

    **Continuous batching** (overlapping windows): the ``with`` form
    splices the window frames for its whole dynamic extent, which
    assumes the thread works for exactly one request at a time.  A
    continuous-batching server interleaves decode steps of many live
    requests on one scheduler thread, so whole-extent splicing would
    attribute every interleaved dispatch to whichever window opened
    last (and double-count once both close).  For that shape, keep the
    window open across the request's lifetime with ``open()``/
    ``close()`` (span timing only — no frame splicing) and stamp each
    dispatch explicitly::

        w1, w2 = (RequestWindow(prof, r, phase="decode").open()
                  for r in ("r1", "r2"))
        with w1.step():                      # this dispatch is r1's
            with prof.dispatch(...): ...
        with w2.step():                      # interleaved: r2's
            with prof.dispatch(...): ...
        w1.close(); w2.close()

    ``step()`` uses ``Profiler.window_exclusive``: it *replaces* the
    thread's window stack for the body, so each dispatch carries exactly
    one request identity no matter how many windows are live —
    ``request_attribution`` sums to the partition total with no double
    counting (pinned in tests/test_serving.py).
    """

    def __init__(self, profiler, request_id, phase: Optional[str] = None):
        self.profiler = profiler
        self.request_id = str(request_id)
        self.phase = phase
        self.t0_ns: Optional[int] = None
        self.t1_ns: Optional[int] = None
        self._cm = None

    @property
    def duration_ns(self) -> int:
        if self.t0_ns is None or self.t1_ns is None:
            return 0
        return self.t1_ns - self.t0_ns

    def __enter__(self) -> "RequestWindow":
        self._cm = self.profiler.window(
            *request_frames(self.request_id, self.phase))
        self._cm.__enter__()
        self.t0_ns = self.profiler.clock()
        return self

    def __exit__(self, *exc) -> None:
        self.t1_ns = self.profiler.clock()
        self._cm.__exit__(*exc)
        self._cm = None

    # -- continuous-batching API (overlapping windows) --------------------
    def open(self) -> "RequestWindow":
        """Start the request's wall-clock span without splicing frames —
        safe to hold open concurrently with other requests' windows."""
        self.t0_ns = self.profiler.clock()
        return self

    def close(self) -> "RequestWindow":
        """End the wall-clock span (latency = ``duration_ns``)."""
        self.t1_ns = self.profiler.clock()
        return self

    def step(self, phase: Optional[str] = None):
        """Per-dispatch stamping: a context manager that attributes
        exactly the dispatches in its body to this request (replacing,
        not nesting under, any other live window's frames).  ``phase``
        overrides the window's phase for this step (e.g. a request whose
        prefill and decode interleave with other requests)."""
        return self.profiler.window_exclusive(
            *request_frames(self.request_id,
                            phase if phase is not None else self.phase))
