"""Live telemetry export: serving snapshots as fleet shards (ISSUE 7).

Each export packages one ``ServingStats.snapshot()`` as a tiny,
perfectly ordinary profile database — a one-node CCT carrying a
dedicated ``serving`` metric kind — tagged with a monotonically
increasing epoch, and stages it through the existing ``ShardProducer``.
Nothing new on the wire: envelopes are content-addressed, the daemon's
journal dedups them, so live telemetry inherits the fleet tier's
exactly-once ingest *for free*, and the fleet database doubles as a
queryable time series (``read_telemetry``).

The telemetry registry is intentionally separate from the measurement
``default_registry()``: telemetry shards fold into their *own* fleet
database (the daemon's metric-taxonomy gate would rightly quarantine a
serving shard folded into a kernel-measurement database).
"""
from __future__ import annotations

import os
import shutil
import socket
import tempfile
from typing import Dict, List, Optional

from repro.core.cct import CCT, Frame, HOST
from repro.core.metrics import MetricRegistry
from repro.core.profmt import write_profile

SERVING_KIND = "serving"
# fixed column order: every telemetry shard agrees, so the daemon's
# taxonomy gate admits them all into one fleet database
SERVING_METRICS = (
    "requests", "tokens", "tok_s",
    "prefill_p50_ms", "prefill_p99_ms",
    "decode_p50_ms", "decode_p99_ms",
    "overhead_frac", "governor_level",
    "samples_kept", "samples_dropped",
    "spool_depth", "throttled",
)

TAG_PREFIX = "telemetry_e"
TELEMETRY_CTX = "serving_telemetry"


def telemetry_registry() -> MetricRegistry:
    reg = MetricRegistry()
    reg.register_kind(SERVING_KIND, SERVING_METRICS)
    return reg


class TelemetryExporter:
    """Turns snapshots into epoch-tagged shard envelopes.

    ``export()`` never raises into the serving loop for delivery
    problems — the producer's sacrificial contract (bounded outbox,
    backoff, drop-oldest) already covers every failure mode; staging
    itself is local disk I/O on a few KB.
    """

    def __init__(self, producer, *, host: Optional[str] = None,
                 rank: int = 0, deliver: bool = True):
        self.producer = producer
        self.host = host or socket.gethostname()
        self.rank = rank
        self.deliver = deliver
        self.epoch = 0
        self.exported = 0

    def identity(self, epoch: int) -> Dict[str, object]:
        return {"host": self.host, "rank": self.rank, "thread": 0,
                "type": "cpu", "tag": f"{TAG_PREFIX}{epoch:08d}"}

    def shard_id(self, epoch: int) -> str:
        """Deterministic per-epoch shard id: at most one telemetry shard
        per (host, rank, epoch) ever folds.  A redelivered envelope
        dedups as a journal no-op; a *re-exported* epoch (same id, new
        payload bytes) is a journal conflict and quarantines visibly —
        either way the time series stays exactly-once."""
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in self.host)
        return f"telemetry-{safe}-r{self.rank}-e{epoch:08d}"

    def export(self, snapshot: Dict[str, float],
               epoch: Optional[int] = None) -> str:
        """Package ``snapshot`` as epoch ``epoch`` (default: next) and
        stage it into the producer's outbox; returns the shard id."""
        from repro.core.aggregate import aggregate

        if epoch is None:
            epoch = self.epoch
        reg = telemetry_registry()
        kind = reg.kind(SERVING_KIND)
        cct = CCT()
        node = cct.insert_path([Frame(HOST, TELEMETRY_CTX,
                                      "<telemetry>", 0)])
        for metric in SERVING_METRICS:
            value = float(snapshot.get(metric, 0.0))
            if value:
                node.metrics.add(kind, metric, value)
        tmp = tempfile.mkdtemp(prefix="repro_telemetry_")
        try:
            prof = os.path.join(tmp, f"telemetry_r{self.rank}.rpro")
            write_profile(prof, cct, reg, self.identity(epoch))
            db_dir = os.path.join(tmp, "db")
            aggregate([prof], db_dir, n_ranks=1, n_threads=1,
                      trace_db=False, driver="serial")
            sid = self.producer.stage(db_dir, epoch=epoch,
                                      shard_id=self.shard_id(epoch),
                                      meta={"kind": "serving_telemetry",
                                            "host": self.host,
                                            "rank": self.rank})
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        self.epoch = epoch + 1
        self.exported += 1
        if self.deliver:
            self.producer.deliver()
        return sid


def read_telemetry(db) -> List[Dict[str, float]]:
    """The fleet database as a telemetry time series: one row per
    exported epoch (sorted), each a dict of ``SERVING_METRICS`` plus
    ``epoch``/``host``/``rank``.  Works on any ``Database`` whose
    profiles carry ``telemetry_e*`` tags — the daemon's fleet db, a
    merged shard, or a local aggregate."""
    from repro.core.sparse import PMSReader

    rows: List[Dict[str, float]] = []
    if not db.profile_ids:
        return rows
    reader = PMSReader(db.pms_path())
    for pid, ident in sorted(db.profile_ids.items()):
        tag = str(ident.get("tag", ""))
        if not tag.startswith(TAG_PREFIX):
            continue
        row: Dict[str, float] = {m: 0.0 for m in SERVING_METRICS}
        row["epoch"] = float(int(tag[len(TAG_PREFIX):]))
        row["host"] = ident.get("host", "")
        row["rank"] = float(ident.get("rank", 0))
        pv = reader.profile_values(int(pid))
        if pv is not None:
            for ctx, mid, val in zip(pv.ctx, pv.metric, pv.values):
                if ctx != 0:        # root holds the inclusive totals
                    continue
                name = db.metrics[int(mid)]
                if name.startswith(SERVING_KIND + "/"):
                    row[name.split("/", 1)[1]] = float(val)
        rows.append(row)
    rows.sort(key=lambda r: (r["host"], r["rank"], r["epoch"]))
    return rows
