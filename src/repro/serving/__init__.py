"""Always-on serving profiler (ISSUE 7): per-request attribution,
overhead-budgeted adaptive sampling, live telemetry export.

- windows (``RequestWindow``): request/phase identity frames in the CCT
- governor (``OverheadGovernor``): fidelity throttled to a budget
- stats (``ServingStats``): rolling latency/throughput/overhead window
- telemetry (``TelemetryExporter``): snapshots as epoch-tagged fleet
  shards, exactly-once through the existing envelope/journal machinery
- live (``ServingProfiler``): the facade serving loops hold
- sweep: model-zoo scenario sweep (dense/MoE/SSM x prefill/decode-heavy)

See docs/serving.md.
"""
from repro.serving.governor import (  # noqa: F401
    Decision, GovernorConfig, GovernorLevel, LEVELS, OverheadGovernor,
)
from repro.serving.live import ServingProfiler  # noqa: F401
from repro.serving.stats import ServingStats  # noqa: F401
from repro.serving.telemetry import (  # noqa: F401
    SERVING_KIND, SERVING_METRICS, TelemetryExporter, read_telemetry,
    telemetry_registry,
)
from repro.serving.window import (  # noqa: F401
    DECODE, PREFILL, RequestWindow, request_frames, window_label,
)
