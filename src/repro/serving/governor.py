"""Overhead-budgeted adaptive sampling (ISSUE 7 tentpole).

The paper's worst-case overhead (§8.1: 1.85x-2.24x) is the *unthrottled*
figure; always-on production profiling needs the tool to measure its own
dispatch-path cost and throttle itself to a budget.  The profiler
already self-accounts (``Profiler.overhead_counters``: tool ns vs app ns
per dispatch); the governor closes the loop.

Control law (docs/serving.md):

- fidelity is a discrete ladder of ``GovernorLevel``s, from full
  measurement (deep unwinds, unthrottled PC sampling) down to a *floor*
  that still measures every dispatch (coarse timing + tracing + one PC
  sample) — measurement is **never fully disabled**;
- every ``interval`` dispatches the governor reads the overhead of the
  window just passed: ``(tool_ns + deferred_ns) / app_ns``.  With the
  wait-free dispatch path the PC-sample draw and attribution run on the
  monitor thread (``deferred_ns``), not on the dispatch path
  (``tool_ns``) — but they still burn a core, so the budget governs the
  tool's *total* measurement cost, and the sampling knobs still have a
  signal to act on.  Over budget -> step one level down (less fidelity)
  immediately.  Under ``budget * headroom`` for ``patience`` consecutive
  windows -> step one level up (hysteresis, so the controller doesn't
  hunt on noise);
- **SLO shed**: ``observe(p99_ms=...)`` optionally carries the serving
  loop's rolling p99 latency.  The governor keeps an EMA baseline of it
  (``slo_alpha``); a window whose p99 exceeds the baseline by more than
  ``slo_degradation`` (fractional) sheds one level even when the
  overhead budget is met — measurement cost that doesn't show up in
  tool/app (cache pressure, monitor-core contention) still shows up in
  tail latency.  Fidelity never rises while degraded, and the baseline
  only learns from non-degraded windows (the incident doesn't poison
  the reference);
- fleet backpressure composes: while ``note_backpressure(True)`` is in
  effect (the ShardProducer's ``throttled`` flag, fed by the daemon's
  spool depth), the governor will not raise fidelity and steps down one
  extra level — a deep aggregation spool means the fleet wants *less*
  telemetry, not more.

Levels mutate only the profiler's runtime knobs (``sample_scale``,
``sample_cap``, ``unwind_depth``) — no restart, no data loss, and the
knobs are read per dispatch so a decision takes effect on the very next
one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class GovernorLevel:
    """One rung of the fidelity ladder."""
    name: str
    sample_scale: float            # multiplies Profiler.sample_rate_hz
    sample_cap: Optional[int]      # max PC samples per dispatch
    unwind_depth: int              # host unwind depth (0 = <app> frame)


# Fidelity ladder, full -> floor.  The floor still times and traces
# every dispatch and draws one PC sample (the sample budget never
# rounds below one) — the "never off" contract.
#
# Rung costs, re-tuned for the wait-free dispatch path: sample_scale /
# sample_cap shed *monitor-side* cost (the deferred draw + attribution,
# the dominant term), while unwind_depth trims the dispatch-side
# context-memo key walk — cheap once cached, so the middle rungs keep
# deeper unwinds than they used to and lean on tighter caps instead.
LEVELS: Tuple[GovernorLevel, ...] = (
    GovernorLevel("full", 1.0, None, 64),
    GovernorLevel("sampled-1/4", 0.25, 2048, 64),
    GovernorLevel("sampled-1/16", 1.0 / 16, 512, 32),
    GovernorLevel("sampled-1/64", 1.0 / 64, 64, 16),
    GovernorLevel("coarse", 0.0, 1, 0),
)


@dataclasses.dataclass
class GovernorConfig:
    budget: float = 0.05        # max (tool+deferred) ns / app ns
    headroom: float = 0.5       # raise fidelity only below budget*headroom
    interval: int = 64          # dispatches per control window
    patience: int = 3           # consecutive low windows before stepping up
    start_level: int = 0
    slo_degradation: float = 0.5   # shed when p99 > baseline * (1 + this)
    slo_alpha: float = 0.2         # EMA weight for the p99 baseline

    def __post_init__(self):
        if not 0 < self.budget:
            raise ValueError("budget must be positive")
        if not 0 <= self.headroom <= 1:
            raise ValueError("headroom must be in [0, 1]")
        if self.interval < 1 or self.patience < 1:
            raise ValueError("interval and patience must be >= 1")
        if not self.slo_degradation > 0:
            raise ValueError("slo_degradation must be positive")
        if not 0 < self.slo_alpha <= 1:
            raise ValueError("slo_alpha must be in (0, 1]")


@dataclasses.dataclass
class Decision:
    """One control decision (the ``history`` record tests pin)."""
    dispatches: int             # cumulative dispatch count at decision
    overhead: float             # tool/app over the window just closed
    level: int                  # level in effect AFTER the decision


class OverheadGovernor:
    """Feedback controller keeping the profiler's measured dispatch
    overhead under ``config.budget`` by walking the ``LEVELS`` ladder.

    ``observe()`` is designed to be called once per dispatch (or per
    request) from the serving loop — it is a counter compare until a
    control window of ``interval`` dispatches has passed, then one
    decision.  The governor holds no timing state of its own; the
    profiler's cumulative counters are the single source of truth, so
    any number of observers stay consistent.
    """

    def __init__(self, profiler, config: Optional[GovernorConfig] = None,
                 levels: Tuple[GovernorLevel, ...] = LEVELS):
        if not levels:
            raise ValueError("need at least one governor level")
        self.profiler = profiler
        self.config = config or GovernorConfig()
        self.levels = tuple(levels)
        self.level = min(self.config.start_level, len(self.levels) - 1)
        self.history: List[Decision] = []
        self.backpressured = False
        self.throttle_downs = 0
        self.throttle_ups = 0
        self._low_streak = 0
        self._last = dict(profiler.overhead_counters())
        self.slo_baseline_ms: Optional[float] = None
        self.slo_degraded = False
        self.slo_sheds = 0
        self._apply()

    # -- knob application ---------------------------------------------------
    def _apply(self) -> None:
        lv = self.levels[self.level]
        self.profiler.sample_scale = lv.sample_scale
        self.profiler.sample_cap = lv.sample_cap
        self.profiler.unwind_depth = lv.unwind_depth

    def _step(self, delta: int) -> None:
        new = min(max(self.level + delta, 0), len(self.levels) - 1)
        if new != self.level:
            if delta > 0:
                self.throttle_downs += 1
            else:
                self.throttle_ups += 1
            self.level = new
            self._apply()

    # -- feedback -----------------------------------------------------------
    def note_backpressure(self, throttled: bool) -> None:
        """Feed the fleet's backpressure signal (ShardProducer.throttled,
        itself fed by FleetDaemon spool depth).  Taking effect at the
        next decision: never raise fidelity while backpressured, and
        shed one extra level on the transition to throttled."""
        if throttled and not self.backpressured:
            self._step(+1)
        self.backpressured = bool(throttled)

    @staticmethod
    def _tool_total(c: dict) -> int:
        # dispatch-path cost + the monitor-side deferred draw/attribution
        # cost (absent from stub profilers that predate deferral)
        return c["tool_ns"] + c.get("deferred_ns", 0)

    def overhead(self) -> float:
        """Cumulative measured tool overhead, (tool + deferred)/app."""
        c = self.profiler.overhead_counters()
        return self._tool_total(c) / max(c["app_ns"], 1)

    def _slo_check(self, p99_ms: Optional[float]) -> bool:
        """Update the SLO state for one closed window; True = degraded."""
        if p99_ms is None or p99_ms <= 0:
            # no latency signal this window: keep the baseline, and a
            # prior degraded verdict stands until a healthy p99 clears it
            return self.slo_degraded
        cfg = self.config
        base = self.slo_baseline_ms
        if base is not None and p99_ms > base * (1.0 + cfg.slo_degradation):
            self.slo_degraded = True
            return True
        self.slo_degraded = False
        # learn only from non-degraded windows
        self.slo_baseline_ms = p99_ms if base is None else \
            (1.0 - cfg.slo_alpha) * base + cfg.slo_alpha * p99_ms
        return False

    def observe(self, p99_ms: Optional[float] = None) -> Optional[Decision]:
        """One control step; returns the Decision when a window closed
        (every ``config.interval`` dispatches), else None.

        ``p99_ms``: the serving loop's current rolling p99 latency
        (ServingStats), when it has one — the SLO-shed input."""
        counters = self.profiler.overhead_counters()
        dn = counters["dispatches"] - self._last["dispatches"]
        if dn < self.config.interval:
            return None
        tool = self._tool_total(counters) - self._tool_total(self._last)
        app = counters["app_ns"] - self._last["app_ns"]
        self._last = dict(counters)
        overhead = tool / max(app, 1)
        cfg = self.config
        degraded = self._slo_check(p99_ms)
        if degraded:
            # tail latency blew past the rolling baseline: shed even
            # under budget, and reset the step-up streak
            self._low_streak = 0
            self.slo_sheds += 1
            self._step(+1)
        elif overhead > cfg.budget:
            self._low_streak = 0
            self._step(+1)
        elif overhead < cfg.budget * cfg.headroom and not self.backpressured:
            self._low_streak += 1
            if self._low_streak >= cfg.patience:
                self._low_streak = 0
                self._step(-1)
        else:
            self._low_streak = 0
        decision = Decision(counters["dispatches"], overhead, self.level)
        self.history.append(decision)
        return decision

    # -- introspection ------------------------------------------------------
    def state(self) -> dict:
        """Live governor state for ``ServingStats``/telemetry export."""
        last = self.history[-1] if self.history else None
        return {
            "level": self.level,
            "level_name": self.levels[self.level].name,
            "n_levels": len(self.levels),
            "budget": self.config.budget,
            "overhead": last.overhead if last else 0.0,
            "overhead_total": self.overhead(),
            "decisions": len(self.history),
            "throttle_downs": self.throttle_downs,
            "throttle_ups": self.throttle_ups,
            "backpressured": self.backpressured,
            "slo_baseline_ms": self.slo_baseline_ms or 0.0,
            "slo_degraded": self.slo_degraded,
            "slo_sheds": self.slo_sheds,
        }
