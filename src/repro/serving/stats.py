"""Rolling-window live serving telemetry (ISSUE 7 tentpole).

``ServingStats`` is the in-process view of a serving host's health:
request/phase latency percentiles, token throughput, the profiler's
measured overhead, the governor's throttle state, and the fleet
producer's backpressure — everything ``status()`` surfaces and the
``TelemetryExporter`` ships as epoch-tagged shards.

The window is time-based (default 60s of requests, bounded by
``maxlen``): ``record()`` is O(1), snapshots prune lazily.  All numbers
are plain floats so a snapshot serializes straight into the fixed
``SERVING_METRICS`` telemetry columns (repro.serving.telemetry).
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.window import DECODE, PREFILL

# (wall_s, request_id, phase, duration_ns, tokens)
_Row = Tuple[float, str, str, int, int]


class ServingStats:
    """Rolling window over per-request phase records."""

    def __init__(self, *, window_s: float = 60.0, maxlen: int = 8192,
                 clock: Callable[[], float] = time.monotonic):
        self.window_s = float(window_s)
        self.clock = clock
        self._rows: Deque[_Row] = collections.deque(maxlen=maxlen)
        self.total_requests = 0
        self.total_tokens = 0

    # -- ingestion ----------------------------------------------------------
    def record(self, request_id, phase: str, duration_ns: int,
               tokens: int = 0) -> None:
        self._rows.append((self.clock(), str(request_id), str(phase),
                           int(duration_ns), int(tokens)))
        if phase == PREFILL:
            self.total_requests += 1
        self.total_tokens += int(tokens)

    def record_window(self, window, tokens: int = 0) -> None:
        """Record a closed ``RequestWindow`` directly."""
        self.record(window.request_id, window.phase or "serve",
                    window.duration_ns, tokens)

    # -- the window ---------------------------------------------------------
    def _live(self) -> List[_Row]:
        cutoff = self.clock() - self.window_s
        while self._rows and self._rows[0][0] < cutoff:
            self._rows.popleft()
        return list(self._rows)

    def latencies_ns(self, phase: str) -> np.ndarray:
        return np.asarray([r[3] for r in self._live() if r[2] == phase],
                          np.int64)

    def percentile_ms(self, phase: str, q: float) -> float:
        lat = self.latencies_ns(phase)
        if not len(lat):
            return 0.0
        return float(np.percentile(lat, q)) / 1e6

    def tok_s(self) -> float:
        rows = self._live()
        if not rows:
            return 0.0
        tokens = sum(r[4] for r in rows)
        span = max(rows[-1][0] - rows[0][0], 1e-9)
        # a single-record window has no span; fall back to its duration
        if len(rows) == 1:
            span = max(rows[0][3] / 1e9, 1e-9)
        return tokens / span

    def requests_in_window(self) -> int:
        return len({r[1] for r in self._live()})

    # -- the status surface -------------------------------------------------
    def snapshot(self, *, governor=None, profiler=None, producer=None
                 ) -> Dict[str, float]:
        """One flat numeric snapshot — the ``status()`` payload and the
        telemetry shard row.  Keys match ``SERVING_METRICS`` (plus a few
        extras ``status()`` shows but telemetry need not ship)."""
        snap = {
            "requests": float(self.requests_in_window()),
            "tokens": float(sum(r[4] for r in self._live())),
            "tok_s": self.tok_s(),
            "prefill_p50_ms": self.percentile_ms(PREFILL, 50),
            "prefill_p99_ms": self.percentile_ms(PREFILL, 99),
            "decode_p50_ms": self.percentile_ms(DECODE, 50),
            "decode_p99_ms": self.percentile_ms(DECODE, 99),
            "overhead_frac": 0.0,
            "governor_level": 0.0,
            "samples_kept": 0.0,
            "samples_dropped": 0.0,
            "spool_depth": 0.0,
            "throttled": 0.0,
        }
        if profiler is not None:
            c = profiler.overhead_counters()
            snap["overhead_frac"] = c["tool_ns"] / max(c["app_ns"], 1)
            snap["samples_kept"] = float(c["samples_kept"])
            snap["samples_dropped"] = float(c["samples_dropped"])
        if governor is not None:
            st = governor.state()
            snap["governor_level"] = float(st["level"])
            snap["overhead_frac"] = st["overhead_total"]
        if producer is not None:
            snap["throttled"] = 1.0 if producer.throttled else 0.0
            depth = getattr(producer, "daemon_spool_depth", None)
            if depth is not None:
                snap["spool_depth"] = float(depth)
        return snap
