"""Serving-scenario sweep over the model zoo (ISSUE 7 tentpole).

One scenario = one architecture family (dense transformer / MoE / SSM)
x one traffic mix (prefill-heavy long prompts vs decode-heavy long
generations), served through the full always-on stack: per-request
windows, the overhead governor, live stats.  Each run is aggregated and
the sweep reports what the tentpole promises the operator — per-request
GPU attribution and phase latency percentiles straight out of the
database/trace, alongside the governor's steady state.

CLI::

    python -m repro.serving.sweep --small --out /tmp/sweep
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Optional, Tuple

from repro.serving.governor import GovernorConfig
from repro.serving.live import ServingProfiler


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    arch: str
    prompt_len: int
    gen_len: int

    @property
    def family(self) -> str:
        return self.name.split("-", 1)[0]

    @property
    def mix(self) -> str:
        return ("prefill-heavy" if self.prompt_len >= 4 * self.gen_len
                else "decode-heavy")


SCENARIOS: Tuple[Scenario, ...] = (
    Scenario("dense-prefill", "qwen2-1.5b", 64, 4),
    Scenario("dense-decode", "qwen2-1.5b", 8, 24),
    Scenario("moe-prefill", "granite-moe-1b-a400m", 64, 4),
    Scenario("moe-decode", "granite-moe-1b-a400m", 8, 24),
    Scenario("ssm-prefill", "xlstm-125m", 64, 4),
    Scenario("ssm-decode", "xlstm-125m", 8, 24),
)


def run_scenario(scn: Scenario, out_dir: str, *, n_requests: int = 4,
                 batch: int = 2, small: bool = False, budget: float = 0.5,
                 producer=None) -> dict:
    """Serve one scenario end to end; returns the report row."""
    from repro.configs import get_config
    from repro.core.aggregate import aggregate
    from repro.launch.serve import serve
    from repro.traceview.tracedb import TraceDB
    from repro.traceview.stats import (request_attribution,
                                       request_latency_percentiles)

    cfg = get_config(scn.arch).reduced()
    prompt = min(scn.prompt_len, 16) if small else scn.prompt_len
    gen = min(scn.gen_len, 6) if small else scn.gen_len
    os.makedirs(out_dir, exist_ok=True)
    sp = ServingProfiler(out_dir,
                         governor=GovernorConfig(budget=budget, interval=4),
                         producer=producer)
    sp.start()
    serve(cfg, n_requests=n_requests, batch=batch, prompt_len=prompt,
          gen_len=gen, serving=sp)
    sp.profiler.flush()
    paths = sp.write()
    status = sp.status()
    governor = sp.governor.state() if sp.governor else {}
    sp.stop()

    profs = [v for k, v in sorted(paths.items()) if "trace" not in k]
    traces = [v for k, v in sorted(paths.items()) if "trace" in k]
    db = aggregate(profs, os.path.join(out_dir, "db"), n_ranks=1,
                   n_threads=1, trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    attribution = [
        {"request": rid, "total_ns": total,
         "by_phase": {p: ns for p, ns in by.items()}}
        for rid, total, by in request_attribution(lines, db)]
    percentiles = request_latency_percentiles(lines, db)
    return {
        "scenario": scn.name, "arch": scn.arch, "family": scn.family,
        "mix": scn.mix, "prompt_len": prompt, "gen_len": gen,
        "status": status, "governor": governor,
        "attribution": attribution,
        "trace_latency_ms": {p: {str(int(q)): v for q, v in d.items()}
                             for p, d in percentiles.items()},
    }


def run_sweep(out_root: str, *, scenarios=SCENARIOS, small: bool = False,
              n_requests: int = 4, batch: int = 2,
              budget: float = 0.5) -> list:
    rows = []
    for scn in scenarios:
        row = run_scenario(scn, os.path.join(out_root, scn.name),
                           n_requests=n_requests, batch=batch,
                           small=small, budget=budget)
        rows.append(row)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/repro_serving_sweep")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--budget", type=float, default=0.5)
    ap.add_argument("--families", default=None,
                    help="comma list: dense,moe,ssm (default all)")
    args = ap.parse_args(argv)
    scns = SCENARIOS
    if args.families:
        keep = set(args.families.split(","))
        scns = tuple(s for s in scns if s.family in keep)
    rows = run_sweep(args.out, scenarios=scns, small=args.small,
                     n_requests=args.requests, batch=args.batch,
                     budget=args.budget)
    for row in rows:
        st = row["status"]
        top = row["attribution"][0]["request"] if row["attribution"] else "-"
        print(f"{row['scenario']:>16} {row['mix']:>13} "
              f"tok/s={st['tok_s']:8.1f} "
              f"prefill_p50={st['prefill_p50_ms']:7.2f}ms "
              f"decode_p50={st['decode_p50_ms']:7.2f}ms "
              f"overhead={st['overhead_frac']:.3f} "
              f"level={row['governor'].get('level_name', '-')} "
              f"top_request={top}")
    with open(os.path.join(args.out, "sweep.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("report:", os.path.join(args.out, "sweep.json"))


if __name__ == "__main__":
    main()
