"""Mixture-of-Experts FFN with expert parallelism over the ``model`` mesh
axis, written with shard_map + explicit collectives.

Design (see DESIGN.md §5): activations enter replicated over ``model`` (the
attention block's row-parallel output is all-reduced), so each model shard
sees every local-data token.  Shard ``i`` owns experts
[i*E_loc, (i+1)*E_loc); it routes its local tokens, keeps only slots bound
for its own experts, runs the expert FFN over a capacity-bounded dispatch
buffer, scatters results back, and a single psum over ``model`` merges the
shards — the same collective a row-parallel dense FFN would need, i.e. EP
costs no extra collective versus TP.  Expert weights are FSDP-sharded over
``data`` on the d_model dim and all-gathered just-in-time (explicit
overlap-friendly FSDP).

Tokens routed beyond an expert's capacity C = top_k * T_loc / E * cf are
dropped (standard Switch/GShard semantics); the aux load-balance loss keeps
the router near-uniform.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


class MoEMeshArgs(NamedTuple):
    mesh: object          # jax.sharding.Mesh
    dp_axes: tuple        # axes the batch is sharded over, e.g. ("pod","data")
    fsdp_axis: Optional[str]   # axis expert weights' d_model dim is sharded on
    model_axis: str       # expert-parallel axis
    # "gather": FSDP weights, all-gather per invocation (amortizes when the
    #   token batch is large — training).
    # "stationary": weights stay resident with the ffn-hidden dim sharded
    #   over fsdp_axis; the (small) token batch is all-gathered instead and
    #   partial expert outputs are psum'd — decode/serving wins (§Perf B).
    weight_mode: str = "gather"


def init_moe_params(key, d_model: int, d_ff: int, n_experts: int, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w1": dense_init(ks[1], (n_experts, d_model, d_ff), dtype),
        "w3": dense_init(ks[2], (n_experts, d_model, d_ff), dtype),
        "w2": dense_init(ks[3], (n_experts, d_ff, d_model), dtype),
    }


def _local_moe(x, wr, w1, w3, w2, *, n_experts: int, top_k: int,
               capacity: int, e_loc: int, model_axis: Optional[str],
               fsdp_axis: Optional[str], dp_axes: tuple,
               weight_mode: str = "gather"):
    """Per-shard MoE.  x: (T_loc, d) local tokens.  Expert weights are local
    slices (E_loc, d[/fsdp], f) for "gather" / (E_loc, d, f/fsdp) for
    "stationary".  Returns (y (T_loc, d), aux_loss scalar)."""
    T, d = x.shape
    stationary = weight_mode == "stationary" and fsdp_axis is not None
    t_loc = T
    if stationary:
        # weights stay put; replicate the (small) token batch over the
        # fsdp axis instead, psum partial f-slices back at the end
        with jax.named_scope("moe_token_allgather"):
            x = jax.lax.all_gather(x, fsdp_axis, axis=0, tiled=True)
        T = x.shape[0]
    elif fsdp_axis is not None:
        with jax.named_scope("moe_fsdp_allgather"):
            w1 = jax.lax.all_gather(w1, fsdp_axis, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, fsdp_axis, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, fsdp_axis, axis=2, tiled=True)

    with jax.named_scope("moe_router"):
        logits = jnp.einsum("td,de->te", x.astype(jnp.float32), wr)
        probs = jax.nn.softmax(logits, axis=-1)            # (T, E)
        gates, eidx = jax.lax.top_k(probs, top_k)          # (T, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e importance_e * load_e
    with jax.named_scope("moe_aux"):
        importance = probs.mean(axis=0)                    # (E,)
        load = jnp.zeros((n_experts,), jnp.float32)
        for j in range(top_k):
            load = load + jnp.bincount(
                eidx[:, j], length=n_experts).astype(jnp.float32)
        load = load / (T * top_k)
        aux = n_experts * jnp.sum(importance * load)

    e0 = (jax.lax.axis_index(model_axis) * e_loc
          if model_axis is not None else 0)

    with jax.named_scope("moe_dispatch_index"):
        le = eidx - e0                                      # (T, k) local ids
        mine = (le >= 0) & (le < e_loc)
        le_flat = jnp.where(mine, le, e_loc).reshape(-1)    # (T*k,)
        onehot = jax.nn.one_hot(le_flat, e_loc, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot           # slot within expert
        pos_flat = jnp.sum(pos * onehot, axis=1)            # (T*k,)
        keep = mine.reshape(-1) & (pos_flat < capacity)
        slot = jnp.where(keep, le_flat * capacity + pos_flat,
                         e_loc * capacity)                  # dump row

    with jax.named_scope("moe_dispatch"):
        buf = jnp.zeros((e_loc * capacity + 1, d), x.dtype)
        for j in range(top_k):
            sj = slot.reshape(T, top_k)[:, j]
            buf = buf.at[sj].set(x, mode="drop")
        expert_in = buf[:-1].reshape(e_loc, capacity, d)

    with jax.named_scope("moe_experts"):
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, w1))
        u = jnp.einsum("ecd,edf->ecf", expert_in, w3)
        eo = jnp.einsum("ecf,efd->ecd", g * u, w2)
        out_flat = jnp.concatenate(
            [eo.reshape(e_loc * capacity, d),
             jnp.zeros((1, d), eo.dtype)], axis=0)

    with jax.named_scope("moe_combine"):
        y = jnp.zeros((T, d), jnp.float32)
        for j in range(top_k):
            sj = slot.reshape(T, top_k)[:, j]
            kj = keep.reshape(T, top_k)[:, j]
            contrib = out_flat[sj].astype(jnp.float32)
            y = y + contrib * (gates[:, j] * kj)[:, None]
        if stationary:
            # merge partial f-slices (fsdp) and partial experts (model) in
            # one fused reduction, then slice this shard's tokens back out
            axes = (fsdp_axis,) + ((model_axis,) if model_axis else ())
            y = jax.lax.psum(y, axes)
            idx = jax.lax.axis_index(fsdp_axis) * t_loc
            y = jax.lax.dynamic_slice_in_dim(y, idx, t_loc, axis=0)
            aux = jax.lax.pmean(aux, tuple(dp_axes) + (
                (model_axis,) if model_axis else ()))
        elif model_axis is not None:
            y = jax.lax.psum(y, model_axis)
            axes = tuple(dp_axes) + (model_axis,)
            aux = jax.lax.pmean(aux, axes)
    return y.astype(x.dtype), aux


def moe_ffn(params, x, *, n_experts: int, top_k: int,
            capacity_factor: float, mesh_args: Optional[MoEMeshArgs]):
    """MoE FFN.  x: (B, S, d).  Returns (y (B,S,d), aux scalar)."""
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    if mesh_args is None or mesh_args.mesh is None:
        cap = max(top_k, int(B * S * top_k / n_experts * capacity_factor))
        y, aux = _local_moe(
            x2, params["router"], params["w1"], params["w3"], params["w2"],
            n_experts=n_experts, top_k=top_k, capacity=cap, e_loc=n_experts,
            model_axis=None, fsdp_axis=None, dp_axes=())
        return y.reshape(B, S, d), aux

    mesh = mesh_args.mesh
    n_dp = 1
    for a in mesh_args.dp_axes:
        n_dp *= mesh.shape[a]
    n_model = mesh.shape[mesh_args.model_axis]
    t_loc = (B * S) // n_dp
    e_loc = n_experts // n_model
    fsdp = mesh_args.fsdp_axis
    mode = mesh_args.weight_mode
    d_ff = params["w1"].shape[-1]
    if mode == "stationary":
        if fsdp is not None and d_ff % mesh.shape[fsdp] != 0:
            fsdp = None     # f not divisible: weights replicate anyway
        n_gather = mesh.shape[fsdp] if fsdp is not None else 1
        cap = max(top_k, int(t_loc * n_gather * top_k / n_experts
                             * capacity_factor))
        # weights resident: f dim sharded over fsdp, never gathered
        w_d = P(mesh_args.model_axis, None, fsdp)
        w_f = P(mesh_args.model_axis, fsdp, None)
    else:
        if fsdp is not None and d % mesh.shape[fsdp] != 0:
            fsdp = None  # replicate d when not divisible
        cap = max(top_k, int(t_loc * top_k / n_experts * capacity_factor))
        w_d = P(mesh_args.model_axis, fsdp, None)
        w_f = P(mesh_args.model_axis, None, fsdp)

    dp = P(tuple(mesh_args.dp_axes))
    fn = functools.partial(
        _local_moe, n_experts=n_experts, top_k=top_k, capacity=cap,
        e_loc=e_loc, model_axis=mesh_args.model_axis, fsdp_axis=fsdp,
        dp_axes=tuple(mesh_args.dp_axes), weight_mode=mode)
    from repro.distributed.shardmap_compat import shard_map
    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(P(tuple(mesh_args.dp_axes), None), P(None, None),
                  w_d, w_d, w_f),
        out_specs=(P(tuple(mesh_args.dp_axes), None), P()),
    )(x2, params["router"], params["w1"], params["w3"], params["w2"])
    return y.reshape(B, S, d), aux
