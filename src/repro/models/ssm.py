"""Selective SSM (Mamba-2 / SSD style) with chunkwise-parallel training and
O(1)-state recurrent decode.

Scalar-per-head decay (SSD formulation) so the chunkwise form is a masked
linear-attention matmul — this maps onto the TPU MXU (see DESIGN.md hardware
adaptation notes) and is also the Pallas kernel target (kernels/ssm_scan.py).

State convention: h[t] = exp(dt[t]*A) * h[t-1] + dt[t] * outer(x[t], B[t]);
y[t] = h[t] @ C[t] + D * x[t], per head, with B/C shared across heads
(ngroups=1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

CONV_W = 4  # depthwise causal conv width


def init_ssm_params(key, d_model: int, n_heads: int, head_dim: int,
                    state: int, dtype) -> dict:
    inner = n_heads * head_dim
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * inner), dtype),
        "conv": dense_init(ks[1], (CONV_W, inner), dtype, scale=1.0),
        "wBC": dense_init(ks[2], (inner, 2 * state), dtype),
        "wdt": dense_init(ks[3], (inner, n_heads), dtype),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "out_proj": dense_init(ks[4], (inner, d_model), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 carry: Optional[jax.Array] = None):
    """Depthwise causal conv.  x: (B,S,inner), w: (CONV_W, inner).
    carry: (B, CONV_W-1, inner) previous inputs (decode)."""
    if carry is None:
        pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype)
    else:
        pad = carry.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W))
    new_carry = xp[:, -(CONV_W - 1):]
    return jax.nn.silu(out), new_carry


def ssd_chunked(xv, logdecay, Bmat, Cmat, *, chunk: int,
                h0: Optional[jax.Array] = None,
                use_kernel: bool = False):
    """Chunkwise-parallel scan.

    xv:       (B, S, nh, hd)   values (dt already folded in)
    logdecay: (B, S, nh)       log decay per step (<= 0)
    Bmat:     (B, S, st)       input projection (shared across heads)
    Cmat:     (B, S, st)       output projection
    h0:       (B, nh, hd, st)  initial state or None
    Returns (y (B,S,nh,hd), h_final).
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.ssm_scan(xv, logdecay, Bmat, Cmat, chunk=chunk,
                                   h0=h0)
    B, S, nh, hd = xv.shape
    st = Bmat.shape[-1]
    from repro.models.layers import pick_chunk
    c = pick_chunk(S, chunk)
    n = S // c
    xc = xv.reshape(B, n, c, nh, hd)
    ld = logdecay.reshape(B, n, c, nh).astype(jnp.float32)
    Bc = Bmat.reshape(B, n, c, st)
    Cc = Cmat.reshape(B, n, c, st)
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, st), jnp.float32)

    cum = jnp.cumsum(ld, axis=2)               # (B,n,c,nh)
    total = cum[:, :, -1]                      # (B,n,nh)

    with jax.named_scope("ssd_intra"):
        # G[t,tau] = exp(cum_t - cum_tau) * (C_t . B_tau), tau <= t
        cb = jnp.einsum("bncs,bnks->bnck", Cc, Bc,
                        preferred_element_type=jnp.float32)  # (B,n,c,c)
        dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,n,t,tau,nh)
        tri = jnp.tril(jnp.ones((c, c), bool))
        # mask BEFORE exp: exp of the (positive) upper-triangle deltas can
        # overflow, and inf * 0 in the VJP of where() poisons d(logdecay)
        dec = jnp.where(tri[None, None, :, :, None], dec, -jnp.inf)
        g = jnp.exp(dec) * cb[..., None]
        y_intra = jnp.einsum("bntkh,bnkhd->bnthd", g,
                             xc.astype(jnp.float32))

    with jax.named_scope("ssd_state"):
        # per-chunk state contribution: sum_tau exp(total - cum_tau) v (x) B
        w = jnp.exp(total[:, :, None, :] - cum)              # (B,n,c,nh)
        sc = jnp.einsum("bnch,bnchd,bncs->bnhds",
                        w, xc.astype(jnp.float32), Bc.astype(jnp.float32))

    @jax.checkpoint
    def step(h, inputs):
        sc_i, total_i, cum_i, C_i = inputs
        # y_inter[t] = exp(cum_t) * C_t . h
        yi = jnp.einsum("bcs,bhds,bch->bchd",
                        C_i.astype(jnp.float32), h, jnp.exp(cum_i))
        h_new = h * jnp.exp(total_i)[:, :, None, None] + sc_i
        return h_new, yi

    with jax.named_scope("ssd_inter"):
        h_fin, y_inter = jax.lax.scan(
            step, h0,
            (sc.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2),
             cum.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3)))
        y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # (B,n,c,nh,hd)

    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y.astype(xv.dtype), h_fin


def mamba_forward(params, x, *, n_heads: int, head_dim: int, state: int,
                  chunk: int = 256, ssm_state=None, conv_state=None,
                  use_kernel: bool = False):
    """Full mamba mixer.  x: (B,S,d).  Returns (y, (ssm_state, conv_state)).

    For decode (S == 1) pass both states; for prefill/training leave None.
    """
    B, S, d = x.shape
    inner = n_heads * head_dim
    with jax.named_scope("mamba_in_proj"):
        xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
        xin, z = jnp.split(xz, 2, axis=-1)
    xin, new_conv = _causal_conv(xin, params["conv"], conv_state)
    with jax.named_scope("mamba_bcdt"):
        BC = jnp.einsum("bse,ek->bsk", xin, params["wBC"])
        Bmat, Cmat = jnp.split(BC, 2, axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bse,eh->bsh", xin, params["wdt"]).astype(jnp.float32)
            + params["dt_bias"])                       # (B,S,nh)
    a = -jnp.exp(params["A_log"])                      # (nh,) negative
    logdecay = dt * a                                  # (B,S,nh)
    xh = xin.reshape(B, S, n_heads, head_dim)
    xv = xh * dt[..., None].astype(xh.dtype)

    if S == 1 and ssm_state is not None:
        # recurrent decode step
        h = ssm_state * jnp.exp(logdecay)[:, 0, :, None, None]
        h = h + jnp.einsum("bhd,bs->bhds", xv[:, 0].astype(jnp.float32),
                           Bmat[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhds,bs->bhd", h, Cmat[:, 0].astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                 # (B,1,nh,hd)
        h_fin = h
    else:
        y, h_fin = ssd_chunked(xv, logdecay, Bmat, Cmat, chunk=chunk,
                               h0=ssm_state, use_kernel=use_kernel)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, inner) * jax.nn.silu(z)
    with jax.named_scope("mamba_out_proj"):
        out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, (h_fin, new_conv)
