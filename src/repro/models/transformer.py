"""Model assembly: config-driven decoder stack covering every assigned
architecture family (dense GQA, MoE, xLSTM, mamba-hybrid, VLM/audio
backbones).

Layers are grouped into *periods* (one period = one repetition of the
block pattern x MoE interleave), and the stack is a lax.scan over periods
with stacked parameters — this keeps HLO size O(period), which is what makes
512-device dry-run compiles tractable (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, HYBRID, MLSTM, SLSTM, SWA, MAMBA,
                                ModelConfig)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import dense_init, rms_norm, swiglu


class EntrySpec(NamedTuple):
    kind: str
    use_moe: bool


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Build-time knobs (perf hillclimb surface)."""
    remat: bool = True
    remat_policy: str = "dots_no_batch"   # dots_no_batch | nothing | everything
    q_chunk: int = 512
    kv_chunk: int = 512
    ssm_chunk: int = 256
    slstm_block: int = 16         # sLSTM timesteps per scan iteration
    attn_schedule: str = "dense"          # dense | binary
    use_flash_kernel: bool = False        # Pallas kernel (TPU only)
    loss_chunk: int = 512


def layer_plan(cfg: ModelConfig) -> Tuple[Tuple[EntrySpec, ...], int]:
    """Returns (period entries, n_periods)."""
    period = len(cfg.block_pattern)
    if cfg.moe is not None:
        period = math.lcm(period, cfg.moe.moe_every)
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    moe_layers = set(cfg.moe_layers())
    entries = tuple(
        EntrySpec(cfg.blocks[i], i in moe_layers) for i in range(period))
    return entries, cfg.n_layers // period


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def _init_ffn(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {"w1": dense_init(ks[0], (d, f), dtype),
            "w3": dense_init(ks[1], (d, f), dtype),
            "w2": dense_init(ks[2], (f, d), dtype)}


def _init_entry(key, spec: EntrySpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.ones((d,), dtype)}
    if spec.kind in (ATTN, SWA):
        p["attn"] = attn_mod.init_attn_params(ks[0], cfg, dtype)
        p["ln2"] = jnp.ones((d,), dtype)
        if spec.use_moe:
            p["moe"] = moe_mod.init_moe_params(
                ks[1], d, cfg.d_ff, cfg.moe.n_experts, dtype)
            if cfg.moe.shared_expert:
                p["shared"] = _init_ffn(ks[2], cfg, dtype)
        elif cfg.d_ff:
            p["ffn"] = _init_ffn(ks[1], cfg, dtype)
    elif spec.kind == MLSTM:
        p["mlstm"] = xlstm_mod.init_mlstm_params(
            ks[0], d, cfg.n_heads, cfg.head_dim, dtype)
    elif spec.kind == SLSTM:
        p["slstm"] = xlstm_mod.init_slstm_params(ks[0], d, cfg.n_heads, dtype)
    elif spec.kind == HYBRID:
        p["attn"] = attn_mod.init_attn_params(ks[0], cfg, dtype)
        p["mamba"] = ssm_mod.init_ssm_params(
            ks[1], d, cfg.n_heads, cfg.head_dim, cfg.ssm_state, dtype)
        p["beta"] = jnp.ones((2,), jnp.float32)
        p["ln2"] = jnp.ones((d,), dtype)
        if cfg.d_ff:
            p["ffn"] = _init_ffn(ks[2], cfg, dtype)
    elif spec.kind == MAMBA:
        p["mamba"] = ssm_mod.init_ssm_params(
            ks[0], d, cfg.n_heads, cfg.head_dim, cfg.ssm_state, dtype)
    else:
        raise ValueError(spec.kind)
    return p


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    entries, n_periods = layer_plan(cfg)
    k_emb, k_out, k_layers = jax.random.split(key, 3)
    params = {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype,
                            scale=cfg.d_model ** 0.5),  # ~N(0,1) rows
        "unembed": dense_init(k_out, (cfg.d_model, cfg.vocab), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": {},
    }
    lkeys = jax.random.split(k_layers, len(entries))
    for i, spec in enumerate(entries):
        per_period = jax.random.split(lkeys[i], n_periods)
        params["layers"][f"e{i}"] = jax.vmap(
            lambda k: _init_entry(k, spec, cfg, dtype))(per_period)
    return params


# ---------------------------------------------------------------------------
# Caches (serving state per entry)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Zero cache pytree, stacked over periods: {'e0': {...}, ...}."""
    dtype = jnp.dtype(cfg.dtype)
    entries, n_periods = layer_plan(cfg)
    d = cfg.d_model
    inner = cfg.n_heads * cfg.head_dim
    cache = {}
    for i, spec in enumerate(entries):
        c: Dict[str, Any] = {}
        if spec.kind in (ATTN, SWA, HYBRID):
            smax = min(cfg.window, max_len) if spec.kind in (SWA, HYBRID) \
                and cfg.window else max_len
            c["k"] = jnp.zeros((n_periods, batch, smax, cfg.n_kv_heads,
                                cfg.head_dim), dtype)
            c["v"] = jnp.zeros_like(c["k"])
        if spec.kind == HYBRID or spec.kind == MAMBA:
            c["ssm"] = jnp.zeros((n_periods, batch, cfg.n_heads,
                                  cfg.head_dim, cfg.ssm_state), jnp.float32)
            c["conv"] = jnp.zeros((n_periods, batch, ssm_mod.CONV_W - 1,
                                   inner), dtype)
        if spec.kind == MLSTM:
            dv = 2 * d // cfg.n_heads
            c["H"] = jnp.zeros((n_periods, batch, cfg.n_heads,
                                cfg.head_dim, dv + 1), jnp.float32)
            c["m"] = jnp.full((n_periods, batch, cfg.n_heads), -1e30,
                              jnp.float32)
        if spec.kind == SLSTM:
            for name in ("c", "n", "h"):
                c[name] = jnp.zeros((n_periods, batch, d), jnp.float32)
            c["m"] = jnp.full((n_periods, batch, d), -1e30, jnp.float32)
        cache[f"e{i}"] = c
    return cache


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_ffn(p, x, cfg, mesh_args, opts):
    """Dense or MoE FFN sub-block.  Returns (y, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        y, aux = moe_mod.moe_ffn(
            p["moe"], x, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor, mesh_args=mesh_args)
        if "shared" in p:
            y = y + swiglu(x, p["shared"]["w1"], p["shared"]["w3"],
                           p["shared"]["w2"])
    elif "ffn" in p:
        y = swiglu(x, p["ffn"]["w1"], p["ffn"]["w3"], p["ffn"]["w2"])
    else:
        return jnp.zeros_like(x), aux
    return y, aux


def _apply_entry(p, spec: EntrySpec, x, positions, cfg, mesh_args, opts,
                 mode: str, cache=None, cache_pos=None):
    """One block.  Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None or mode != "train" else None
    h = rms_norm(x, p["ln1"])

    if spec.kind in (ATTN, SWA):
        window = cfg.window if spec.kind == SWA else 0
        y, kv = _attention(p["attn"], h, positions, cfg, window, opts,
                           mode, cache, cache_pos)
        if kv is not None:
            new_cache.update(kv)
        x = x + y
        h2 = rms_norm(x, p["ln2"])
        y2, aux = _apply_ffn(p, h2, cfg, mesh_args, opts)
        x = x + y2
    elif spec.kind == MLSTM:
        state = (cache["H"], cache["m"]) if cache is not None else None
        y, st = xlstm_mod.mlstm_forward(
            p["mlstm"], h, n_heads=cfg.n_heads, dqk=cfg.head_dim,
            chunk=opts.ssm_chunk, state=state,
            use_kernel=opts.use_flash_kernel)
        if mode != "train":
            new_cache.update({"H": st[0], "m": st[1]})
        x = x + y
    elif spec.kind == SLSTM:
        state = cache if cache is not None else None
        if state is not None:
            state = {k: cache[k] for k in ("c", "n", "h", "m")}
        y, st = xlstm_mod.slstm_forward(p["slstm"], h, n_heads=cfg.n_heads,
                                        state=state,
                                        time_block=opts.slstm_block)
        if mode != "train":
            new_cache.update(st)
        x = x + y
    elif spec.kind == HYBRID:
        window = cfg.window
        kv_in = None
        ssm_state = conv_state = None
        if cache is not None:
            kv_in = cache
            ssm_state, conv_state = cache["ssm"], cache["conv"]
        ya, kv = _attention(p["attn"], h, positions, cfg, window, opts,
                            mode, kv_in, cache_pos)
        ym, (st, cv) = ssm_mod.mamba_forward(
            p["mamba"], h, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
            state=cfg.ssm_state, chunk=opts.ssm_chunk,
            ssm_state=ssm_state, conv_state=conv_state,
            use_kernel=opts.use_flash_kernel)
        beta = p["beta"].astype(x.dtype)
        y = 0.5 * (beta[0] * ya + beta[1] * ym)
        if mode != "train":
            new_cache.update(kv or {})
            new_cache.update({"ssm": st, "conv": cv})
        x = x + y
        h2 = rms_norm(x, p["ln2"])
        y2, aux = _apply_ffn(p, h2, cfg, mesh_args, opts)
        x = x + y2
    elif spec.kind == MAMBA:
        ssm_state = conv_state = None
        if cache is not None:
            ssm_state, conv_state = cache["ssm"], cache["conv"]
        y, (st, cv) = ssm_mod.mamba_forward(
            p["mamba"], h, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
            state=cfg.ssm_state, chunk=opts.ssm_chunk,
            ssm_state=ssm_state, conv_state=conv_state)
        if mode != "train":
            new_cache.update({"ssm": st, "conv": cv})
        x = x + y

    if mesh_args is not None and mesh_args.mesh is not None:
        x = jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(
                mesh_args.mesh, P(tuple(mesh_args.dp_axes), None, None)))
    return x, new_cache, aux


def _attention(ap, h, positions, cfg, window, opts, mode, cache, cache_pos):
    """Attention sub-block across the three modes.  Returns (y, cache)."""
    if mode == "train":
        y, _ = attn_mod.attention_block(
            ap, h, positions, cfg, layer_window=window,
            q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
            schedule=opts.attn_schedule, use_kernel=opts.use_flash_kernel)
        return y, None
    if mode == "prefill":
        # build cache from scratch: compute qkv, then keep (window or full)
        q, k, v = attn_mod.project_qkv(ap, h, cfg, positions)
        if window:
            out = attn_mod.swa_attention(q, k, v, window)
            # ring cache: slot i must hold absolute position p with
            # p % w == i, so the kept tail is rolled by S % w.
            S = h.shape[1]
            w = min(window, S)
            kc = jnp.roll(k[:, -w:], S % w, axis=1)
            vc = jnp.roll(v[:, -w:], S % w, axis=1)
        else:
            out = attn_mod.chunked_attention(
                q, k, v, q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
                schedule=opts.attn_schedule)
            kc, vc = k, v
        with jax.named_scope("o_proj"):
            y = jnp.einsum("bshk,hkd->bsd", out, ap["wo"])
        return y, {"k": kc.astype(jnp.dtype(cfg.dtype)),
                   "v": vc.astype(jnp.dtype(cfg.dtype))}
    # decode
    y, kv = attn_mod.attention_block(
        ap, h, positions, cfg, layer_window=window,
        kv_cache=(cache["k"], cache["v"]), cache_pos=cache_pos,
        q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
        schedule=opts.attn_schedule)
    return y, {"k": kv[0], "v": kv[1]}


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------
def _remat_policy(opts: ModelOptions):
    if opts.remat_policy == "dots_no_batch":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if opts.remat_policy == "everything":
        return jax.checkpoint_policies.everything_saveable
    return jax.checkpoint_policies.nothing_saveable


def embed_inputs(params, cfg: ModelConfig, tokens, embeds):
    """tokens: (B, S_text) int32 or None; embeds: (B, S_front, d) or None."""
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.dtype(cfg.dtype)))
    if tokens is not None:
        with jax.named_scope("embed"):
            parts.append(jnp.take(params["embed"], tokens, axis=0))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def _stack_forward(params, x, cfg, mesh_args, opts, mode,
                   cache=None, cache_pos=None, positions=None):
    """Runs the scan over periods.  Returns (x, new_cache, aux_sum)."""
    entries, n_periods = layer_plan(cfg)

    def body(carry, xs):
        x, aux_sum = carry
        layer_p = xs["params"]
        layer_c = xs.get("cache")
        new_c = {}
        for i, spec in enumerate(entries):
            ename = f"e{i}"
            c = layer_c[ename] if layer_c is not None else None
            with jax.named_scope(f"block_{spec.kind}{i}"):
                x, nc, aux = _apply_entry(
                    layer_p[ename], spec, x, positions, cfg, mesh_args, opts,
                    mode, cache=c, cache_pos=cache_pos)
            new_c[ename] = nc
            aux_sum = aux_sum + aux
        return (x, aux_sum), (new_c if mode != "train" else None)

    if opts.remat and mode == "train":
        body = jax.checkpoint(body, policy=_remat_policy(opts),
                              prevent_cse=False)

    xs = {"params": params["layers"]}
    if cache is not None:
        xs["cache"] = cache
    (x, aux_sum), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                           xs)
    return x, new_cache, aux_sum


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, *,
            mesh_args=None, opts: ModelOptions = ModelOptions()):
    """Training forward.  Returns (hidden (B,S,d), aux)."""
    x = embed_inputs(params, cfg, tokens, embeds)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, _, aux = _stack_forward(params, x, cfg, mesh_args, opts, "train",
                               positions=positions)
    return rms_norm(x, params["final_norm"]), aux


def lm_loss(params, cfg: ModelConfig, hidden, labels, *,
            mesh_args=None, opts: ModelOptions = ModelOptions(),
            z_loss: float = 1e-4):
    """Chunked cross-entropy over the unembedding.  labels: (B,S) int32,
    positions with label < 0 are masked.  Returns (loss, n_tokens)."""
    B, S, d = hidden.shape
    from repro.models.layers import pick_chunk
    c = pick_chunk(S, opts.loss_chunk)
    n = S // c
    hs = hidden.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        h, lab = xs
        with jax.named_scope("unembed"):
            logits = jnp.einsum("bcd,dv->bcv", h,
                                params["unembed"]).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gather (not one-hot einsum): avoids materializing a second
        # (B, c, V) fp32 temporary — see EXPERIMENTS.md §Perf
        lab_logit = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - lab_logit) * mask
        zl = z_loss * jnp.square(lse) * mask
        loss, ntok = carry
        return (loss + jnp.sum(nll + zl), ntok + jnp.sum(mask)), None

    (loss, ntok), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls))
    return loss, ntok


def loss_fn(params, cfg: ModelConfig, batch, *, mesh_args=None,
            opts: ModelOptions = ModelOptions()):
    """Scalar-mean LM loss + MoE aux.  batch: dict(tokens?, embeds?, labels)."""
    hidden, aux = forward(params, cfg, batch.get("tokens"),
                          batch.get("embeds"), mesh_args=mesh_args, opts=opts)
    loss, ntok = lm_loss(params, cfg, hidden, batch["labels"],
                         mesh_args=mesh_args, opts=opts)
    total = loss / jnp.maximum(ntok, 1.0) + 0.01 * aux
    return total, {"nll": loss / jnp.maximum(ntok, 1.0), "aux": aux,
                   "ntok": ntok}


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, *,
            mesh_args=None, opts: ModelOptions = ModelOptions()):
    """Serving prefill.  Returns (last_logits (B,V), cache)."""
    x = embed_inputs(params, cfg, tokens, embeds)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, cache, _ = _stack_forward(params, x, cfg, mesh_args, opts, "prefill",
                                 positions=positions)
    h_last = rms_norm(x[:, -1:], params["final_norm"])
    with jax.named_scope("unembed"):
        logits = jnp.einsum("bsd,dv->bsv", h_last, params["unembed"])
    return logits[:, 0].astype(jnp.float32), cache


def decode_step(params, cfg: ModelConfig, cache, token=None, embed=None,
                pos=None, *, mesh_args=None,
                opts: ModelOptions = ModelOptions()):
    """One serving step: one new token against the cache.

    token: (B,) int32 (or embed: (B,1,d) for audio).  pos: scalar int32
    absolute position of this token.  Returns (logits (B,V), new_cache).
    """
    if embed is None:
        x = jnp.take(params["embed"], token[:, None], axis=0)
    else:
        x = embed.astype(jnp.dtype(cfg.dtype))
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x, new_cache, _ = _stack_forward(params, x, cfg, mesh_args, opts,
                                     "decode", cache=cache, cache_pos=pos,
                                     positions=positions)
    h = rms_norm(x, params["final_norm"])
    with jax.named_scope("unembed"):
        logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])
    return logits[:, 0].astype(jnp.float32), new_cache
