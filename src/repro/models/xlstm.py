"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential recurrence).

mLSTM cell:  C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
             h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))
with exponential input gate i = exp(i~), forget gate f = sigmoid(f~), and the
paper's max-state m_t stabilization.  We track the normalizer n as an extra
"value" column of the matrix state (state shape (dqk, dv+1)) so the chunkwise
form is a single masked linear-attention computation — the same skeleton as
ssm.ssd_chunked (kernels/ssm_scan.py implements that skeleton for the SSD
case; the mLSTM variant adds the max-stabilizer carry and stays in jnp).

sLSTM is inherently sequential (h_{t-1} feeds the gate pre-activations via
recurrent matrix R) and is implemented as a lax.scan over time — the paper
itself notes it is not parallelizable; see EXPERIMENTS.md §Roofline for the
consequences.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm_params(key, d_model: int, n_heads: int, dqk: int, dtype):
    """xLSTM block: up-proj x2 (factor 2), conv-less variant, per-head qkv."""
    inner = 2 * d_model
    dv = inner // n_heads
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], (d_model, 2 * inner), dtype),
        "wq": dense_init(ks[1], (inner, n_heads, dqk), dtype),
        "wk": dense_init(ks[2], (inner, n_heads, dqk), dtype),
        "wv": dense_init(ks[3], (inner, n_heads, dv), dtype),
        "wif": dense_init(ks[4], (inner, n_heads, 2), dtype),
        "b_if": jnp.zeros((n_heads, 2), jnp.float32),
        "out_norm": jnp.ones((inner,), dtype),
        "down_proj": dense_init(ks[5], (inner, d_model), dtype),
    }


def mlstm_chunked(q, k, v, ig, fg, *, chunk: int,
                  state: Optional[Tuple] = None):
    """Chunkwise-parallel mLSTM.

    q,k: (B,S,nh,dqk); v: (B,S,nh,dv); ig/fg: (B,S,nh) raw gate
    pre-activations.  state: (H (B,nh,dqk,dv+1), m (B,nh)) or None.
    Returns (h (B,S,nh,dv), (H, m)).
    """
    B, S, nh, dqk = q.shape
    dv = v.shape[-1]
    from repro.models.layers import pick_chunk
    c = pick_chunk(S, chunk)
    n = S // c
    scale = dqk ** -0.5
    # normalizer tracked as an extra all-ones value column
    v1 = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((B, S, nh, 1), jnp.float32)], -1)

    qc = q.reshape(B, n, c, nh, dqk).astype(jnp.float32) * scale
    kc = k.reshape(B, n, c, nh, dqk).astype(jnp.float32)
    vc = v1.reshape(B, n, c, nh, dv + 1)
    lf = jax.nn.log_sigmoid(fg.astype(jnp.float32)).reshape(B, n, c, nh)
    li = ig.astype(jnp.float32).reshape(B, n, c, nh)

    cum = jnp.cumsum(lf, axis=2)                    # (B,n,c,nh) cumulative logf
    total = cum[:, :, -1]                           # (B,n,nh)
    tri = jnp.tril(jnp.ones((c, c), bool))

    if state is None:
        H0 = jnp.zeros((B, nh, dqk, dv + 1), jnp.float32)
        m0 = jnp.full((B, nh), -jnp.inf, jnp.float32)
    else:
        H0, m0 = state
        m0 = jnp.where(jnp.isfinite(m0), m0, -jnp.inf)

    @jax.checkpoint
    def step(carry, inputs):
        H, m = carry
        q_i, k_i, v_i, cum_i, total_i, li_i = inputs
        # intra-chunk log weights w[t,tau] = cum_t - cum_tau + li_tau
        # (tau <= t) — computed PER CHUNK inside the scan body: hoisted
        # out it materializes a (B, n, c, c, nh) tensor for all chunks at
        # once (~1 GiB/device live + its traffic on xlstm prefill_32k;
        # EXPERIMENTS.md §Perf C2)
        dec_i = (cum_i[:, :, None, :] - cum_i[:, None, :, :]
                 + li_i[:, None, :, :])                       # (B,c,c,nh)
        dec_i = jnp.where(tri[None, :, :, None], dec_i, -jnp.inf)
        m_intra_i = dec_i.max(axis=2)                         # (B,c,nh)
        # combined stabilizer per row t
        m_inter = cum_i + m[:, None, :]                       # (B,c,nh)
        m_t = jnp.maximum(m_intra_i, m_inter)
        m_t = jnp.where(jnp.isfinite(m_t), m_t, 0.0)
        p = jnp.exp(dec_i - m_t[:, :, None, :])               # (B,c,c,nh)
        # scores: (q_t . k_tau) weighted by stabilized gate products
        s = jnp.einsum("bthq,bkhq->btkh", q_i, k_i)           # (B,c,c,nh)
        h_intra = jnp.einsum("btkh,bkhd->bthd", s * p, v_i)   # (B,c,nh,dv+1)
        w_inter = jnp.exp(m_inter - m_t)                      # (B,c,nh)
        h_inter = jnp.einsum("bthq,bhqd,bth->bthd", q_i, H, w_inter)
        h = h_intra + h_inter                                  # (B,c,nh,dv+1)
        # state update
        m_new = jnp.maximum(total_i + m,
                            (total_i[:, None, :] - cum_i + li_i).max(axis=1))
        Hc = jnp.einsum("bkhq,bkhd,bkh->bhqd", k_i, v_i,
                        jnp.exp(total_i[:, None, :] - cum_i + li_i
                                - m_new[:, None, :]))
        H_new = H * jnp.exp(total_i + m - m_new)[:, :, None, None] + Hc
        return (H_new, m_new), (h, m_t)

    with jax.named_scope("mlstm_chunked"):
        (H_fin, m_fin), (h, m_t) = jax.lax.scan(
            step, (H0, m0),
            (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
             vc.transpose(1, 0, 2, 3, 4), cum.transpose(1, 0, 2, 3),
             total.transpose(1, 0, 2), li.transpose(1, 0, 2, 3)))
    h = h.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, dv + 1)
    m_t = m_t.transpose(1, 0, 2, 3).reshape(B, S, nh)
    num = h[..., :dv]
    den = h[..., dv]
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    return (num / den[..., None]).astype(q.dtype), (H_fin, m_fin)


def mlstm_decode(q, k, v, ig, fg, state):
    """One-step recurrent mLSTM.  q/k: (B,nh,dqk); v: (B,nh,dv);
    ig/fg: (B,nh).  state: (H (B,nh,dqk,dv+1), m (B,nh))."""
    H, m = state
    dqk = q.shape[-1]
    lf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    li = ig.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    m_new = jnp.where(jnp.isfinite(m_new), m_new, li)
    f_ = jnp.exp(lf + m - m_new)
    f_ = jnp.where(jnp.isfinite(m), f_, 0.0)
    i_ = jnp.exp(li - m_new)
    v1 = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones(v.shape[:-1] + (1,), jnp.float32)],
        -1)
    H_new = H * f_[..., None, None] + i_[..., None, None] * jnp.einsum(
        "bhq,bhd->bhqd", k.astype(jnp.float32), v1)
    hq = jnp.einsum("bhqd,bhq->bhd", H_new,
                    q.astype(jnp.float32) * dqk ** -0.5)
    dv = v.shape[-1]
    num, den = hq[..., :dv], hq[..., dv]
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (H_new, m_new)


def mlstm_forward(params, x, *, n_heads: int, dqk: int, chunk: int = 256,
                  state=None, use_kernel: bool = False):
    """mLSTM block mixer.  x: (B,S,d).  Returns (y, state)."""
    B, S, d = x.shape
    inner = 2 * d
    with jax.named_scope("mlstm_up_proj"):
        ug = jnp.einsum("bsd,de->bse", x, params["up_proj"])
        u, gate = jnp.split(ug, 2, axis=-1)
    q = jnp.einsum("bse,ehq->bshq", u, params["wq"])
    k = jnp.einsum("bse,ehq->bshq", u, params["wk"])
    v = jnp.einsum("bse,ehd->bshd", u, params["wv"])
    if_ = jnp.einsum("bse,ehg->bshg", u, params["wif"]).astype(jnp.float32) \
        + params["b_if"]
    ig, fg = if_[..., 0], if_[..., 1]
    if S == 1 and state is not None:
        h, new_state = mlstm_decode(q[:, 0], k[:, 0], v[:, 0],
                                    ig[:, 0], fg[:, 0], state)
        h = h[:, None]
    else:
        h, new_state = mlstm_chunked(q, k, v, ig, fg, chunk=chunk,
                                     state=state)
    h = h.reshape(B, S, inner)
    h = rms_norm(h, params["out_norm"]) * jax.nn.silu(gate)
    with jax.named_scope("mlstm_down_proj"):
        y = jnp.einsum("bse,ed->bsd", h, params["down_proj"])
    return y, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm_params(key, d_model: int, n_heads: int, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 5)
    # ~4/3 proj factor, rounded up to 64 for TP divisibility / MXU tiles
    f_up = -(-int(d_model * 4 / 3) // 64) * 64
    return {
        "wx": dense_init(ks[0], (d_model, 4 * d_model), dtype),
        # recurrent block-diagonal per head: (nh, dh, 4*dh)
        "r": dense_init(ks[1], (n_heads, dh, 4 * dh), dtype),
        "b": jnp.zeros((4 * d_model,), jnp.float32),
        "out_norm": jnp.ones((d_model,), dtype),
        "up1": dense_init(ks[2], (d_model, f_up), dtype),
        "up2": dense_init(ks[3], (d_model, f_up), dtype),
        "down": dense_init(ks[4], (f_up, d_model), dtype),
    }


def _slstm_cell(params, xt, state, n_heads: int):
    """One sLSTM step.  xt: (B, 4d) preactivation from W x.
    state: dict(c, n, h, m) each (B, d) fp32."""
    B = xt.shape[0]
    d = xt.shape[-1] // 4
    dh = d // n_heads
    h_heads = state["h"].reshape(B, n_heads, dh)
    rec = jnp.einsum("bhe,hef->bhf", h_heads.astype(params["r"].dtype),
                     params["r"]).reshape(B, 4 * d)
    pre = (xt + rec).astype(jnp.float32) + params["b"]
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o)
    lf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(lf + state["m"], i)
    i_ = jnp.exp(i - m_new)
    f_ = jnp.exp(lf + state["m"] - m_new)
    c_new = f_ * state["c"] + i_ * z
    n_new = f_ * state["n"] + i_
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(params, x, *, n_heads: int, state=None,
                  time_block: int = 16):
    """sLSTM block mixer (sequential).  x: (B,S,d).  Returns (y, state).

    ``time_block``: timesteps per scan iteration (inner loop unrolled).
    The recurrence is inherently sequential, but the recurrent matrix
    ``r`` need only be fetched once per iteration — at time_block=1 the
    32k-step long-context shapes re-read r every step (~157 TB of pure
    weight traffic on xlstm prefill_32k; §Perf C3).  On TPU the unrolled
    block also keeps r resident in VMEM (2.4 MB).
    """
    B, S, d = x.shape
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = {"c": z, "n": z, "h": z,
                 "m": jnp.full((B, d), -1e30, jnp.float32)}
    with jax.named_scope("slstm_x_proj"):
        xp = jnp.einsum("bsd,de->bse", x, params["wx"])  # (B,S,4d)

    k = time_block
    while S % k:
        k //= 2
    n = S // k

    def step(st, xt_blk):
        # xt_blk: (k, B, 4d); inner python loop unrolls so XLA loads the
        # recurrent weights once per outer iteration
        hs = []
        for i in range(k):
            st = _slstm_cell(params, xt_blk[i], st, n_heads)
            hs.append(st["h"])
        return st, jnp.stack(hs)

    with jax.named_scope("slstm_scan"):
        xb = xp.transpose(1, 0, 2).reshape(n, k, B, 4 * d)
        state, hs = jax.lax.scan(step, state, xb)
    h = hs.reshape(S, B, d).transpose(1, 0, 2).astype(x.dtype)   # (B,S,d)
    h = rms_norm(h, params["out_norm"])
    with jax.named_scope("slstm_ffn"):
        u = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, params["up1"]))
        g = jnp.einsum("bsd,df->bsf", h, params["up2"])
        y = jnp.einsum("bsf,fd->bsd", u * g, params["down"])
    return y, state
