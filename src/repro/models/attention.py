"""GQA attention: chunked (flash-style) training/prefill, sliding-window, and
single-token decode against a KV cache.

Two causal schedules are provided for the chunked path:

- ``dense``  — every (q-chunk, kv-chunk) pair is computed and masked.  This is
  the straightforward baseline; on a causal workload it spends ~2x the useful
  FLOPs (the upper triangle is masked out but still fed to the MXU).
- ``binary`` — exact triangular schedule via balanced binary decomposition:
  the strictly-lower triangle of the chunk grid is covered by log2(n) levels
  of *unmasked* square blocks (level l has 2^l squares of side n/2^(l+1)),
  plus n masked diagonal blocks.  Compiled FLOPs ~ S^2/2 + S*c.  Used by the
  perf hillclimb (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, dense_init, pick_chunk,
                                 rms_norm, rope_freqs)

NEG_INF = -1e30


def init_attn_params(key, cfg, dtype) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), dtype),
        "wk": dense_init(ks[1], (d, hkv, dh), dtype),
        "wv": dense_init(ks[2], (d, hkv, dh), dtype),
        "wo": dense_init(ks[3], (h, dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((hkv, dh), dtype)
        p["bv"] = jnp.zeros((hkv, dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def project_qkv(params, x, cfg, positions):
    """x: (B,S,d) -> q (B,S,H,Dh), k/v (B,S,Hkv,Dh) with rope applied."""
    with jax.named_scope("qkv_proj"):
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if cfg.qkv_bias:
            q = q + params["bq"]
            k = k + params["bk"]
            v = v + params["bv"]
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"])
            k = rms_norm(k, params["k_norm"])
    cos, sin = rope_freqs(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _merge_stats(m1, l1, o1, m2, l2, o2):
    """Combine two online-softmax stat sets over the same q rows."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return m, l1 * a1 + l2 * a2, o1 * a1[..., None] + o2 * a2[..., None]


def _block_scores(q_blk, k_blk):
    """q_blk: (..., q, Hkv, G, D); k_blk: (..., k, Hkv, D) ->
    (..., Hkv, G, q, k) fp32 scaled scores."""
    scale = q_blk.shape[-1] ** -0.5
    return jnp.einsum("...qhgd,...khd->...hgqk", q_blk, k_blk,
                      preferred_element_type=jnp.float32) * scale


def _block_attn(q_blk, k_blk, v_blk, mask, m, l, o):
    """One online-softmax update.  q_blk: (B,cq,Hkv,G,D); k/v: (B,ck,Hkv,D);
    mask: (cq,ck) boolean (True = allowed) or None."""
    s = _block_scores(q_blk, k_blk)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_blk,
                    preferred_element_type=jnp.float32)
    o_new = o * alpha[..., None] + pv
    return m_new, l_new, o_new


def chunked_attention(q, k, v, *, q_chunk: int, kv_chunk: int,
                      q_offset=0, window: int = 0,
                      schedule: str = "dense") -> jax.Array:
    """Causal flash-style attention with an O(S)-memory custom VJP.

    q: (B,S,H,D), k/v: (B,Sk,Hkv,D).  ``q_offset`` is the absolute position
    of q[0] relative to k[0] (used when a prefix of KV comes from a cache).
    Returns (B,S,H,D).

    The backward pass recomputes score blocks from the saved (q,k,v,out,
    lse) — the standard flash-attention trick — because differentiating the
    nested forward scans directly stores O(n_q x n_k) block temporaries
    (measured 80 GiB/device on qwen2 train_4k before this VJP; see
    EXPERIMENTS.md §Perf).
    """
    if isinstance(q_offset, int) and q_offset == 0 and q.shape[1] == \
            k.shape[1]:
        return _flash(q, k, v, q_chunk, kv_chunk, window, schedule)
    return _chunked_attention_fwd_only(q, k, v, q_chunk=q_chunk,
                                       kv_chunk=kv_chunk, q_offset=q_offset,
                                       window=window, schedule=schedule)


def _chunked_attention_fwd_only(q, k, v, *, q_chunk, kv_chunk, q_offset=0,
                                window=0, schedule="dense") -> jax.Array:
    return _attn_core(q, k, v, q_chunk, kv_chunk, q_offset, window,
                      schedule)[0]


def _attn_core(q, k, v, q_chunk, kv_chunk, q_offset, window, schedule):
    """Online-softmax attention.  Returns (out (B,S,H,D), lse (B,Hkv,G,S))."""
    B, S, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    cq = pick_chunk(S, q_chunk)
    nq = S // cq
    ck = pick_chunk(Sk, kv_chunk)
    nk = Sk // ck

    if (schedule == "binary" and S == Sk and nq == nk and cq == ck
            and (nq & (nq - 1)) == 0 and isinstance(q_offset, int)
            and q_offset == 0 and not window):
        return _binary_causal(q, k, v, nq, cq)

    qr = q.reshape(B, nq, cq, Hkv, G, D)
    kr = k.reshape(B, nk, ck, Hkv, D)
    vr = v.reshape(B, nk, ck, Hkv, D)
    qpos = q_offset + jnp.arange(S).reshape(nq, cq)
    kpos = jnp.arange(Sk).reshape(nk, ck)

    def q_body(_, qi):
        q_blk = jax.lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
        qp = jax.lax.dynamic_index_in_dim(qpos, qi, 0, keepdims=False)

        def kv_body(carry, kj):
            m, l, o = carry
            k_blk = jax.lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
            kp = kpos[kj]
            mask = kp[None, :] <= qp[:, None]
            if window:
                mask &= kp[None, :] > qp[:, None] - window
            m, l, o = _block_attn(q_blk, k_blk, v_blk, mask, m, l, o)
            return (m, l, o), None

        init = (jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, cq), jnp.float32),
                jnp.zeros((B, Hkv, G, cq, D), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_body, init, jnp.arange(nk))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return None, (o, lse)

    with jax.named_scope("attention_core"):
        _, (out, lse) = jax.lax.scan(q_body, None, jnp.arange(nq))
    # out: (nq, B, Hkv, G, cq, D) -> (B, S, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, S)
    return out.astype(q.dtype), lse


# --------------------------------------------------------------------------
# O(S)-memory custom VJP (flash-attention backward with block recompute)
# --------------------------------------------------------------------------
import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, q_chunk, kv_chunk, window, schedule):
    return _attn_core(q, k, v, q_chunk, kv_chunk, 0, window, schedule)[0]


def _flash_fwd(q, k, v, q_chunk, kv_chunk, window, schedule):
    out, lse = _attn_core(q, k, v, q_chunk, kv_chunk, 0, window, schedule)
    # residuals: (q, k, v) ONLY.  out/lse are recomputed in the backward:
    # custom_vjp residuals are opaque to jax.checkpoint, so under
    # scan-over-layers everything saved here is stacked x n_periods — with
    # (out, lse) saved that was 14 GiB/device on qwen2 train_4k
    # (EXPERIMENTS.md §Perf A5); recomputing costs one extra attention fwd.
    return out, (q, k, v)


def _flash_bwd(q_chunk, kv_chunk, window, schedule, res, dout):
    q, k, v = res
    out, lse = _attn_core(q, k, v, q_chunk, kv_chunk, 0, window, schedule)
    B, S, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    cq = pick_chunk(S, q_chunk)
    nq = S // cq
    ck = pick_chunk(Sk, kv_chunk)
    nk = Sk // ck
    scale = D ** -0.5

    qr = q.reshape(B, nq, cq, Hkv, G, D)
    dor = dout.reshape(B, nq, cq, Hkv, G, D)
    kr = k.reshape(B, nk, ck, Hkv, D)
    vr = v.reshape(B, nk, ck, Hkv, D)
    lser = lse.reshape(B, Hkv, G, nq, cq)
    # delta = rowsum(dout * out)  (B,Hkv,G,nq,cq)
    delta = jnp.einsum("bshd,bshd->bsh", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    delta = delta.reshape(B, nq, cq, Hkv, G).transpose(0, 3, 4, 1, 2)
    qpos = jnp.arange(S).reshape(nq, cq)
    kpos = jnp.arange(Sk).reshape(nk, ck)

    def q_body(carry, qi):
        dk_acc, dv_acc = carry
        q_blk = jax.lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
        do_blk = jax.lax.dynamic_index_in_dim(dor, qi, 1, keepdims=False)
        lse_i = jax.lax.dynamic_index_in_dim(lser, qi, 3, keepdims=False)
        dl_i = jax.lax.dynamic_index_in_dim(delta, qi, 3, keepdims=False)
        qp = jax.lax.dynamic_index_in_dim(qpos, qi, 0, keepdims=False)

        def kv_body(inner, kj):
            dq_i, dk_acc, dv_acc = inner
            k_blk = jax.lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
            kp = kpos[kj]
            mask = kp[None, :] <= qp[:, None]
            if window:
                mask &= kp[None, :] > qp[:, None] - window
            s = _block_scores(q_blk, k_blk)                    # (B,h,g,cq,ck)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lse_i[..., None]), 0.0)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk, v_blk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dl_i[..., None])                    # fp32
            dq_i = dq_i + jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk,
                                     preferred_element_type=jnp.float32
                                     ) * scale
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk,
                              preferred_element_type=jnp.float32) * scale
            dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, do_blk,
                              preferred_element_type=jnp.float32)
            dk_acc = jax.lax.dynamic_update_index_in_dim(
                dk_acc, jax.lax.dynamic_index_in_dim(
                    dk_acc, kj, 1, keepdims=False) + dk_j, kj, 1)
            dv_acc = jax.lax.dynamic_update_index_in_dim(
                dv_acc, jax.lax.dynamic_index_in_dim(
                    dv_acc, kj, 1, keepdims=False) + dv_j, kj, 1)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, cq, Hkv, G, D), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_body, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((B, nk, ck, Hkv, D), jnp.float32)
    dv0 = jnp.zeros((B, nk, ck, Hkv, D), jnp.float32)
    with jax.named_scope("attention_bwd"):
        (dk, dv), dq = jax.lax.scan(q_body, (dk0, dv0), jnp.arange(nq))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, D).astype(q.dtype)
    dk = dk.reshape(B, Sk, Hkv, D).astype(k.dtype)
    dv = dv.reshape(B, Sk, Hkv, D).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def _binary_causal(q, k, v, n: int, c: int):
    """Exact causal attention via balanced binary decomposition.

    Chunk grid is n x n (chunk size c, n a power of two).  Work items:
      * n diagonal blocks (causal-masked within the block);
      * for level l in [0, log2 n): 2^l UNMASKED squares of side n/2^(l+1),
        square k covering q-chunks [2km+m, 2km+2m) x kv-chunks [2km, 2km+m)
        with m = n/2^(l+1).
    All squares at a level touch disjoint q rows, so each level is one
    batched (reshaped) einsum and a slice-update of the running stats —
    no scatter, no masking, ~S^2/2 exact FLOPs.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qr = q.reshape(B, n, c, Hkv, G, D)
    kr = k.reshape(B, n, c, Hkv, D)
    vr = v.reshape(B, n, c, Hkv, D)

    with jax.named_scope("attn_binary_diag"):
        # diagonal blocks, causal-masked
        dmask = jnp.tril(jnp.ones((c, c), bool))
        s = _block_scores(qr, kr)                       # (B,n,Hkv,G,c,c)
        s = jnp.where(dmask[None, None, None, None], s, NEG_INF)
        m = s.max(axis=-1)                              # (B,n,Hkv,G,c)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bnhgqk,bnkhd->bnhgqd", p, vr,
                       preferred_element_type=jnp.float32)

    level = 0
    half = n // 2
    while half >= 1:
        mm = half  # squares of side mm chunks at this level: count n/(2*mm)
        ns = n // (2 * mm)
        with jax.named_scope(f"attn_binary_l{level}"):
            # group chunks into (ns, 2, mm): [:,0] = kv side, [:,1] = q side
            qg = qr.reshape(B, ns, 2, mm * c, Hkv, G, D)[:, :, 1]
            kg = kr.reshape(B, ns, 2, mm * c, Hkv, D)[:, :, 0]
            vg = vr.reshape(B, ns, 2, mm * c, Hkv, D)[:, :, 0]
            s = _block_scores(qg, kg)                   # (B,ns,Hkv,G,Q,K)
            m2 = s.max(axis=-1)
            p = jnp.exp(s - m2[..., None])
            l2 = p.sum(axis=-1)
            o2 = jnp.einsum("bnhgqk,bnkhd->bnhgqd", p, vg,
                            preferred_element_type=jnp.float32)
            # merge into running stats at the q rows of this level
            # (B,n,Hkv,G,c) -> chunk-major rows -> (B,ns,2,Hkv,G,Q)
            mr = (m.transpose(0, 1, 4, 2, 3)
                  .reshape(B, ns, 2, mm * c, Hkv, G)
                  .transpose(0, 1, 2, 4, 5, 3))
            lr = (l.transpose(0, 1, 4, 2, 3)
                  .reshape(B, ns, 2, mm * c, Hkv, G)
                  .transpose(0, 1, 2, 4, 5, 3))
            orr = (o.transpose(0, 1, 4, 2, 3, 5)
                   .reshape(B, ns, 2, mm * c, Hkv, G, D)
                   .transpose(0, 1, 2, 4, 5, 3, 6))
            mu, lu, ou = _merge_stats(mr[:, :, 1], lr[:, :, 1], orr[:, :, 1],
                                      m2, l2, o2)
            mr = mr.at[:, :, 1].set(mu)
            lr = lr.at[:, :, 1].set(lu)
            orr = orr.at[:, :, 1].set(ou)
            m = mr.transpose(0, 1, 2, 5, 3, 4).reshape(B, n, c, Hkv, G) \
                  .transpose(0, 1, 3, 4, 2)
            l = lr.transpose(0, 1, 2, 5, 3, 4).reshape(B, n, c, Hkv, G) \
                  .transpose(0, 1, 3, 4, 2)
            o = orr.transpose(0, 1, 2, 5, 3, 4, 6).reshape(
                B, n, c, Hkv, G, D).transpose(0, 1, 3, 4, 2, 5)
        half //= 2
        level += 1

    lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B,n,Hkv,G,c)
    lse = lse.transpose(0, 2, 3, 1, 4).reshape(B, Hkv, G, S)
    o = o / jnp.maximum(l, 1e-30)[..., None]
    out = o.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, H, D)
    return out.astype(q.dtype), lse


def swa_attention(q, k, v, window: int, chunk: int = 256) -> jax.Array:
    """Sliding-window causal attention, banded schedule: O(S*(w+c)) compute
    and O(c*(w+c)) working set per scan step.

    Each q chunk of size c attends a contiguous padded-KV slice of w+c
    positions, so no quadratic masked waste (forward/prefill path; training
    SWA goes through the flash VJP with a window mask instead — see
    transformer._attention).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    w = min(window, S)
    c = pick_chunk(S, min(chunk, w))
    if w % c or S % c:
        # misaligned: fall back to masked chunked attention
        return chunked_attention(q, k, v, q_chunk=min(chunk, S),
                                 kv_chunk=min(chunk, S), window=window)
    b = w // c                       # kv chunks of history per q chunk
    nq = S // c
    with jax.named_scope("swa_attention"):
        kp = jnp.concatenate(
            [jnp.zeros((B, w, Hkv, D), k.dtype), k], axis=1)
        vp = jnp.concatenate(
            [jnp.zeros((B, w, Hkv, D), v.dtype), v], axis=1)
        qr = q.reshape(B, nq, c, Hkv, G, D)
        qpos_rel = jnp.arange(c)
        kpos_rel = jnp.arange(w + c) - w
        mask0 = (kpos_rel[None, :] <= qpos_rel[:, None]) & \
                (kpos_rel[None, :] > qpos_rel[:, None] - w)

        def q_body(_, qi):
            q_blk = jax.lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
            start = qi * c
            k_blk = jax.lax.dynamic_slice_in_dim(kp, start, w + c, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, start, w + c, 1)
            # absolute kv positions: start - w + arange(w+c); mask out the
            # zero padding (positions < 0)
            valid = (start + kpos_rel) >= 0
            mask = mask0 & valid[None, :]
            s = _block_scores(q_blk, k_blk)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(q.dtype), v_blk)
            return None, o

        _, out = jax.lax.scan(q_body, None, jnp.arange(nq))
    # (nq, B, c, Hkv, G, D) -> (B, S, H, D)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, D)


def decode_attention(q, k_cache, v_cache, length) -> jax.Array:
    """q: (B,H,D); caches: (B,Smax,Hkv,D); length: scalar valid length.
    Returns (B,H,D)."""
    B, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    with jax.named_scope("decode_attention"):
        qr = q.reshape(B, Hkv, G, D)
        scale = D ** -0.5
        s = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache,
                       preferred_element_type=jnp.float32) * scale
        valid = jnp.arange(k_cache.shape[1]) < length
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # accumulate in fp32 WITHOUT materializing an fp32 copy of the
        # (B, Smax, Hkv, D) cache — the explicit astype was 1.6 GB/layer of
        # pure convert traffic on llama4 decode_32k (§Perf B2)
        out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)


def attention_block(params, x, positions, cfg, *, layer_window: int = 0,
                    kv_cache: Optional[Tuple] = None,
                    cache_pos=None, q_chunk: int = 512, kv_chunk: int = 512,
                    schedule: str = "dense", use_kernel: bool = False):
    """Full attention sub-block.  Returns (y, new_kv_cache_entry).

    kv_cache: None for training; (k_cache, v_cache) of shape
    (B, Smax, Hkv, D) for serving.  For SWA layers the cache is a ring
    buffer of Smax == window slots.  cache_pos: absolute position of x[0].
    """
    B, S, d = x.shape
    q, k, v = project_qkv(params, x, cfg, positions)
    new_cache = None
    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        smax = k_cache.shape[1]
        if layer_window:
            # ring buffer: slot = absolute position mod window.  S == 1
            # (decode) inserts one slot; prefill with S % window == 0 fills
            # the ring exactly with the last `window` tokens.
            if S == 1:
                slot = jnp.asarray(cache_pos) % smax
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    k_cache, k.astype(k_cache.dtype), slot, 1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    v_cache, v.astype(v_cache.dtype), slot, 1)
            else:
                k_cache = k[:, -smax:].astype(k_cache.dtype)
                v_cache = v[:, -smax:].astype(v_cache.dtype)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), cache_pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), cache_pos, 1)
        new_cache = (k_cache, v_cache)
        if S == 1:  # decode
            length = jnp.minimum(jnp.asarray(cache_pos) + 1, smax) \
                if layer_window else jnp.asarray(cache_pos) + 1
            out = decode_attention(q[:, 0], k_cache, v_cache, length)[:, None]
        else:       # prefill
            if layer_window:
                out = swa_attention(q, k, v, layer_window)
            else:
                out = chunked_attention(
                    q, k_cache, v_cache, q_chunk=q_chunk, kv_chunk=kv_chunk,
                    q_offset=cache_pos, window=0, schedule=schedule)
    else:
        if use_kernel:
            from repro.kernels import ops as kernel_ops
            out = kernel_ops.flash_attention(q, k, v, causal=True,
                                             window=layer_window)
        else:
            # training: the flash VJP handles the window mask (banded SWA
            # is forward-only; its scan backward stores O(nq*nk) blocks)
            out = chunked_attention(q, k, v, q_chunk=q_chunk,
                                    kv_chunk=kv_chunk, window=layer_window,
                                    schedule=schedule)
    with jax.named_scope("o_proj"):
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache
