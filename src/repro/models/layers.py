"""Shared model building blocks: norms, rotary embeddings, gated MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    with jax.named_scope("rms_norm"):
        dt = x.dtype
        x = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables for rotary embedding.  positions: (...,S) int32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    with jax.named_scope("rope"):
        half = x.shape[-1] // 2
        x1, x2 = x[..., :half], x[..., half:]
        if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
            cos_ = cos[None, :, None, :]
            sin_ = sin[None, :, None, :]
        else:              # (B, S, half)
            cos_ = cos[:, :, None, :]
            sin_ = sin[:, :, None, :]
        cos_ = cos_.astype(x.dtype)
        sin_ = sin_.astype(x.dtype)
        return jnp.concatenate(
            [x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
           ) -> jax.Array:
    """Gated MLP: silu(x@w1) * (x@w3) @ w2."""
    with jax.named_scope("ffn"):
        g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w1))
        u = jnp.einsum("...d,df->...f", x, w3)
        return jnp.einsum("...f,fd->...d", g * u, w2)


def pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (trace-time helper)."""
    c = min(n, target)
    while n % c:
        c -= 1
    return c


def dense_init(key: jax.Array, shape, dtype, scale: float = 1.0) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)
