"""Kernel-granularity counter collection (paper §6).

On NVIDIA hardware the collector programs a counter group, (re)launches
the kernel, and reads the registers back.  The TPU/Pallas analogue has no
readable counter registers, so the *counter source* here is the same pair
of inputs the rest of this reproduction treats as ground truth about a
compiled kernel: ``compiled.cost_analysis()`` (XLA's per-device flop /
byte accounting) and the hpcstruct-analogue HLO structure parse
(``repro.core.structure``), which supplies trip-count scaling, the
read/write traffic split, collective wire bytes, and the roofline busy
-time model.  Per kernel *execution* the only dynamic input is the
measured wall time; everything else is a property of the compiled module,
so replay-mode readings are deterministic by construction — which is
exactly the property serialized replay has on real hardware, and what
tests/test_counters.py pins down.

Counter records ride the existing measurement path end-to-end: the
collector's reading is attached to the ``GpuActivity`` record the
dispatching application thread pushes onto its wait-free operation
channel, the monitor thread routes it back with the matched placeholder,
and attribution lands the vector in the CCT as the sparse ``gpu_counter``
metric kind (``core.metrics``) — no new queues, no locks, same SPSC
invariants (§4.1).
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from repro.core.metrics import GPU_COUNTER_METRICS
from repro.core.sampling import op_time_model
from repro.core.structure import HloModule, collective_bytes
from repro.counters.scheduler import MultiplexSchedule, build_schedule
from repro.counters.taxonomy import COUNTER_INDEX

_N = len(GPU_COUNTER_METRICS)
_I_ELAPSED = COUNTER_INDEX["elapsed_ns"]
_I_PASSES = COUNTER_INDEX["replay_passes"]
_I_ACTIVE = COUNTER_INDEX["active_ns"]

# pseudo-ops that are not executed instructions (mirrors sampling._NON_INST)
_NON_INST = frozenset({"parameter", "constant", "get-tuple-element", "tuple",
                       "bitcast", "after-all", "partition-id", "replica-id"})
_CONTROL = ("fusion", "call", "while", "conditional")


def _kstruct_totals(ks) -> tuple:
    """(flops, mxu_flops, transcendental_elems, n_inst, active_s) of one
    bound KernelStructure, cached on it (read() is per-dispatch)."""
    cached = getattr(ks, "_counter_totals", None)
    if cached is not None:
        return cached
    from repro.core.kstruct import _TRANSCENDENTAL
    kf = km = kt = ka = 0.0
    for lf in ks.leaves:
        kf += lf.flops
        ka += lf.weight
        op = lf.frames[-1].name
        if op == "dot_general":
            km += lf.flops
        elif op in _TRANSCENDENTAL:
            kt += lf.flops / 10.0    # kstruct weights transcendentals 10x
    totals = (kf, km, kt, float(len(ks.leaves)), ka)
    ks._counter_totals = totals
    return totals


def static_counters(module: HloModule,
                    cost: Optional[Dict[str, float]] = None) -> np.ndarray:
    """Per-execution counter values that depend only on the compiled
    module (cached on it): the raw-counter analogue of programming every
    domain's registers and running the kernel once.

    ``cost`` is ``compiled.cost_analysis()``; when given, its per-device
    flops/bytes are used as the calibrated totals (scaled by the parsed
    trip-count ratio, like ``roofline.analyze``), with the structure
    parse supplying everything cost_analysis does not report (the
    read/write split, collective wire bytes, op counts, busy time).
    """
    # cache keyed by the calibration input: the same module may be read
    # with and without a cost_analysis dict (tests do; tools could)
    ckey = (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0))) if cost else None
    cache = getattr(module, "_counter_cache", None)
    if cache is not None and cache[0] == ckey:
        return cache[1]

    vec = np.zeros(_N, np.float64)
    mults = module.comp_multipliers()
    fused = module.fused_comps()
    kstructs = module.kernel_structures() \
        if hasattr(module, "kernel_structures") else {}
    flops = mxu = transcendental = 0.0
    read_b = write_b = 0.0
    inst = active_s = 0.0
    for comp in module.computations.values():
        m = mults.get(comp.name, 1.0)
        in_hbm = comp.name not in fused
        for op in comp.ops:
            if op.opcode not in _CONTROL:
                flops += op.flops * m
                if op.opcode in ("dot", "convolution"):
                    mxu += op.flops * m
                if op.opcode in ("exponential", "tanh", "log", "rsqrt",
                                 "sqrt", "power", "logistic", "sine",
                                 "cosine"):
                    transcendental += op.out_elems * m
            if in_hbm:
                write_b += op.out_bytes * m
                read_b += (op.bytes - op.out_bytes) * m
            if op.opcode not in _NON_INST:
                inst += m
                t = op_time_model(op)
                active_s += max(t.values()) * m
            ks = kstructs.get(op.index)
            if ks is not None:
                # kernel-interior refinement (repro.core.kstruct): a
                # bound Pallas kernel parses as an opaque custom-call
                # with zero flops; its recovered leaves supply the
                # interior-granularity compute/instruction totals the
                # HLO text cannot see.  HBM traffic stays with the
                # custom-call's own operand/result accounting (interior
                # get/swap traffic is VMEM, not HBM).
                kf, km, kt, ki, ka = _kstruct_totals(ks)
                flops += kf * m
                mxu += km * m
                transcendental += kt * m
                inst += ki * m
                active_s += ka * m

    scale_f = scale_b = 1.0
    if cost:
        fr, br = module.cost_scale()
        ca_flops = float(cost.get("flops", 0.0)) * fr
        ca_bytes = float(cost.get("bytes accessed", 0.0)) * br
        if flops > 0 and ca_flops > 0:
            scale_f = ca_flops / flops
        total_b = read_b + write_b
        if total_b > 0 and ca_bytes > 0:
            scale_b = ca_bytes / total_b

    coll = collective_bytes(module)
    n_coll = sum(max(mults.get(op.comp, 1.0), 1.0)
                 for op in module.collective_ops())

    idx = COUNTER_INDEX
    vec[idx["flops"]] = flops * scale_f
    vec[idx["mxu_flops"]] = mxu * scale_f
    vec[idx["transcendental_ops"]] = transcendental
    vec[idx["hbm_read_bytes"]] = read_b * scale_b
    vec[idx["hbm_write_bytes"]] = write_b * scale_b
    vec[idx["hbm_bytes"]] = (read_b + write_b) * scale_b
    vec[idx["ici_wire_bytes"]] = coll["wire_bytes"]
    vec[idx["collective_invocations"]] = n_coll
    vec[idx["inst_executed"]] = inst
    vec[idx["active_ns"]] = active_s * 1e9
    module._counter_cache = (ckey, vec)
    return vec


class CounterCollector:
    """Per-profiler counter measurement state.

    ``replay=True`` (the paper's serialized replay): every kernel
    execution is measured ``schedule.n_passes`` times, once per counter
    group, so every requested counter is read on every execution and
    totals are deterministic.

    ``replay=False`` (single-pass best effort): one group per kernel
    invocation, rotated round-robin, each reading scaled by the group
    count so totals are unbiased estimates — and exactly equal to the
    replay totals whenever the invocation count is a multiple of the
    group count and executions are identical (or the set is not
    multiplexed at all).
    """

    def __init__(self, counters: Iterable[str], *, replay: bool = True):
        self.schedule: MultiplexSchedule = build_schedule(counters)
        self.replay = replay
        self._invocation = itertools.count()
        # kind-local index arrays per group (precomputed gather masks).
        # The tool-domain "free" counters (elapsed_ns, replay_passes) are
        # dynamic per-execution bookkeeping, filled explicitly in read().
        self._group_idx = [
            np.array([COUNTER_INDEX[c] for c in g.counters], np.int64)
            for g in self.schedule.groups]

    def read(self, module: HloModule, duration_ns: int,
             cost: Optional[Dict[str, float]] = None) -> np.ndarray:
        """One kernel execution's counter reading: a dense vector in
        ``GPU_COUNTER_METRICS`` order (zeros for counters not collected
        this invocation)."""
        static = static_counters(module, cost)
        vec = np.zeros(_N, np.float64)
        if self.replay:
            for gidx in self._group_idx:
                vec[gidx] = static[gidx]
            passes = self.schedule.n_passes
        else:
            g = next(self._invocation)
            if self._group_idx:
                gidx = self._group_idx[g % len(self._group_idx)]
                vec[gidx] = static[gidx] * len(self._group_idx)
            passes = 1
        vec[_I_ELAPSED] = float(duration_ns)
        vec[_I_PASSES] = float(passes)
        return vec
