"""Backend-neutral hardware-counter taxonomy (paper §6; THAPI
arXiv:2504.03683 motivates one uniform counter vocabulary across
heterogeneous backends).

On NVIDIA GPUs HPCToolkit collects kernel-granularity counters through
CUPTI's profiling API: each *counter* is sourced by one hardware *domain*
(SM, L2, DRAM, NVLink, ...), and each domain has a small number of
physical counter registers, so a request that exceeds a domain's register
budget must be split into *groups* collected over multiple passes
(serialized kernel replay, or statistical multiplexing across
invocations).  PAPI exposes the same model one level up.

This module is the backend-neutral half of that design: a catalog of
named counters, each tagged with the domain that sources it, the
per-domain register capacities, and units/descriptions for reporting.
The TPU/Pallas *backend* half (how a counter value is actually produced
from ``compiled.cost_analysis()`` + the HLO structure parse) lives in
``repro.counters.collector``; the group packing lives in
``repro.counters.scheduler``.

The counter *names* double as the member metrics of the ``gpu_counter``
metric kind (``repro.core.metrics.GPU_COUNTER_METRICS``) so that counter
values land in profiles as one more sparse kind and survive aggregation
unchanged; the catalog validates itself against that tuple at import
time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Tuple

from repro.core.metrics import GPU_COUNTER_KIND, GPU_COUNTER_METRICS

# The tool domain is never multiplexed: its "counters" (elapsed time,
# replay bookkeeping) are available on every pass for free.
TOOL_DOMAIN = "tool"


@dataclasses.dataclass(frozen=True)
class Counter:
    """One catalog entry: a backend-neutral counter name plus the
    hardware domain whose registers source it."""
    name: str
    domain: str
    unit: str
    description: str

    @property
    def schedulable(self) -> bool:
        return self.domain != TOOL_DOMAIN


# Physical counter registers per domain and pass — the constraint the
# group scheduler packs against.  (CUPTI exposes exactly this shape:
# ``maxEventsPerGroup`` per domain.)
DOMAIN_CAPACITY: Dict[str, int] = {
    "compute": 2,
    "memory": 2,
    "collective": 1,
    "scheduler": 2,
    TOOL_DOMAIN: 1 << 30,
}

_CATALOG_ROWS: Tuple[Tuple[str, str, str, str], ...] = (
    ("flops", "compute", "flop",
     "floating-point operations executed (trip-count scaled)"),
    ("mxu_flops", "compute", "flop",
     "matrix-unit flops (dot/convolution ops)"),
    ("transcendental_ops", "compute", "op",
     "transcendental-function element evaluations"),
    ("hbm_read_bytes", "memory", "byte",
     "bytes read from device memory (operand traffic)"),
    ("hbm_write_bytes", "memory", "byte",
     "bytes written to device memory (result traffic)"),
    ("hbm_bytes", "memory", "byte",
     "total device-memory traffic (read + write)"),
    ("ici_wire_bytes", "collective", "byte",
     "bytes crossing the interconnect (ring-model wire bytes)"),
    ("collective_invocations", "collective", "op",
     "collective operations executed"),
    ("inst_executed", "scheduler", "inst",
     "executed 'instructions' (HLO ops, trip-count scaled)"),
    ("active_ns", "scheduler", "ns",
     "modeled busy time (roofline max-term per op, summed)"),
    ("elapsed_ns", TOOL_DOMAIN, "ns",
     "kernel wall time (always collected)"),
    ("replay_passes", TOOL_DOMAIN, "pass",
     "measurement passes taken for this kernel execution"),
)

CATALOG: Dict[str, Counter] = {
    name: Counter(name, domain, unit, desc)
    for name, domain, unit, desc in _CATALOG_ROWS
}

# kind-local index of every counter, in GPU_COUNTER_METRICS order
COUNTER_INDEX: Dict[str, int] = {n: i
                                 for i, n in enumerate(GPU_COUNTER_METRICS)}

assert tuple(CATALOG) == GPU_COUNTER_METRICS, \
    "counter catalog out of sync with metrics.GPU_COUNTER_METRICS"
assert all(c.domain in DOMAIN_CAPACITY for c in CATALOG.values())

ALL_COUNTERS: Tuple[str, ...] = tuple(CATALOG)
KIND_NAME = GPU_COUNTER_KIND


def resolve(names: Iterable[str]) -> List[Counter]:
    """Validate and resolve counter names (order-preserving, deduped)."""
    out: List[Counter] = []
    seen = set()
    for n in names:
        if n not in CATALOG:
            raise KeyError(f"unknown counter {n!r}; catalog: "
                           f"{', '.join(ALL_COUNTERS)}")
        if n not in seen:
            seen.add(n)
            out.append(CATALOG[n])
    return out


def describe() -> str:
    """Aligned text catalog (used by docs/examples)."""
    w = max(len(c.name) for c in CATALOG.values())
    lines = []
    for c in CATALOG.values():
        cap = DOMAIN_CAPACITY[c.domain]
        cap_s = "free" if not c.schedulable else f"cap={cap}"
        lines.append(f"{c.name:<{w}}  {c.domain:<10} {cap_s:<6} "
                     f"[{c.unit}] {c.description}")
    return "\n".join(lines)
