"""Counter group scheduling (paper §6; CUPTI/PAPI multiplexing model).

A request for counters that exceeds some hardware domain's register
budget cannot be satisfied in one pass.  The scheduler packs the
requested counters into *compatible groups* — each group fits every
domain's per-pass capacity — and the collector then either

- **replays** the kernel once per group (the paper's serialized kernel
  replay: deterministic, every counter measured on every kernel
  execution), or
- **multiplexes** groups round-robin across successive kernel
  invocations in single-pass best-effort mode, scaling each reading by
  the group count so long-run totals remain unbiased estimates of the
  replay totals (the PAPI multiplexing convention).

Packing is first-fit in request order, which is deterministic and
optimal for per-domain capacities: the number of groups equals
``max_d ceil(n_requested_in_domain_d / capacity_d)`` (asserted by
tests/test_counters.py), so every requested counter is covered in at
most that many passes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.counters.taxonomy import (Counter, DOMAIN_CAPACITY, resolve)


@dataclasses.dataclass(frozen=True)
class CounterGroup:
    """One compatible set: collectible together in a single pass."""
    index: int
    counters: Tuple[str, ...]

    def __len__(self) -> int:
        return len(self.counters)


@dataclasses.dataclass(frozen=True)
class MultiplexSchedule:
    """The pass plan for one request."""
    requested: Tuple[str, ...]          # schedulable counters, request order
    free: Tuple[str, ...]               # tool-domain: collected every pass
    groups: Tuple[CounterGroup, ...]

    @property
    def n_passes(self) -> int:
        """Replay passes needed to cover every requested counter."""
        return max(len(self.groups), 1)

    @property
    def multiplexed(self) -> bool:
        return len(self.groups) > 1

    def group_for(self, invocation: int) -> CounterGroup:
        """Round-robin group for the i-th kernel invocation
        (single-pass best-effort mode)."""
        if not self.groups:
            return CounterGroup(0, ())
        return self.groups[invocation % len(self.groups)]

    def coverage(self) -> frozenset:
        out = set(self.free)
        for g in self.groups:
            out.update(g.counters)
        return frozenset(out)

    def describe(self) -> str:
        lines = [f"schedule: {len(self.requested)} counters -> "
                 f"{len(self.groups)} group(s), {self.n_passes} pass(es)"]
        for g in self.groups:
            lines.append(f"  pass {g.index}: {', '.join(g.counters)}")
        if self.free:
            lines.append(f"  every pass: {', '.join(self.free)}")
        return "\n".join(lines)


def build_schedule(names: Iterable[str],
                   capacity: Dict[str, int] = DOMAIN_CAPACITY
                   ) -> MultiplexSchedule:
    """Pack requested counters into compatible groups (first-fit in
    request order against per-domain capacities)."""
    counters = resolve(names)
    free = tuple(c.name for c in counters if not c.schedulable)
    sched = [c for c in counters if c.schedulable]

    packs: List[List[Counter]] = []
    remaining: List[Dict[str, int]] = []    # per group: domain -> left
    for c in sched:
        for gi, left in enumerate(remaining):
            if left.get(c.domain, capacity.get(c.domain, 1)) > 0:
                left[c.domain] = left.get(
                    c.domain, capacity.get(c.domain, 1)) - 1
                packs[gi].append(c)
                break
        else:
            packs.append([c])
            remaining.append(
                {c.domain: capacity.get(c.domain, 1) - 1})

    groups = tuple(CounterGroup(i, tuple(c.name for c in pack))
                   for i, pack in enumerate(packs))
    return MultiplexSchedule(tuple(c.name for c in sched), free, groups)


def optimal_passes(names: Sequence[str],
                   capacity: Dict[str, int] = DOMAIN_CAPACITY) -> int:
    """Lower bound on passes: the tightest domain's ceil(n / cap).
    First-fit meets this bound (test_counters asserts equality)."""
    per_domain: Dict[str, int] = {}
    for c in resolve(names):
        if c.schedulable:
            per_domain[c.domain] = per_domain.get(c.domain, 0) + 1
    if not per_domain:
        return 1
    return max(-(-n // capacity.get(d, 1)) for d, n in per_domain.items())
