"""Kernel-granularity hardware-counter measurement (paper §6).

The paper supplements fine-grained PC sampling with *hardware performance
counters* read at kernel granularity.  This package is that measurement
mode for the JAX/Pallas stack:

- ``taxonomy``  — backend-neutral counter catalog + hardware domains and
  per-domain register capacities (THAPI-style uniform vocabulary);
- ``scheduler`` — packs requested counters into compatible groups and
  plans serialized-replay or round-robin multiplex passes (CUPTI/PAPI);
- ``collector`` — produces per-kernel-execution counter readings from
  ``compiled.cost_analysis()`` + the HLO structure parse, riding the
  existing wait-free activity channels into the CCT as the sparse
  ``gpu_counter`` metric kind.

Typical flow::

    prof = Profiler(out_dir)
    prof.enable_counters(["flops", "hbm_bytes", "active_ns"])  # replay
    mid = prof.register_module("step", compiled.as_text(),
                               cost=compiled.cost_analysis())
    with prof, prof.dispatch("kernel", "step", module_id=mid):
        step(...)

then aggregate as usual; ``viewer.counter_table`` and
``traceview.stats.top_kernel_counters`` surface the derived columns
(``core.derived``: achieved occupancy, flop efficiency, bytes/flop,
replay passes).  See docs/counters.md.
"""
from repro.counters.collector import CounterCollector, static_counters
from repro.counters.scheduler import (CounterGroup, MultiplexSchedule,
                                      build_schedule, optimal_passes)
from repro.counters.taxonomy import (ALL_COUNTERS, CATALOG, COUNTER_INDEX,
                                     Counter, DOMAIN_CAPACITY, KIND_NAME,
                                     describe, resolve)

__all__ = [
    "Counter", "CATALOG", "ALL_COUNTERS", "COUNTER_INDEX",
    "DOMAIN_CAPACITY", "KIND_NAME", "describe", "resolve",
    "CounterGroup", "MultiplexSchedule", "build_schedule", "optimal_passes",
    "CounterCollector", "static_counters",
]
