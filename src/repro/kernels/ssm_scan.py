"""Chunkwise-parallel selective-SSM (SSD) scan as a Pallas TPU kernel.

TPU-native adaptation of the Mamba-2 SSD chunked algorithm: a GPU
implementation leans on warp-level scan primitives; on TPU the profitable
decomposition is three MXU matmuls per chunk plus an O(1) state carry:

    intra:  y_intra = (tril(exp(cum_t - cum_tau)) * (C B^T)) @ X
    inter:  y_inter = (C * exp(cum)) @ h^T
    state:  h <- exp(total) * h + X^T @ (B * exp(total - cum))

Grid: (B, nh, n_chunks) with the chunk dimension innermost — TPU executes
the grid sequentially, so the (hd, st) fp32 state lives in VMEM scratch
across chunk steps (the same carry idiom as the flash kernel's (m, l, acc)).

Blocks: X (1, c, 1, hd) value chunk, logdecay (1, c, 1), B/C (1, c, st) —
B/C index maps ignore the head grid index (B/C are shared across heads,
ngroups=1).  VMEM per step ~ c*(hd + 2*st + 1)*4B + c*c*4B: at c = 256,
hd = 64, st = 128 that is ~0.6 MB.

The kernel computes the *forward*; ops.py wires a custom VJP whose backward
differentiates the pure-jnp chunked reference (the recompute-from-chunks
trick, O(S) memory).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, ld_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                h_scr, *, c: int, n: int, with_h0: bool):
    """One (b, h, chunk) grid cell; chunk innermost/sequential."""
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        if with_h0:
            h_scr[...] = h0_ref[0, 0].astype(jnp.float32)
        else:
            h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (c, hd)
    ld = ld_ref[0, :, 0].astype(jnp.float32)           # (c,)
    Bm = b_ref[0].astype(jnp.float32)                  # (c, st)
    Cm = c_ref[0].astype(jnp.float32)                  # (c, st)

    cum = jnp.cumsum(ld)                               # (c,)
    total = cum[-1]

    # ---- intra-chunk: masked decaying linear attention -----------------
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    dec = cum[:, None] - cum[None, :]                  # (t, tau)
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    g = jnp.where(row >= col, jnp.exp(dec), 0.0) * cb
    y = jax.lax.dot_general(g, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c, hd)

    # ---- inter-chunk: contribution of the carried state -----------------
    h = h_scr[...]                                     # (hd, st)
    cw = Cm * jnp.exp(cum)[:, None]                    # (c, st)
    y = y + jax.lax.dot_general(cw, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # ---- state update ----------------------------------------------------
    bw = Bm * jnp.exp(total - cum)[:, None]            # (c, st)
    dh = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (hd, st)
    h_scr[...] = h * jnp.exp(total) + dh

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n - 1)
    def _finish():
        hout_ref[0, 0] = h_scr[...]


def ssm_scan_fwd(xv: jax.Array, logdecay: jax.Array, Bmat: jax.Array,
                 Cmat: jax.Array, h0: Optional[jax.Array] = None, *,
                 chunk: int = 256, interpret: bool = False):
    """xv: (B,S,nh,hd); logdecay: (B,S,nh); Bmat/Cmat: (B,S,st);
    h0: (B,nh,hd,st) or None.  Returns (y (B,S,nh,hd), h_fin fp32)."""
    B, S, nh, hd = xv.shape
    st = Bmat.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    n = S // c
    with_h0 = h0 is not None
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, st), jnp.float32)

    grid = (B, nh, n)
    kern = functools.partial(_ssd_kernel, c=c, n=n, with_h0=with_h0)
    y, h_fin = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, 1, hd), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, c, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, c, st), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, c, st), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, hd, st), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, 1, hd), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, hd, st), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hd), xv.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, st), jnp.float32),
        ],
        scratch_shapes=[_vmem((hd, st))],
        interpret=interpret,
    )(xv, logdecay, Bmat, Cmat, h0)
    return y, h_fin


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# kstruct annotation: grid (B, nh, n_chunks); the chunk axis is the
# sequential scan loop carrying the (hd, st) state scratch
KSTRUCT_GRID_LOOPS = {2: "chunks"}


def kernel_structure(*, chunk: int = 128):
    """Recover this kernel's interior structure (repro.core.kstruct)."""
    from repro.core.kstruct import KernelStructure
    xv = jnp.zeros((1, 2 * chunk, 2, 64), jnp.bfloat16)
    ld = jnp.zeros((1, 2 * chunk, 2), jnp.float32)
    Bm = jnp.zeros((1, 2 * chunk, 64), jnp.bfloat16)
    return KernelStructure.from_function(
        ssm_scan_fwd, xv, ld, Bm, Bm, name="ssm_scan",
        grid_loops=KSTRUCT_GRID_LOOPS, chunk=chunk, interpret=True)
