"""Flash attention as a Pallas TPU kernel (forward).

TPU-native adaptation (DESIGN.md §2 hardware-adaptation notes): instead of
a CUDA warp-level softmax, the kernel tiles (q-block x kv-block) into VMEM
via BlockSpecs, runs the online-softmax update on the MXU with fp32
accumulator scratch, and walks kv blocks on the *innermost grid dimension*
(sequentially executed on TPU) so the running (m, l, acc) state lives in
VMEM scratch across grid steps — the canonical TPU flash schedule.

Grid: (B, Hkv, G, nq, nk), nk innermost/sequential.
Blocks: q (1,1,1,bq,D), k/v (1,1,bk,D) with the kv index map collapsing the
G grouped-query dimension (GQA: G q-heads share one kv head).  D is the
full head dim (<= 256 fits VMEM comfortably at bq = bk = 128/256:
bq*D + 2*bk*D + bq*bk fp32 ~ 0.5 MB).

Causal masking: blocks strictly above the diagonal are skipped with
``pl.when`` (no MXU work issued); the diagonal block applies the triangular
mask.  ``window`` adds a sliding-window lower bound (SWA layers).

The backward pass is the O(S)-memory block-recompute VJP already used by
``models.attention`` (ops.py wires it via jax.custom_vjp) — the hot spot
the paper-style profile attributes >90% of training step samples to is the
forward+recompute matmuls, which is exactly what this kernel owns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      bq: int, bk: int, nk: int, causal: bool, window: int,
                      q_offset: int):
    """One (b, hkv, g, qi, ki) grid cell."""
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this block's rows/cols
    q_lo = q_offset + qi * bq           # first q row's absolute position
    k_lo = ki * bk

    # causal block skip: the whole kv block is in the future of the whole
    # q block  <=>  k_lo > q_lo + bq - 1
    run = jnp.bool_(True)
    if causal:
        run &= k_lo <= q_lo + bq - 1
    if window:
        # whole kv block is below the window of the last q row
        run &= k_lo + bk - 1 > q_lo - window

    @pl.when(run)
    def _block():
        q = q_ref[0, 0, 0].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (bq, bk)
        s *= q.shape[-1] ** -0.5
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        if window:
            s = jnp.where(kpos > qpos - window, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 256, block_kv: int = 256,
                        q_offset: int = 0,
                        interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, Sk, Hkv, D).  Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_kv, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, bq, Sk, bk)
    nq, nk = S // bq, Sk // bk

    # layout: heads-major so the last two dims of every block are the MXU
    # tile (seq, head_dim)
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hkv, G, S, D)
    kh = k.transpose(0, 2, 1, 3)                    # (B, Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3)

    grid = (B, Hkv, G, nq, nk)
    kern = functools.partial(
        _flash_fwd_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
        window=window, q_offset=q_offset)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, D),
                         lambda b, h, g, qi, ki: (b, h, g, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, g, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, g, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, D),
                               lambda b, h, g, qi, ki: (b, h, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1)),       # running row max m
            _vmem((bq, 1)),       # running denominator l
            _vmem((bq, D)),       # fp32 output accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _vmem(shape):
    """VMEM fp32 scratch spec."""
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# kstruct annotation: the innermost grid axis (ki over kv blocks) is
# sequential on TPU — it is the kernel's outer loop, carrying the
# (m, l, acc) online-softmax scratch across steps
KSTRUCT_GRID_LOOPS = {4: "kv_blocks"}


def kernel_structure(*, block_q: int = 128, block_kv: int = 128):
    """Recover this kernel's interior structure (repro.core.kstruct §5
    analogue) by tracing the wrapper at a small representative shape.
    The recovered loop/scope/line tree is shape-independent — only leaf
    weights scale — so one trace serves every deployment shape."""
    from repro.core.kstruct import KernelStructure
    q = jnp.zeros((1, 2 * block_q, 2, 64), jnp.bfloat16)
    kv = jnp.zeros((1, 2 * block_q, 1, 64), jnp.bfloat16)
    return KernelStructure.from_function(
        flash_attention_fwd, q, kv, kv, name="flash_attention",
        grid_loops=KSTRUCT_GRID_LOOPS, causal=True, block_q=block_q,
        block_kv=block_kv, interpret=True)
