"""Pure-jnp oracles for the Pallas kernels.

Deliberately naive (full-softmax attention; per-timestep sequential SSM
scan): these are the ground truth the kernels must match in interpret mode,
per-shape/per-dtype, in tests/test_kernels.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Full materialized-softmax GQA attention.

    q: (B, S, H, D); k/v: (B, Sk, Hkv, D); H % Hkv == 0.
    Returns (B, S, H, D) in q.dtype.
    """
    B, S, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qr = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= kpos <= qpos + (Sk - S)
    if window:
        mask &= kpos > qpos + (Sk - S) - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def ssm_scan_ref(xv: jax.Array, logdecay: jax.Array, Bmat: jax.Array,
                 Cmat: jax.Array, h0: Optional[jax.Array] = None):
    """Sequential (per-timestep) selective-SSM scan, SSD convention.

    xv:       (B, S, nh, hd)   values (dt folded in)
    logdecay: (B, S, nh)       log decay per step (<= 0)
    Bmat:     (B, S, st)       input projection (shared across heads)
    Cmat:     (B, S, st)       output projection
    h0:       (B, nh, hd, st)  initial state or None

    h[t] = exp(logdecay[t]) * h[t-1] + outer(xv[t], B[t])
    y[t] = h[t] @ C[t]
    Returns (y (B,S,nh,hd) in xv.dtype, h_final (B,nh,hd,st) fp32).
    """
    B, S, nh, hd = xv.shape
    st = Bmat.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, st), jnp.float32)

    def step(h, inputs):
        x_t, ld_t, b_t, c_t = inputs
        h = h * jnp.exp(ld_t.astype(jnp.float32))[:, :, None, None]
        h = h + jnp.einsum("bhd,bs->bhds", x_t.astype(jnp.float32),
                           b_t.astype(jnp.float32))
        y = jnp.einsum("bhds,bs->bhd", h, c_t.astype(jnp.float32))
        return h, y

    h_fin, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xv.transpose(1, 0, 2, 3), logdecay.transpose(1, 0, 2),
         Bmat.transpose(1, 0, 2), Cmat.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3).astype(xv.dtype)
    return y, h_fin


def mlstm_ref(q, k, v, ig, fg, state=None):
    """Sequential mLSTM oracle (normalizer-augmented state), matching
    models.xlstm semantics.  q/k: (B,S,nh,dqk); v: (B,S,nh,dv);
    ig/fg: (B,S,nh) raw gate pre-activations."""
    from repro.models.xlstm import mlstm_decode
    B, S, nh, dqk = q.shape
    dv = v.shape[-1]
    if state is None:
        H0 = jnp.zeros((B, nh, dqk, dv + 1), jnp.float32)
        m0 = jnp.full((B, nh), -jnp.inf, jnp.float32)
        state = (H0, m0)

    def step(st, inputs):
        q_t, k_t, v_t, i_t, f_t = inputs
        h, st = mlstm_decode(q_t, k_t, v_t, i_t, f_t, st)
        return st, h

    state, hs = jax.lax.scan(
        step, state,
        (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
         fg.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2, 3), state
