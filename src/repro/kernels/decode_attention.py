"""Flash-decode attention as a Pallas TPU kernel.

The serving hot spot: one query token per sequence against a long KV
cache.  There is no parallelism in the q dimension (S_q = 1), so the TPU
schedule parallelizes over the *cache sequence*: the grid walks kv blocks
on its innermost (sequential) dimension carrying (m, l, acc) online-softmax
scratch in VMEM — the split-KV half of "flash decoding", with the final
merge happening in the same carry (TPU grids execute sequentially, so no
separate reduction kernel is needed).

GQA layout: one grid cell covers ALL G grouped q-heads of one kv head —
q block (G, D) x kv block (bk, D) keeps the MXU busy with a (G x bk)
score tile instead of G separate (1 x bk) vector products.

Length masking: positions >= ``length`` (the current cache fill) are
masked with -inf before the online-softmax update; whole blocks beyond
``length`` are skipped with ``pl.when`` (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, bk: int, nk: int):
    """Grid (B, Hkv, nk); nk innermost/sequential."""
    ki = pl.program_id(2)
    length = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_lo = ki * bk

    @pl.when(k_lo < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)    # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (G, bk)
        s *= q.shape[-1] ** -0.5
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]                          # (G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_fwd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length, *, block_kv: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k/v_cache: (B, Smax, Hkv, D); length: scalar int32
    valid cache length.  Returns (B, H, D)."""
    B, H, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    bk = min(block_kv, Smax)
    assert Smax % bk == 0, (Smax, bk)
    nk = Smax // bk
    qh = q.reshape(B, Hkv, G, D)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kern = functools.partial(_decode_kernel, bk=bk, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (0,)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[_vmem((G, 1)), _vmem((G, 1)), _vmem((G, D))],
        interpret=interpret,
    )(length, qh, k_cache, v_cache)
    return out.reshape(B, H, D)


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


# kstruct annotation: grid (B, Hkv, nk); ki over kv-cache blocks is the
# sequential split-KV loop carrying the online-softmax scratch
KSTRUCT_GRID_LOOPS = {2: "kv_blocks"}


def kernel_structure(*, block_kv: int = 512):
    """Recover this kernel's interior structure (repro.core.kstruct)."""
    from repro.core.kstruct import KernelStructure
    q = jnp.zeros((1, 4, 64), jnp.bfloat16)
    cache = jnp.zeros((1, 2 * block_kv, 2, 64), jnp.bfloat16)
    return KernelStructure.from_function(
        flash_decode_fwd, q, cache, cache, block_kv,
        name="decode_attention", grid_loops=KSTRUCT_GRID_LOOPS,
        block_kv=block_kv, interpret=True)
