# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Each kernel module exports ``kernel_structure()`` — the recovered
kstruct interior (loops / inlined scopes / source lines) that
``Profiler.register_kernel_structures`` binds to the kernel's
``custom-call`` HLO op for fine-grained PC-sample attribution."""

_KSTRUCT_CACHE = None


def kernel_structures():
    """Recover (and cache) the interior structures of all three Pallas
    kernels.  Tracing needs jax; callers on jax-less hosts should catch
    ImportError."""
    global _KSTRUCT_CACHE
    if _KSTRUCT_CACHE is None:
        from repro.kernels import (decode_attention, flash_attention,
                                   ssm_scan)
        _KSTRUCT_CACHE = (flash_attention.kernel_structure(),
                          decode_attention.kernel_structure(),
                          ssm_scan.kernel_structure())
    return _KSTRUCT_CACHE
