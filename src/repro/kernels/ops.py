"""jit'd public wrappers around the Pallas kernels.

- interpret mode is selected automatically off-TPU (this container is
  CPU-only: kernels execute via the Pallas interpreter, which runs the
  kernel body in Python and validates the BlockSpec tiling/index maps).
- both wrappers are differentiable: forward = Pallas kernel, backward =
  O(S)-memory block-recompute VJP expressed in pure jnp (the flash trick;
  on TPU the backward would be a second Pallas kernel with the same
  schedule transposed).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _fd
from repro.kernels import flash_attention as _fa
from repro.kernels import ssm_scan as _ss


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ===========================================================================
# flash attention
# ===========================================================================
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_kv: int = 256):
    """q: (B,S,H,D); k/v: (B,Sk,Hkv,D) -> (B,S,H,D).  Causal (+optional
    sliding window) GQA attention; Pallas forward, custom VJP backward.
    Public wrapper (jax.custom_vjp takes positional args only)."""
    bq = min(block_q, q.shape[1])
    bk = min(block_kv, k.shape[1])
    return _flash_cv(q, k, v, bool(causal), int(window), bq, bk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_cv(q, k, v, causal, window, block_q, block_kv):
    return _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=_use_interpret())


def _flash_vjp_fwd(q, k, v, causal, window, block_q, block_kv):
    out = _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=_use_interpret())
    return out, (q, k, v)


def _flash_vjp_bwd(causal, window, block_q, block_kv, res, dout):
    q, k, v = res
    # O(S)-memory block-recompute backward (jnp; runs through XLA fusion)
    from repro.models.attention import chunked_attention
    _, vjp = jax.vjp(
        lambda q_, k_, v_: chunked_attention(
            q_, k_, v_, q_chunk=block_q, kv_chunk=block_kv,
            window=window),
        q, k, v)
    return vjp(dout)


_flash_cv.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_decode(q, k_cache, v_cache, length, block_kv: int = 512):
    """One-token decode attention against a KV cache (B,H,D) x
    (B,Smax,Hkv,D) -> (B,H,D).  Inference-only (no VJP needed)."""
    return _fd.flash_decode_fwd(q, k_cache, v_cache, length,
                                block_kv=block_kv,
                                interpret=_use_interpret())


# ===========================================================================
# selective-SSM / SSD scan
# ===========================================================================
def ssm_scan(xv, logdecay, Bmat, Cmat, h0=None, chunk: int = 256):
    """Chunkwise SSD scan; Pallas forward, custom VJP backward.
    Returns (y (B,S,nh,hd), h_final (B,nh,hd,st) fp32).  Public wrapper
    (jax.custom_vjp takes positional args only)."""
    c = min(chunk, xv.shape[1])
    return _ssm_cv(xv, logdecay, Bmat, Cmat, h0, c)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssm_cv(xv, logdecay, Bmat, Cmat, h0, chunk):
    return _ss.ssm_scan_fwd(xv, logdecay, Bmat, Cmat, h0, chunk=chunk,
                            interpret=_use_interpret())


def _ssm_vjp_fwd(xv, logdecay, Bmat, Cmat, h0, chunk):
    out = _ss.ssm_scan_fwd(xv, logdecay, Bmat, Cmat, h0, chunk=chunk,
                           interpret=_use_interpret())
    return out, (xv, logdecay, Bmat, Cmat, h0)


def _ssm_vjp_bwd(chunk, res, cotangents):
    xv, logdecay, Bmat, Cmat, h0 = res
    from repro.models.ssm import ssd_chunked

    def ref(xv_, ld_, b_, c_, h0_):
        return ssd_chunked(xv_, ld_, b_, c_, chunk=chunk, h0=h0_)

    if h0 is None:
        B, S, nh, hd = xv.shape
        st = Bmat.shape[-1]
        h0_z = jnp.zeros((B, nh, hd, st), jnp.float32)
        _, vjp = jax.vjp(lambda a, b, c, d: ref(a, b, c, d, h0_z),
                         xv, logdecay, Bmat, Cmat)
        dxv, dld, dB, dC = vjp(cotangents)
        return dxv, dld, dB, dC, None
    _, vjp = jax.vjp(ref, xv, logdecay, Bmat, Cmat, h0)
    return vjp(cotangents)


_ssm_cv.defvjp(_ssm_vjp_fwd, _ssm_vjp_bwd)
