"""Step functions lowered by the dry-run and executed by the drivers."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingPlan
from repro.models import transformer as T
from repro.optim import adamw
from repro.distributed import compression as comp_mod


def make_train_step(cfg: ModelConfig, plan: Optional[ShardingPlan],
                    opts: T.ModelOptions, opt_cfg: adamw.OptConfig,
                    grad_compression: bool = False,
                    n_microbatches: int = 1):
    mesh_args = plan.moe_args() if plan is not None else None

    def lf(p, b):
        return T.loss_fn(p, cfg, b, mesh_args=mesh_args, opts=opts)

    def finish(params, opt_state, loss, metrics, grads):
        if grad_compression:
            with jax.named_scope("grad_compression"):
                grads = comp_mod.ef_compress_tree(grads)
        with jax.named_scope("optimizer"):
            new_p, new_o, om = adamw.update(opt_cfg, grads, opt_state,
                                            params)
        out_metrics = {"loss": loss, **metrics, **om}
        return new_p, new_o, out_metrics

    def train_step(params, opt_state, batch):
        with jax.named_scope("fwd_bwd"):
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        return finish(params, opt_state, loss, metrics, grads)

    def train_step_micro(params, opt_state, batch):
        """Gradient accumulation over n_microbatches (peak-memory lever:
        activations scale with B/n_microbatches; §Perf A6)."""
        n = n_microbatches

        def split(x):
            y = x.reshape((n, x.shape[0] // n) + x.shape[1:])
            if plan is not None and plan.mesh is not None:
                y = jax.lax.with_sharding_constraint(
                    y, jax.sharding.NamedSharding(
                        plan.mesh, jax.sharding.PartitionSpec(
                            None, *plan.batch_spec())))
            return y
        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            gsum, loss_sum, aux = acc
            with jax.named_scope("fwd_bwd_micro"):
                (loss, metrics), g = jax.value_and_grad(
                    lf, has_aux=True)(params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, loss_sum + loss,
                    jax.tree.map(jnp.add, aux, metrics)), None

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        azero = {"nll": jnp.zeros(()), "aux": jnp.zeros(()),
                 "ntok": jnp.zeros(())}
        (gsum, loss_sum, aux), _ = jax.lax.scan(
            body, (gzero, jnp.zeros(()), azero), micro)
        grads = jax.tree.map(lambda g: g / n, gsum)
        metrics = {k: v / n for k, v in aux.items()}
        metrics["ntok"] = aux["ntok"]
        return finish(params, opt_state, loss_sum / n, metrics, grads)

    return train_step if n_microbatches <= 1 else train_step_micro


def make_prefill_step(cfg: ModelConfig, plan: Optional[ShardingPlan],
                      opts: T.ModelOptions):
    mesh_args = plan.moe_args() if plan is not None else None

    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch.get("tokens"),
                         batch.get("embeds"), mesh_args=mesh_args,
                         opts=opts)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: Optional[ShardingPlan],
                     opts: T.ModelOptions):
    mesh_args = plan.moe_args() if plan is not None else None

    def decode_step(params, cache, pos, token=None, embed=None):
        return T.decode_step(params, cfg, cache, token=token, embed=embed,
                             pos=pos, mesh_args=mesh_args, opts=opts)

    return decode_step
