"""Production mesh construction (deliverable (e), MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh``: ``jax.sharding.AxisType`` landed
    after 0.4.x; older jax infers Auto axes when the kwarg is omitted."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU smoke tests (1 device)."""
    return make_mesh(shape, axes)
