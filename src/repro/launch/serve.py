"""Batched serving driver: continuous prefill + decode with the measurement
stack attached.

Serving shape: a queue of synthetic requests (prompt lengths drawn from a
mixture) is served in fixed-size decode batches.  Prefill runs per request
batch; decode steps run against the shared KV cache.  Every GPU-side
dispatch (prefill, decode, cache copy, sync) goes through
``Profiler.dispatch`` so the §8.4-style analysis (sync_count vs
kernel_count, idleness blame) has real material — examples/
find_redundant_sync.py injects a deliberately redundant sync here and
finds it with the derived metric, reproducing the PeleC case study.
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.serving.window import DECODE, PREFILL


def _maybe_window(serving, rid: str, phase: str, tokens: int):
    if serving is None:
        return contextlib.nullcontext()
    return serving.request(rid, phase, tokens=tokens)


def serve(cfg: ModelConfig, *, n_requests: int = 8, batch: int = 4,
          prompt_len: int = 32, gen_len: int = 16, seed: int = 0,
          profile_dir: Optional[str] = None, redundant_sync: bool = False,
          opts: Optional[T.ModelOptions] = None, serving=None,
          rid_prefix: str = ""):
    """Returns (generated tokens (n_requests, gen_len), profile paths).

    ``serving`` takes a started ``repro.serving.ServingProfiler``: every
    dispatch then runs through it inside per-request/per-phase windows
    (``r<lo>`` / ``r<lo>-r<hi>`` for a batch), feeding latency stats plus
    governor/telemetry ticks; the caller owns its lifecycle and output.
    Mutually exclusive with ``profile_dir`` (which owns a plain Profiler
    internally, as before).  ``rid_prefix`` disambiguates request ids
    when several serve() passes feed one profiler (window identities
    with equal ids unify in the database).
    """
    opts = opts or T.ModelOptions(q_chunk=min(256, prompt_len),
                                  kv_chunk=min(256, prompt_len),
                                  ssm_chunk=min(64, prompt_len),
                                  loss_chunk=min(256, prompt_len))
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    max_len = prompt_len + gen_len

    prefill_fn = jax.jit(steps_mod.make_prefill_step(cfg, None, opts))
    decode_fn = jax.jit(steps_mod.make_decode_step(cfg, None, opts))

    if serving is not None and profile_dir:
        raise ValueError("pass either serving= or profile_dir=, not both")
    prof = serving.profiler if serving is not None else None
    own_prof = False
    if profile_dir:
        from repro.core.profiler import Profiler
        prof = Profiler(profile_dir, tracing=True, rng_seed=seed)
        prof.start()
        own_prof = True

    # --- warm-up: compile and register BOTH modules before the measured
    # loop.  Compilation used to run lazily inside the first batch's
    # dispatch, so its trace event (and any serving latency derived from
    # it) carried the full XLA compile time.
    warm_in = {"tokens": jnp.zeros((batch, prompt_len), jnp.int32)}
    logits, cache = prefill_fn(params, warm_in)
    cache = _grow_cache(cfg, cache, batch, max_len, prompt_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos0 = jnp.int32(prompt_len)
    warm_logits, _ = decode_fn(params, cache, pos0, token=tok)
    jax.block_until_ready(warm_logits)
    mid_p = mid_d = None
    if prof is not None:
        mid_p = prof.register_module(
            "prefill",
            prefill_fn.lower(params, warm_in).compile().as_text())
        mid_d = prof.register_module(
            "decode_step",
            decode_fn.lower(params, cache, pos0,
                            token=tok).compile().as_text())

    rng = np.random.default_rng(seed)
    outs = []
    n_batches = (n_requests + batch - 1) // batch
    for bi in range(n_batches):
        lo, hi = bi * batch, min(bi * batch + batch, n_requests)
        rid = f"{rid_prefix}r{lo}" if hi - lo <= 1 \
            else f"{rid_prefix}r{lo}-r{hi - 1}"
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len),
                                        np.int32))
        batch_in = {"tokens": toks}
        # --- prefill ------------------------------------------------------
        with _maybe_window(serving, rid, PREFILL, batch * prompt_len):
            if prof is not None:
                with prof.dispatch("kernel", "prefill", stream=0,
                                   module_id=mid_p):
                    logits, cache = prefill_fn(params, batch_in)
                    jax.block_until_ready(logits)
            else:
                logits, cache = prefill_fn(params, batch_in)
        # cache is sized prompt_len by prefill; decode needs max_len slots
        cache = _grow_cache(cfg, cache, batch, max_len, prompt_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen = [tok]
        # --- decode ---------------------------------------------------------
        for t in range(gen_len - 1):
            pos = jnp.int32(prompt_len + t)
            with _maybe_window(serving, rid, DECODE, batch):
                if prof is not None:
                    with prof.dispatch("kernel", "decode_step", stream=0,
                                       module_id=mid_d):
                        logits, cache = decode_fn(params, cache, pos,
                                                  token=tok)
                        jax.block_until_ready(logits)
                    if redundant_sync:
                        # §8.4.1: a sync with no kernel between it and the
                        # previous sync — found by diff = sync - kernels
                        with prof.dispatch("sync", "device_sync", stream=0):
                            jax.block_until_ready(logits)
                        with prof.dispatch("sync", "device_sync", stream=0):
                            jax.block_until_ready(logits)
                else:
                    logits, cache = decode_fn(params, cache, pos, token=tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            gen.append(tok)
        outs.append(jnp.stack(gen, axis=1))
    paths = None
    if own_prof:
        prof.flush()
        paths = prof.write()
        prof.stop()
    return jnp.concatenate(outs, axis=0)[:n_requests], paths


def _grow_cache(cfg, cache, batch, max_len, cur_len):
    """Pad prefill KV caches out to max_len slots (attention layers only)."""
    def grow(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and leaf.ndim == 5 and \
                leaf.shape[2] == cur_len:
            pad = jnp.zeros(leaf.shape[:2] + (max_len - cur_len,)
                            + leaf.shape[3:], leaf.dtype)
            return jnp.concatenate([leaf, pad], axis=2)
        return leaf
    return jax.tree_util.tree_map_with_path(grow, cache)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--profile-dir", default=None)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    t0 = time.monotonic()
    toks, paths = serve(cfg, n_requests=args.requests, batch=args.batch,
                        prompt_len=args.prompt_len, gen_len=args.gen_len,
                        profile_dir=args.profile_dir)
    dt = time.monotonic() - t0
    n_tok = toks.shape[0] * toks.shape[1]
    print(f"served {toks.shape[0]} requests x {toks.shape[1]} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    if paths:
        print("profiles:", sorted(paths)[:4], "...")


if __name__ == "__main__":
    main()
