"""Batched serving driver: continuous prefill + decode with the measurement
stack attached.

Serving shape: a queue of synthetic requests (prompt lengths drawn from a
mixture) is served in fixed-size decode batches.  Prefill runs per request
batch; decode steps run against the shared KV cache.  Every GPU-side
dispatch (prefill, decode, cache copy, sync) goes through
``Profiler.dispatch`` so the §8.4-style analysis (sync_count vs
kernel_count, idleness blame) has real material — examples/
find_redundant_sync.py injects a deliberately redundant sync here and
finds it with the derived metric, reproducing the PeleC case study.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch import steps as steps_mod
from repro.models import transformer as T


def serve(cfg: ModelConfig, *, n_requests: int = 8, batch: int = 4,
          prompt_len: int = 32, gen_len: int = 16, seed: int = 0,
          profile_dir: Optional[str] = None, redundant_sync: bool = False,
          opts: Optional[T.ModelOptions] = None):
    """Returns (generated tokens (n_requests, gen_len), profile paths)."""
    opts = opts or T.ModelOptions(q_chunk=min(256, prompt_len),
                                  kv_chunk=min(256, prompt_len),
                                  ssm_chunk=min(64, prompt_len),
                                  loss_chunk=min(256, prompt_len))
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    max_len = prompt_len + gen_len

    prefill_fn = jax.jit(steps_mod.make_prefill_step(cfg, None, opts))
    decode_fn = jax.jit(steps_mod.make_decode_step(cfg, None, opts))

    prof = None
    mid_p = mid_d = None
    if profile_dir:
        from repro.core.profiler import Profiler
        prof = Profiler(profile_dir, tracing=True, rng_seed=seed)
        prof.start()

    rng = np.random.default_rng(seed)
    outs = []
    n_batches = (n_requests + batch - 1) // batch
    for bi in range(n_batches):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len),
                                        np.int32))
        batch_in = {"tokens": toks}
        # --- prefill ------------------------------------------------------
        if prof is not None:
            if mid_p is None:
                mid_p = prof.register_module(
                    "prefill", prefill_fn.lower(
                        params, batch_in).compile().as_text())
            with prof.dispatch("kernel", "prefill", stream=0,
                               module_id=mid_p):
                logits, cache = prefill_fn(params, batch_in)
                jax.block_until_ready(logits)
        else:
            logits, cache = prefill_fn(params, batch_in)
        # cache is sized prompt_len by prefill; decode needs max_len slots
        cache = _grow_cache(cfg, cache, batch, max_len, prompt_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen = [tok]
        # --- decode ---------------------------------------------------------
        for t in range(gen_len - 1):
            pos = jnp.int32(prompt_len + t)
            if prof is not None:
                if mid_d is None:
                    mid_d = prof.register_module(
                        "decode_step", decode_fn.lower(
                            params, cache, pos,
                            token=tok).compile().as_text())
                with prof.dispatch("kernel", "decode_step", stream=0,
                                   module_id=mid_d):
                    logits, cache = decode_fn(params, cache, pos, token=tok)
                    jax.block_until_ready(logits)
                if redundant_sync:
                    # §8.4.1: a sync with no kernel between it and the
                    # previous sync — found by diff = sync - kernels
                    with prof.dispatch("sync", "device_sync", stream=0):
                        jax.block_until_ready(logits)
                    with prof.dispatch("sync", "device_sync", stream=0):
                        jax.block_until_ready(logits)
            else:
                logits, cache = decode_fn(params, cache, pos, token=tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            gen.append(tok)
        outs.append(jnp.stack(gen, axis=1))
    paths = None
    if prof is not None:
        prof.flush()
        paths = prof.write()
        prof.stop()
    return jnp.concatenate(outs, axis=0)[:n_requests], paths


def _grow_cache(cfg, cache, batch, max_len, cur_len):
    """Pad prefill KV caches out to max_len slots (attention layers only)."""
    def grow(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v") and leaf.ndim == 5 and \
                leaf.shape[2] == cur_len:
            pad = jnp.zeros(leaf.shape[:2] + (max_len - cur_len,)
                            + leaf.shape[3:], leaf.dtype)
            return jnp.concatenate([leaf, pad], axis=2)
        return leaf
    return jax.tree_util.tree_map_with_path(grow, cache)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--profile-dir", default=None)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    t0 = time.monotonic()
    toks, paths = serve(cfg, n_requests=args.requests, batch=args.batch,
                        prompt_len=args.prompt_len, gen_len=args.gen_len,
                        profile_dir=args.profile_dir)
    dt = time.monotonic() - t0
    n_tok = toks.shape[0] * toks.shape[1]
    print(f"served {toks.shape[0]} requests x {toks.shape[1]} tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    if paths:
        print("profiles:", sorted(paths)[:4], "...")


if __name__ == "__main__":
    main()
