"""Production training driver.

Wires every substrate together: config -> mesh/sharding plan -> data
pipeline -> jitted train step -> checkpoint manager (atomic/async) ->
straggler watchdog -> and, when ``--profile``, the paper's measurement
stack around every dispatch (heterogeneous CCTs, wait-free channels, PC
sample analogue, sparse profiles).

CPU-runnable end to end (examples/quickstart.py calls main() with a
reduced config); on a real TPU fleet the same file is the per-host entry
point — the mesh argument switches to the production mesh and
jax.distributed.initialize() is the only addition.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed import sharding as shard_mod
from repro.ft import RestartPolicy, StragglerWatchdog
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.optim import adamw


def train(cfg: ModelConfig, shape: ShapeConfig, *, n_steps: int = 20,
          mesh=None, strategy: str = "tp", ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, profile_dir: Optional[str] = None,
          opts: Optional[T.ModelOptions] = None,
          opt_cfg: Optional[adamw.OptConfig] = None,
          grad_compression: bool = False, seed: int = 0,
          resume: bool = False, log_every: int = 10,
          host_id: int = 0, watchdog: Optional[StragglerWatchdog] = None):
    """Returns (final params, metrics history, profile paths or None)."""
    opts = opts or T.ModelOptions()
    opt_cfg = opt_cfg or adamw.OptConfig(total_steps=max(n_steps, 2))
    plan = shard_mod.make_plan(mesh, strategy=strategy)
    watchdog = watchdog or StragglerWatchdog()

    # ---- init or resume --------------------------------------------------
    key = jax.random.PRNGKey(seed)
    if mesh is not None:
        p_struct = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
        p_sh = shard_mod.param_shardings(p_struct, cfg, plan)
        with mesh:
            params = jax.jit(lambda k: T.init_params(k, cfg),
                             out_shardings=p_sh)(key)
            opt_state = jax.jit(adamw.init,
                                out_shardings=shard_mod.opt_shardings(
                                    jax.eval_shape(adamw.init, p_struct),
                                    p_sh))(params)
    else:
        params = T.init_params(key, cfg)
        opt_state = adamw.init(params)

    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        p_sh = (shard_mod.param_shardings(params, cfg, plan)
                if mesh is not None else None)
        o_sh = (shard_mod.opt_shardings(jax.eval_shape(lambda x: x,
                                                       opt_state), p_sh)
                if mesh is not None else None)
        start_step, state = mgr.restore(
            {"params": params, "opt": opt_state},
            shardings={"params": p_sh, "opt": o_sh} if mesh is not None
            else None)
        params, opt_state = state["params"], state["opt"]

    # ---- data -------------------------------------------------------------
    ds = SyntheticLM(cfg, shape, seed=seed, host_id=host_id)
    prefetch = Prefetcher(ds, start_step=start_step)

    step_fn = steps_mod.make_train_step(cfg, plan if mesh is not None
                                        else None, opts, opt_cfg,
                                        grad_compression=grad_compression)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ---- optional measurement (the paper's tool) ---------------------------
    prof = None
    mid = None
    if profile_dir:
        from repro.core.profiler import Profiler
        prof = Profiler(profile_dir, tracing=True, rng_seed=seed)
        prof.start()

    history = []
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, n_steps):
            _, batch = next(prefetch)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if prof is not None:
                if mid is None:
                    lowered = jit_step.lower(params, opt_state, batch)
                    mid = prof.register_module(
                        "train_step", lowered.compile().as_text())
                with prof.dispatch("kernel", "train_step", stream=0,
                                  module_id=mid):
                    params, opt_state, metrics = jit_step(params, opt_state,
                                                          batch)
                    jax.block_until_ready(metrics["loss"])
            else:
                params, opt_state, metrics = jit_step(params, opt_state,
                                                      batch)
            watchdog.beat(f"host{host_id}", step)
            if step % log_every == 0 or step == n_steps - 1:
                loss = float(metrics["loss"])
                history.append({"step": step, "loss": loss,
                                "gnorm": float(metrics.get("grad_norm", 0))})
                print(f"step {step:5d} loss {loss:.4f}", flush=True)
            if mgr and ((step + 1) % ckpt_every == 0 or step == n_steps - 1):
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         block=False)
    if mgr:
        mgr.wait()
    paths = None
    if prof is not None:
        prof.flush()
        paths = prof.write()
        prof.stop()
    prefetch.close()
    return params, history, paths


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--profile-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("custom", args.seq, args.batch, "train")
    opts = T.ModelOptions(q_chunk=min(256, args.seq),
                          kv_chunk=min(256, args.seq),
                          ssm_chunk=min(128, args.seq),
                          loss_chunk=min(256, args.seq))
    t0 = time.monotonic()
    _, history, paths = train(
        cfg, shape, n_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, profile_dir=args.profile_dir,
        opts=opts, grad_compression=args.grad_compression, seed=args.seed,
        resume=args.resume)
    print(f"done in {time.monotonic() - t0:.1f}s; "
          f"final loss {history[-1]['loss']:.4f}")
    if paths:
        print(f"profiles: {sorted(paths)[:4]} ...")


if __name__ == "__main__":
    main()
