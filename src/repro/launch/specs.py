"""ShapeDtypeStruct stand-ins for every model input (MULTI-POD DRY-RUN §2):
weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shard_mod
from repro.models import transformer as T
from repro.optim import adamw


def _sds(shape, dtype, sh=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract training/prefill batch for one architecture x shape."""
    B, S = shape.global_batch, shape.seq_len
    batch: Dict = {}
    if cfg.frontend == "audio":
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "vlm" and cfg.frontend_tokens:
        F = min(cfg.frontend_tokens, S // 2)
        batch["embeds"] = _sds((B, F, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = _sds((B, S - F), jnp.int32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def decode_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract serve_step inputs: one new token + KV cache of seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(T.init_cache, cfg, B, S))
    out: Dict = {"cache": cache, "pos": _sds((), jnp.int32)}
    if cfg.frontend == "audio":
        out["embed"] = _sds((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        out["token"] = _sds((B,), jnp.int32)
    return out


def params_struct(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(T.init_params, cfg=cfg), key)


def opt_struct(params):
    return jax.eval_shape(adamw.init, params)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                plan: Optional[shard_mod.ShardingPlan] = None,
                kv_seq_axis: Optional[str] = None) -> Dict:
    """Sharded abstract inputs for the step function this shape lowers.

    train  -> {params, opt_state, batch}
    prefill-> {params, batch}
    decode -> {params, cache, token/embed, pos}
    """
    p_struct = params_struct(cfg)
    if plan is not None and plan.mesh is not None:
        p_sh = shard_mod.param_shardings(p_struct, cfg, plan)
        p_struct = jax.tree.map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), p_struct, p_sh)
    out: Dict = {"params": p_struct}
    if shape.kind in ("train", "prefill"):
        b_struct = batch_struct(cfg, shape)
        if plan is not None and plan.mesh is not None:
            b_sh = shard_mod.batch_shardings(b_struct, plan)
            b_struct = jax.tree.map(
                lambda s, sh: _sds(s.shape, s.dtype, sh), b_struct, b_sh)
        out["batch"] = b_struct
        if shape.kind == "train":
            o_struct = opt_struct(params_struct(cfg))
            if plan is not None and plan.mesh is not None:
                o_sh = shard_mod.opt_shardings(
                    o_struct, shard_mod.param_shardings(
                        params_struct(cfg), cfg, plan))
                o_struct = jax.tree.map(
                    lambda s, sh: _sds(s.shape, s.dtype, sh),
                    o_struct, o_sh)
            out["opt_state"] = o_struct
    else:
        d = decode_struct(cfg, shape)
        if plan is not None and plan.mesh is not None:
            c_sh = shard_mod.cache_shardings(d["cache"], cfg, plan,
                                             kv_seq_axis=kv_seq_axis)
            d["cache"] = jax.tree.map(
                lambda s, sh: _sds(s.shape, s.dtype, sh), d["cache"], c_sh)
        out.update(d)
    return out
