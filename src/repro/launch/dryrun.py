import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init) — MULTI-POD DRY-RUN §0.

"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape x mesh) cell:
  lower the step function with sharded ShapeDtypeStruct inputs,
  .compile() it, record memory_analysis() (proves it fits) and
  cost_analysis() (FLOPs/bytes for §Roofline), parse the partitioned HLO
  for collective bytes, and emit the roofline record.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k --mesh single --out dryrun_results
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import gzip
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_configs, shape_applicable
from repro.core import roofline as roof_mod
from repro.core.structure import parse_hlo
from repro.distributed import sharding as shard_mod
from repro.launch import mesh as mesh_mod
from repro.launch import specs as specs_mod
from repro.launch import steps as steps_mod
from repro.models.transformer import ModelOptions
from repro.optim.adamw import OptConfig

HBM_PER_CHIP = 16 * 1024 ** 3   # v5e: 16 GiB


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, strategy: str = "tp", attn_schedule: str = "dense",
             kv_seq_axis: str = None, remat_policy: str = "dots_no_batch",
             moe_mode: str = "gather", loss_chunk: int = 512,
             n_microbatches: int = 1, ssm_chunk: int = 256,
             slstm_block: int = 16,
             save_hlo: bool = True, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_desc = "pod2x16x16" if multi_pod else "pod16x16"
    label = f"{arch}_{shape_name}_{mesh_desc}" + (f"_{tag}" if tag else "")
    os.makedirs(out_dir, exist_ok=True)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_desc,
           "strategy": strategy, "tag": tag, "status": "pending"}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        _write(out_dir, label, rec)
        return rec

    t0 = time.monotonic()
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    plan = shard_mod.make_plan(mesh, multi_pod=multi_pod, strategy=strategy,
                               moe_weight_mode=moe_mode)
    opts = ModelOptions(attn_schedule=attn_schedule,
                        remat_policy=remat_policy, loss_chunk=loss_chunk,
                        ssm_chunk=ssm_chunk, slstm_block=slstm_block)
    specs = specs_mod.input_specs(cfg, shape, plan, kv_seq_axis=kv_seq_axis)

    if shape.kind == "train":
        fn = steps_mod.make_train_step(cfg, plan, opts, OptConfig(),
                                       n_microbatches=n_microbatches)
        args = (specs["params"], specs["opt_state"], specs["batch"])
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg, plan, opts)
        args = (specs["params"], specs["batch"])
        donate = ()
    else:
        fn = steps_mod.make_decode_step(cfg, plan, opts)
        kw = {}
        if "token" in specs:
            kw["token"] = specs["token"]
        if "embed" in specs:
            kw["embed"] = specs["embed"]
        fn = _bind_decode(fn, kw)
        args = (specs["params"], specs["cache"], specs["pos"]) + tuple(
            kw[k] for k in sorted(kw))
        donate = (1,)

    try:
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(f"[{label}] memory_analysis:", mem)
        print(f"[{label}] cost_analysis: flops={cost.get('flops', 0):.4g}"
              f" bytes={cost.get('bytes accessed', 0):.4g}")
        hlo_text = compiled.as_text()
        module = parse_hlo(hlo_text, name=label)
        report = roof_mod.analyze(
            label, mesh_desc, chips, cost, module=module,
            model_flops_total=roof_mod.model_flops(cfg, shape))
        per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                   + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device": per_dev,
                "fits_hbm": bool(per_dev < HBM_PER_CHIP),
            },
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
            roofline=report.row(),
            params=cfg.n_params(),
            active_params=cfg.n_active_params(),
        )
        if save_hlo:
            hpath = os.path.join(out_dir, f"{label}.hlo.gz")
            with gzip.open(hpath, "wt") as f:
                f.write(hlo_text)
            rec["hlo"] = hpath
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=str(e)[-2000:],
                   trace=traceback.format_exc()[-4000:])
    _write(out_dir, label, rec)
    return rec


def _bind_decode(fn, kw):
    names = sorted(kw)

    def bound(params, cache, pos, *rest):
        kwargs = dict(zip(names, rest))
        return fn(params, cache, pos, **kwargs)
    return bound


def _write(out_dir: str, label: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{label}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    ap.add_argument("--strategy", default="tp")
    ap.add_argument("--attn-schedule", default="dense")
    ap.add_argument("--kv-seq-axis", default=None)
    ap.add_argument("--remat-policy", default="dots_no_batch")
    ap.add_argument("--moe-mode", default="gather",
                    choices=("gather", "stationary"))
    ap.add_argument("--loss-chunk", type=int, default=512)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ssm-chunk", type=int, default=256)
    ap.add_argument("--slstm-block", type=int, default=16)
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose record file already exists")
    args = ap.parse_args()

    archs = list_configs() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_desc = "pod2x16x16" if mp else "pod16x16"
                label = f"{arch}_{shape}_{mesh_desc}" + (
                    f"_{args.tag}" if args.tag else "")
                path = os.path.join(args.out, f"{label}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"{label}: exists, skipping", flush=True)
                            continue
                rec = run_cell(arch, shape, mp, args.out,
                               strategy=args.strategy,
                               attn_schedule=args.attn_schedule,
                               kv_seq_axis=args.kv_seq_axis,
                               remat_policy=args.remat_policy,
                               moe_mode=args.moe_mode,
                               loss_chunk=args.loss_chunk,
                               n_microbatches=args.microbatch,
                               ssm_chunk=args.ssm_chunk,
                               slstm_block=args.slstm_block,
                               save_hlo=not args.no_hlo, tag=args.tag)
                status = rec["status"]
                extra = rec.get("reason", rec.get("error", ""))[:120]
                print(f"{arch} x {shape} x "
                      f"{'multi' if mp else 'single'}: {status} {extra}",
                      flush=True)
                failures += status == "error"
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
