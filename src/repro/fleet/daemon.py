"""The fleet aggregation daemon: crash-tolerant continuous ingest
(ISSUE 6 tentpole).

A long-running service that turns the one-shot ``merge_databases`` into
the always-on aggregation tier the exascale papers argue for
(PAPERS.md): producer hosts deliver checksummed shard envelopes
(``repro.fleet.envelope``) into a spool directory (or over a unix
socket), and the daemon folds them incrementally into one queryable
database with **exactly-once** semantics.

Spool layout::

    spool/
      incoming/     delivered envelopes (visible only after rename)
      pending/      <shard_id>/ — verified, unpacked shard databases
      quarantine/   rejected envelopes + <name>.reason files

Ingest pipeline, ``poll_once()``:

1. **recover** — repair any interrupted merge commit
   (``recover_interrupted_swap``: the previous database is either intact
   or parked at ``<db>.pre-merge``), sweep staging/temp droppings, and
   delete pending shards the journal already records as applied (the
   crash-between-commit-and-cleanup window).
2. **admit** — verify each incoming envelope (magic, sizes, SHA-256)
   and its unpacked shard database; torn, corrupt, malformed,
   conflicting, or unreadable shards go to quarantine with a reason —
   never a daemon crash.  Journaled ids are duplicates: dropped as
   no-ops.  Survivors are staged under ``pending/<id>`` and the
   envelope acknowledged (deleted).
3. **fold** — all pending shards fold through
   ``merge_databases(base_db, *pending, retention=...)`` in one commit;
   the successor journal rides the same directory swap
   (``extra_files``), so applying the shards and recording that they
   were applied is a single atomic rename.  Shards whose metric
   taxonomy does not match the database are quarantined instead of
   folded.

The correctness spine: after *any* schedule of crashes (at every
labeled fault point, ``repro.ft.inject``), restarts, and redeliveries,
the database is byte-identical to a one-shot ``aggregate()`` over the
union of journaled shards (tests/test_fleet_crash.py sweeps the
matrix; docs/fleet.md states the failure table).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import socket
import struct
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.merge import (FP_COMMIT_MID_SWAP, FP_COMMIT_POST_SWAP,
                              FP_COMMIT_PRE_SWAP, LoadedShard,
                              merge_databases, recover_interrupted_swap)
from repro.core.pipeline.database import Database
from repro.core.retention import RetentionPolicy
from repro.fleet.envelope import (EnvelopeError, atomic_write,
                                  sweep_stale_temps, unpack_envelope,
                                  verify_envelope)
from repro.fleet.journal import JOURNAL_NAME, Journal
from repro.ft import inject

ENVELOPE_SUFFIX = ".shard"
INGEST_META = "ingest.json"     # sha + meta, staged inside pending/<id>

# Labeled crash points on the daemon's admit/fold path; together with
# the merge commit points these are the daemon half of the crash
# matrix.  Order follows the ingest pipeline.
FP_ADMIT_PRE_UNPACK = "daemon.admit.pre_unpack"
FP_ADMIT_POST_UNPACK = "daemon.admit.post_unpack"
FP_ADMIT_POST_ACK = "daemon.admit.post_ack"
FP_FOLD_PRE_MERGE = "daemon.fold.pre_merge"
FP_FOLD_POST_COMMIT = "daemon.fold.post_commit"
FP_FOLD_POST_CLEANUP = "daemon.fold.post_cleanup"
inject.register_points(FP_ADMIT_PRE_UNPACK, FP_ADMIT_POST_UNPACK,
                       FP_ADMIT_POST_ACK, FP_FOLD_PRE_MERGE,
                       FP_FOLD_POST_COMMIT, FP_FOLD_POST_CLEANUP)

DAEMON_FAULT_POINTS = (
    FP_ADMIT_PRE_UNPACK, FP_ADMIT_POST_UNPACK, FP_ADMIT_POST_ACK,
    FP_FOLD_PRE_MERGE, FP_COMMIT_PRE_SWAP, FP_COMMIT_MID_SWAP,
    FP_COMMIT_POST_SWAP, FP_FOLD_POST_COMMIT, FP_FOLD_POST_CLEANUP,
)


@dataclasses.dataclass
class IngestReport:
    """What one ``poll_once`` did (all counts for this poll only)."""
    applied: List[str] = dataclasses.field(default_factory=list)
    duplicates: List[str] = dataclasses.field(default_factory=list)
    quarantined: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)                  # (name, reason)
    replay_cleaned: List[str] = dataclasses.field(default_factory=list)
    recovered: Optional[str] = None            # swap repair action
    folded: bool = False

    def summary(self) -> str:
        parts = [f"applied {len(self.applied)}"]
        if self.duplicates:
            parts.append(f"duplicates {len(self.duplicates)}")
        if self.quarantined:
            parts.append(f"quarantined {len(self.quarantined)}")
        if self.replay_cleaned:
            parts.append(f"replay-cleaned {len(self.replay_cleaned)}")
        if self.recovered:
            parts.append(f"recovered:{self.recovered}")
        return "ingest: " + ", ".join(parts)


class FleetDaemon:
    """Crash-tolerant aggregation daemon over a spool directory.

    Restart-safe by construction: a ``FleetDaemon`` holds no state that
    is not derivable from disk — constructing a fresh instance over the
    same ``db_dir``/``spool_dir`` *is* the restart path the crash tests
    exercise.
    """

    def __init__(self, db_dir: str, spool_dir: str, *,
                 retention: Optional[RetentionPolicy] = None,
                 n_workers: int = 2):
        self.db_dir = os.path.abspath(db_dir)
        self.spool_dir = os.path.abspath(spool_dir)
        self.incoming_dir = os.path.join(self.spool_dir, "incoming")
        self.pending_dir = os.path.join(self.spool_dir, "pending")
        self.quarantine_dir = os.path.join(self.spool_dir, "quarantine")
        self.retention = retention
        self.n_workers = max(1, n_workers)
        # cumulative counters (diagnostics only; never load-bearing)
        self.total_applied = 0
        self.total_duplicates = 0
        self.total_quarantined = 0
        self._stop = threading.Event()
        for d in (self.incoming_dir, self.pending_dir,
                  self.quarantine_dir):
            os.makedirs(d, exist_ok=True)

    # -- recovery -----------------------------------------------------------
    def recover(self, report: Optional[IngestReport] = None
                ) -> IngestReport:
        """Restore disk consistency after any crash: repair an
        interrupted merge swap, sweep temp droppings, and drop pending
        shards the journal already records (they *were* folded; only
        their cleanup was lost)."""
        report = report if report is not None else IngestReport()
        report.recovered = recover_interrupted_swap(self.db_dir)
        sweep_stale_temps(self.incoming_dir)
        for fn in os.listdir(self.pending_dir):
            if fn.startswith(".unpack_"):
                shutil.rmtree(os.path.join(self.pending_dir, fn),
                              ignore_errors=True)
        journal = self.journal()
        for sid in self._pending_ids():
            if sid in journal:
                shutil.rmtree(os.path.join(self.pending_dir, sid),
                              ignore_errors=True)
                report.replay_cleaned.append(sid)
        return report

    def journal(self) -> Journal:
        return Journal.load(self.db_dir)

    def database(self) -> Optional[Database]:
        if os.path.exists(os.path.join(self.db_dir, "meta.json")):
            return Database.load(self.db_dir)
        return None

    def _pending_ids(self) -> List[str]:
        return sorted(
            fn for fn in os.listdir(self.pending_dir)
            if not fn.startswith(".")
            and os.path.isdir(os.path.join(self.pending_dir, fn)))

    # -- quarantine ---------------------------------------------------------
    def _quarantine(self, path: str, reason: str,
                    report: IngestReport) -> None:
        """Move a rejected envelope (or unpacked shard dir) into
        quarantine with a ``.reason`` file; never raises on a missing
        source (a crashed prior attempt may have half-moved it)."""
        name = os.path.basename(path)
        dest = os.path.join(self.quarantine_dir, name)
        i = 0
        while os.path.lexists(dest):
            i += 1
            dest = os.path.join(self.quarantine_dir, f"{name}.{i}")
        if os.path.lexists(path):
            os.rename(path, dest)
        atomic_write(dest + ".reason", (reason + "\n").encode())
        report.quarantined.append((os.path.basename(dest), reason))
        self.total_quarantined += 1

    # -- admit --------------------------------------------------------------
    def _admit_one(self, env_path: str, journal: Journal,
                   report: IngestReport) -> None:
        try:
            header = verify_envelope(env_path)
        except EnvelopeError as e:
            self._quarantine(env_path, f"invalid envelope: {e}", report)
            return
        sid = header.shard_id
        if journal.conflict(sid, header.payload_sha256):
            self._quarantine(
                env_path,
                f"shard id {sid!r} already applied with different "
                f"payload (journal {journal.applied[sid][:12]}..., "
                f"envelope {header.payload_sha256[:12]}...)", report)
            return
        if sid in journal:
            os.unlink(env_path)             # duplicate delivery: no-op
            report.duplicates.append(sid)
            self.total_duplicates += 1
            return
        dest = os.path.join(self.pending_dir, sid)
        inject.fault_point(FP_ADMIT_PRE_UNPACK)
        fresh = not os.path.isdir(dest)
        unpack_envelope(env_path, dest)
        if fresh:
            try:
                self._validate_shard(dest)
            except (ValueError, OSError, KeyError) as e:
                shutil.rmtree(dest, ignore_errors=True)
                self._quarantine(env_path, f"invalid shard database: {e}",
                                 report)
                return
            atomic_write(
                os.path.join(dest, INGEST_META),
                json.dumps({"shard_id": sid,
                            "payload_sha256": header.payload_sha256,
                            "meta": header.meta},
                           sort_keys=True).encode())
        inject.fault_point(FP_ADMIT_POST_UNPACK)
        os.unlink(env_path)                 # acknowledge the delivery
        inject.fault_point(FP_ADMIT_POST_ACK)

    @staticmethod
    def _validate_shard(shard_dir: str) -> None:
        """A shard must load as a coherent database before it may ever
        reach the fold (``LoadedShard`` rejects torn meta/PMS pairs)."""
        LoadedShard(shard_dir, load_traces=False)

    def _shard_metrics(self, shard_dir: str) -> Optional[list]:
        """Metric columns of a pending shard (``None`` for an empty
        shard, which is compatible with anything)."""
        with open(os.path.join(shard_dir, "meta.json")) as f:
            meta = json.load(f)
        return meta["metrics"] if meta.get("profiles") else None

    def _shard_sha(self, shard_dir: str) -> str:
        try:
            with open(os.path.join(shard_dir, INGEST_META)) as f:
                return str(json.load(f)["payload_sha256"])
        except (OSError, ValueError, KeyError):
            return ""                       # pre-INGEST_META crash window

    # -- fold ---------------------------------------------------------------
    def _fold(self, journal: Journal, report: IngestReport) -> None:
        batch = [sid for sid in self._pending_ids() if sid not in journal]
        if not batch:
            return
        # metric-taxonomy gate: the database's columns are the reference;
        # mismatched shards quarantine rather than poison the fold.
        # Bootstrapping an empty database, the reference is the batch's
        # MAJORITY taxonomy (ties broken by smallest shard id holding
        # them) — shard ids are content hashes, so "first id in the
        # batch" would let an arbitrary outlier win the fleet db
        db = self.database()
        reference = db.metrics if db is not None and db.profile_ids \
            else None
        shard_metrics = {
            sid: self._shard_metrics(os.path.join(self.pending_dir, sid))
            for sid in batch}
        if reference is None:
            votes: dict = {}
            for sid in batch:
                m = shard_metrics[sid]
                if m is not None:
                    votes.setdefault(tuple(m), []).append(sid)
            if votes:
                top = max(len(sids) for sids in votes.values())
                reference = list(min(
                    (tax for tax, sids in votes.items()
                     if len(sids) == top),
                    key=lambda tax: min(votes[tax])))
        kept: List[str] = []
        for sid in batch:
            sdir = os.path.join(self.pending_dir, sid)
            metrics = shard_metrics[sid]
            if metrics is not None and reference is not None \
                    and metrics != reference:
                self._quarantine(
                    sdir, f"metric taxonomy mismatch: shard has "
                    f"{len(metrics)} column(s) ({metrics[:3]}...), "
                    f"database has {len(reference)}", report)
                continue
            kept.append(sid)
        if not kept:
            return
        applied = {sid: self._shard_sha(os.path.join(self.pending_dir,
                                                     sid))
                   for sid in kept}
        successor = journal.with_applied(applied)
        inputs: List[str] = []
        if os.path.exists(os.path.join(self.db_dir, "meta.json")):
            inputs.append(self.db_dir)
        inputs += [os.path.join(self.pending_dir, sid) for sid in kept]
        inject.fault_point(FP_FOLD_PRE_MERGE)
        merge_databases(
            inputs, self.db_dir, n_workers=self.n_workers,
            retention=self.retention,
            extra_files={JOURNAL_NAME: successor.dumps()})
        inject.fault_point(FP_FOLD_POST_COMMIT)
        for sid in kept:
            shutil.rmtree(os.path.join(self.pending_dir, sid),
                          ignore_errors=True)
        inject.fault_point(FP_FOLD_POST_CLEANUP)
        report.applied.extend(kept)
        report.folded = True
        self.total_applied += len(kept)

    # -- the poll loop ------------------------------------------------------
    def poll_once(self) -> IngestReport:
        """One recover/admit/fold cycle.  Every step is restartable:
        killing the daemon anywhere in here and constructing a fresh one
        loses no acknowledged shard and re-applies none."""
        report = self.recover()
        journal = self.journal()
        for fn in sorted(os.listdir(self.incoming_dir)):
            if fn.startswith(".") or not fn.endswith(ENVELOPE_SUFFIX):
                continue
            self._admit_one(os.path.join(self.incoming_dir, fn),
                            journal, report)
        self._fold(journal, report)
        return report

    def stop(self) -> None:
        self._stop.set()

    def run(self, *, interval_s: float = 1.0,
            max_polls: Optional[int] = None) -> int:
        """Poll until stopped (or ``max_polls``); returns polls done."""
        polls = 0
        while not self._stop.is_set():
            self.poll_once()
            polls += 1
            if max_polls is not None and polls >= max_polls:
                break
            self._stop.wait(interval_s)
        return polls

    # -- status -------------------------------------------------------------
    def spool_depth(self) -> int:
        """The backpressure signal: shards delivered but not yet folded
        (incoming envelopes + pending unpacked shards).  Producers poll
        this (``ShardProducer.poll_backpressure``) to throttle their own
        measurement while the daemon digests a backlog."""
        incoming = sum(1 for fn in os.listdir(self.incoming_dir)
                       if fn.endswith(ENVELOPE_SUFFIX))
        return incoming + len(self._pending_ids())

    def status(self) -> dict:
        journal = self.journal()
        db = self.database()
        status = {
            "db": self.db_dir,
            "profiles": len(db.profile_ids) if db else 0,
            "contexts": len(db.frames) if db else 0,
            "applied_shards": len(journal.applied),
            "generation": journal.generation,
            "pending": self._pending_ids(),
            "incoming": sorted(
                fn for fn in os.listdir(self.incoming_dir)
                if fn.endswith(ENVELOPE_SUFFIX)),
            "quarantined": sorted(
                fn for fn in os.listdir(self.quarantine_dir)
                if not fn.endswith(".reason")),
        }
        status["spool_depth"] = (len(status["incoming"])
                                 + len(status["pending"]))
        return status


# --------------------------------------------------------------------------
# Socket ingest: a thin transport in front of the same spool pipeline
# --------------------------------------------------------------------------
_LEN = struct.Struct("<Q")
MAX_ENVELOPE_BYTES = 1 << 31


class SocketIngest(threading.Thread):
    """Unix-socket envelope receiver.

    Protocol: client sends ``u64le length`` + envelope bytes; server
    commits them into the daemon's incoming spool (temp + fsync +
    rename — the same all-or-nothing contract as directory delivery)
    and replies ``OK <shard_id>\\n`` or ``ERR <reason>\\n``.  Envelopes
    whose header cannot even be parsed are still committed under a
    content-hash name so the poll loop quarantines them visibly rather
    than the bytes vanishing.
    """

    def __init__(self, daemon: FleetDaemon, socket_path: str):
        super().__init__(daemon=True, name="fleet-socket-ingest")
        self.fleet = daemon
        self.socket_path = socket_path
        if os.path.exists(socket_path):
            os.unlink(socket_path)
        self._srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._srv.bind(socket_path)
        self._srv.listen(8)
        self._srv.settimeout(0.2)
        self._halt = threading.Event()

    def run(self) -> None:
        while not self._halt.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            with conn:
                try:
                    self._serve(conn)
                except Exception as e:     # noqa: BLE001 — stay serving
                    try:
                        conn.sendall(f"ERR {e}\n".encode())
                    except OSError:
                        pass
        self._srv.close()

    def _serve(self, conn: socket.socket) -> None:
        raw = self._recv_exact(conn, _LEN.size)
        (n,) = _LEN.unpack(raw)
        if n == 0:
            # a zero-length frame is a status poll (backpressure):
            # reply OK + the daemon's status JSON on one line
            conn.sendall(b"OK " + json.dumps(
                self.fleet.status(), sort_keys=True).encode() + b"\n")
            return
        if n > MAX_ENVELOPE_BYTES:
            conn.sendall(b"ERR envelope too large\n")
            return
        data = self._recv_exact(conn, n)
        from repro.fleet.envelope import MAGIC, read_header
        import hashlib
        import tempfile
        fd, tmp = tempfile.mkstemp(prefix=".tmp-socket-",
                                   dir=self.fleet.incoming_dir)
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        try:
            header, _ = read_header(tmp)
            name = header.shard_id + ENVELOPE_SUFFIX
        except EnvelopeError:
            digest = hashlib.sha256(data).hexdigest()[:12]
            name = f"socket-{digest}{ENVELOPE_SUFFIX}"
        os.replace(tmp, os.path.join(self.fleet.incoming_dir, name))
        conn.sendall(f"OK {name[: -len(ENVELOPE_SUFFIX)]}\n".encode())

    @staticmethod
    def _recv_exact(conn: socket.socket, n: int) -> bytes:
        chunks = []
        got = 0
        while got < n:
            chunk = conn.recv(min(1 << 20, n - got))
            if not chunk:
                raise ConnectionError(
                    f"peer closed after {got}/{n} bytes")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def stop(self) -> None:
        self._halt.set()
        self.join(timeout=5)
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)
