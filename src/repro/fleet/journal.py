"""The ingest journal: exactly-once shard application (ISSUE 6).

The journal is a JSON file, ``fleet_journal.json``, living **inside the
fleet database directory** and committed *atomically with the fold*:
``merge_databases(..., extra_files=...)`` writes it into the staged
output before the directory-swap commit, so the fold and the record
that the fold happened are one rename — there is no schedule of crashes
that applies a shard without journaling it or journals a shard without
applying it.  That single invariant is the whole exactly-once argument
(docs/fleet.md spells it out as a failure matrix):

- daemon dies before the swap  -> old database, old journal; the shard
  is still spooled and not journaled -> replayed on restart;
- daemon dies after the swap   -> new database, new journal; the spooled
  copy is journaled -> cleaned up on restart, never re-folded;
- a shard is delivered twice   -> second copy's id is journaled -> no-op.

Entries map shard id -> the envelope's payload SHA-256, so a
*different* payload arriving under an already-applied id is detected
(quarantined as a conflict) rather than silently dropped.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

JOURNAL_NAME = "fleet_journal.json"
_VERSION = 1


@dataclasses.dataclass
class Journal:
    """Applied-shard record.  Immutable in spirit: ``with_applied``
    returns the successor journal the fold commits."""
    applied: Dict[str, str] = dataclasses.field(default_factory=dict)
    generation: int = 0            # fold count, for recovery diagnostics

    @classmethod
    def load(cls, db_dir: str) -> "Journal":
        path = os.path.join(db_dir, JOURNAL_NAME)
        if not os.path.exists(path):
            return cls()
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != _VERSION:
            raise ValueError(f"{path}: unknown journal version "
                             f"{data.get('version')!r}")
        return cls(applied={str(k): str(v)
                            for k, v in data["applied"].items()},
                   generation=int(data.get("generation", 0)))

    def with_applied(self, shards: Dict[str, str]) -> "Journal":
        """Successor journal with ``shards`` (id -> payload sha) added
        and the generation bumped."""
        merged = dict(self.applied)
        merged.update(shards)
        return Journal(applied=merged, generation=self.generation + 1)

    def dumps(self) -> bytes:
        return json.dumps(
            {"version": _VERSION, "generation": self.generation,
             "applied": dict(sorted(self.applied.items()))},
            indent=1, sort_keys=True).encode()

    def __contains__(self, shard_id: str) -> bool:
        return shard_id in self.applied

    def conflict(self, shard_id: str, payload_sha: str) -> bool:
        """True when ``shard_id`` was applied with *different* bytes —
        an id collision the daemon must quarantine, not dedup."""
        got = self.applied.get(shard_id)
        return got is not None and got != payload_sha
