"""Shard envelopes: the checksummed unit of fleet ingest (ISSUE 6).

A producer packages one shard database directory (the output of
``aggregate()`` over its local measurement) into a single self-verifying
file, so delivery over any transport — spool directory, socket, object
store — is all-or-nothing: the daemon either reconstructs the exact
shard database the producer staged, or rejects the envelope to
quarantine.  Torn writes, truncated copies, and bit flips are all caught
by construction; they can never fold into the fleet database.

Wire format (little-endian)::

    magic   8 bytes   b"RFLEET1\\n"
    hlen    8 bytes   u64 header length
    header  hlen      JSON: shard_id, files [{name, size}...],
                      payload_size, payload_sha256, meta {...}
    payload ...       the files' bytes, concatenated in header order

The payload SHA-256 covers every file byte; ``payload_size`` makes
truncation detectable before hashing.  File names are relative paths
inside the database directory and are refused if they escape it
(``..`` / absolute), so a hostile envelope cannot write outside the
daemon's spool.

The default ``shard_id`` is content-addressed
(``<producer>-<sha256(payload)[:16]>``): a producer that re-packages and
re-sends the identical measurement after a crash lands on the same id,
and the daemon's journal dedups it — exactly-once ingest without
producer-side bookkeeping (``repro.fleet.journal``).

All writes are staged (temp file in the destination directory, flush,
``fsync``, rename), so a partially-written envelope is never visible
under its final name.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import struct
import tempfile
from typing import Dict, List, Optional, Tuple

from repro.ft import inject

MAGIC = b"RFLEET1\n"
_HLEN = struct.Struct("<Q")

# fault points on the producer's staging path (client-side process)
FP_STAGE_PRE_WRITE = "client.stage.pre_write"
FP_STAGE_PRE_RENAME = "client.stage.pre_rename"
inject.register_points(FP_STAGE_PRE_WRITE, FP_STAGE_PRE_RENAME)


class EnvelopeError(ValueError):
    """A torn, truncated, corrupt, or malformed envelope."""


@dataclasses.dataclass(frozen=True)
class EnvelopeHeader:
    shard_id: str
    files: List[dict]               # [{"name": str, "size": int}, ...]
    payload_size: int
    payload_sha256: str
    meta: dict


def _iter_files(db_dir: str) -> List[str]:
    """Relative paths of every file under ``db_dir``, sorted — the
    canonical packing order, so identical databases pack to identical
    envelope bytes."""
    out = []
    for root, _dirs, files in os.walk(db_dir):
        for fn in files:
            out.append(os.path.relpath(os.path.join(root, fn), db_dir))
    return sorted(out)


def _check_relative(name: str) -> str:
    norm = os.path.normpath(name)
    if os.path.isabs(norm) or norm.startswith("..") or norm != name:
        raise EnvelopeError(f"envelope file name {name!r} escapes the "
                            "database directory")
    return norm


def atomic_write(dest: str, data: bytes) -> None:
    """Write-temp / flush / fsync / rename: ``dest`` is either absent or
    complete, never torn — the producer and transport commit primitive."""
    d = os.path.dirname(os.path.abspath(dest)) or "."
    fd, tmp = tempfile.mkstemp(prefix=".tmp-envelope-", dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        inject.fault_point(FP_STAGE_PRE_RENAME)
        os.replace(tmp, dest)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def sweep_stale_temps(directory: str) -> int:
    """Remove ``.tmp-*`` droppings a crashed staging attempt left behind
    (they were never renamed, so they were never visible as envelopes)."""
    n = 0
    if not os.path.isdir(directory):
        return 0
    for fn in os.listdir(directory):
        if fn.startswith(".tmp-"):
            os.unlink(os.path.join(directory, fn))
            n += 1
    return n


def pack_envelope(db_dir: str, dest: str, *,
                  shard_id: Optional[str] = None,
                  producer: str = "producer",
                  meta: Optional[dict] = None) -> str:
    """Package ``db_dir`` into an envelope file at ``dest`` (staged
    atomically); returns the shard id.  ``dest`` may contain the
    placeholder ``{id}``, substituted with the (possibly
    content-derived) shard id."""
    inject.fault_point(FP_STAGE_PRE_WRITE)
    names = _iter_files(db_dir)
    if not os.path.exists(os.path.join(db_dir, "meta.json")):
        raise EnvelopeError(f"{db_dir}: not a database directory "
                            "(no meta.json)")
    blobs = []
    files = []
    h = hashlib.sha256()
    for name in names:
        with open(os.path.join(db_dir, name), "rb") as f:
            data = f.read()
        blobs.append(data)
        files.append({"name": name, "size": len(data)})
        h.update(data)
    payload_sha = h.hexdigest()
    if shard_id is None:
        shard_id = f"{producer}-{payload_sha[:16]}"
    header = {
        "shard_id": shard_id,
        "files": files,
        "payload_size": sum(len(b) for b in blobs),
        "payload_sha256": payload_sha,
        "meta": dict(meta or {}),
    }
    hdr = json.dumps(header, sort_keys=True).encode()
    out = dest.replace("{id}", shard_id)
    atomic_write(out, MAGIC + _HLEN.pack(len(hdr)) + hdr
                 + b"".join(blobs))
    return shard_id


def read_header(path: str) -> Tuple[EnvelopeHeader, int]:
    """Parse and validate the header; returns (header, payload offset).
    Raises ``EnvelopeError`` on anything short of a well-formed header."""
    try:
        with open(path, "rb") as f:
            magic = f.read(len(MAGIC))
            if magic != MAGIC:
                raise EnvelopeError(
                    f"{path}: bad magic {magic!r} (torn or not an "
                    "envelope)")
            raw = f.read(_HLEN.size)
            if len(raw) != _HLEN.size:
                raise EnvelopeError(f"{path}: truncated header length")
            (hlen,) = _HLEN.unpack(raw)
            if hlen > 64 * 1024 * 1024:
                raise EnvelopeError(f"{path}: implausible header length "
                                    f"{hlen}")
            hdr_raw = f.read(hlen)
            if len(hdr_raw) != hlen:
                raise EnvelopeError(f"{path}: truncated header")
    except OSError as e:
        raise EnvelopeError(f"{path}: unreadable ({e})") from e
    try:
        hdr = json.loads(hdr_raw.decode())
        header = EnvelopeHeader(
            shard_id=str(hdr["shard_id"]),
            files=[{"name": _check_relative(str(fe["name"])),
                    "size": int(fe["size"])} for fe in hdr["files"]],
            payload_size=int(hdr["payload_size"]),
            payload_sha256=str(hdr["payload_sha256"]),
            meta=dict(hdr.get("meta", {})))
    except EnvelopeError:
        raise
    except (ValueError, KeyError, TypeError) as e:
        raise EnvelopeError(f"{path}: malformed header ({e})") from e
    if header.payload_size != sum(fe["size"] for fe in header.files):
        raise EnvelopeError(f"{path}: header file sizes do not sum to "
                            "payload_size")
    return header, len(MAGIC) + _HLEN.size + hlen


def verify_envelope(path: str) -> EnvelopeHeader:
    """Full validation: header, payload length, SHA-256.  Raises
    ``EnvelopeError``; returns the header on success."""
    header, off = read_header(path)
    h = hashlib.sha256()
    size = 0
    with open(path, "rb") as f:
        f.seek(off)
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            h.update(chunk)
            size += len(chunk)
    if size != header.payload_size:
        raise EnvelopeError(
            f"{path}: payload is {size} bytes, header says "
            f"{header.payload_size} (torn delivery)")
    if h.hexdigest() != header.payload_sha256:
        raise EnvelopeError(f"{path}: payload SHA-256 mismatch "
                            "(corrupt delivery)")
    return header


def unpack_envelope(path: str, dest_dir: str) -> EnvelopeHeader:
    """Verify and extract into ``dest_dir`` (staged: written to a
    sibling temp dir, committed by one rename — ``dest_dir`` is either
    absent or a complete shard database).  Idempotent: an existing
    ``dest_dir`` is left untouched."""
    header = verify_envelope(path)
    if os.path.isdir(dest_dir):
        return header            # already unpacked (crash replay)
    parent = os.path.dirname(os.path.abspath(dest_dir)) or "."
    os.makedirs(parent, exist_ok=True)
    work = tempfile.mkdtemp(prefix=".unpack_", dir=parent)
    try:
        with open(path, "rb") as f:
            _, off = read_header(path)
            f.seek(off)
            for fe in header.files:
                target = os.path.join(work, fe["name"])
                os.makedirs(os.path.dirname(target) or work, exist_ok=True)
                with open(target, "wb") as out:
                    out.write(f.read(fe["size"]))
        os.replace(work, dest_dir)
    except OSError:
        if os.path.isdir(dest_dir):   # lost a benign race to a replayer
            shutil.rmtree(work, ignore_errors=True)
            return header
        raise
    finally:
        if os.path.isdir(work):
            shutil.rmtree(work, ignore_errors=True)
    return header
