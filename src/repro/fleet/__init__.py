"""Fleet-scale continuous aggregation: crash-tolerant daemon + producer
client with exactly-once shard ingest (ISSUE 6).  See docs/fleet.md."""
from repro.fleet.client import (CLIENT_FAULT_POINTS, DeliveryReport,  # noqa: F401
                                DirectoryTransport, ShardProducer,
                                SocketTransport, TransportError)
from repro.fleet.daemon import (DAEMON_FAULT_POINTS, FleetDaemon,  # noqa: F401
                                IngestReport, SocketIngest)
from repro.fleet.envelope import (EnvelopeError, EnvelopeHeader,  # noqa: F401
                                  pack_envelope, unpack_envelope,
                                  verify_envelope)
from repro.fleet.journal import JOURNAL_NAME, Journal  # noqa: F401
