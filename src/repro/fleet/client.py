"""The producer side of fleet ingest: stage-and-forward shard delivery
(ISSUE 6 tentpole).

Each profiled host runs a ``ShardProducer`` next to its serving
process.  The producer's contract is sacrificial: it must **never block
or crash the host it measures**.  Concretely:

- ``stage()`` packages a local shard database into a checksummed
  envelope in a bounded on-disk outbox (write-temp/fsync/rename, so a
  crash mid-stage leaves no torn envelope).  When the outbox exceeds
  its soft bound the producer reports *throttled* (callers may lower
  their profiling rate); at the hard bound it **drops the
  oldest-epoch envelopes with a counted warning** — losing the oldest
  measurements is the designed failure mode, stalling the host is not.
- ``deliver()`` pushes spooled envelopes to the daemon, oldest epoch
  first, retrying transport failures with the exponential backoff of
  ``repro.ft.watchdog.RestartPolicy`` (the same budget-per-window
  supervisor used for job restarts).  A crash between a successful send
  and the local acknowledgement re-delivers the envelope on restart;
  the daemon's journal dedups it (envelope ids are content-addressed),
  so at-least-once delivery composes to exactly-once ingest.

Transports are pluggable: ``DirectoryTransport`` renames into the
daemon's incoming spool (same-filesystem deployments, and the crash
tests); ``SocketTransport`` speaks the length-prefixed unix-socket
protocol of ``repro.fleet.daemon.SocketIngest``.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import struct
import tempfile
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

from repro.fleet.envelope import (FP_STAGE_PRE_RENAME, FP_STAGE_PRE_WRITE,
                                  read_header, pack_envelope,
                                  sweep_stale_temps)
from repro.ft import inject
from repro.ft.watchdog import RestartPolicy

ENVELOPE_SUFFIX = ".shard"

FP_SEND_PRE_DELIVER = "client.send.pre_deliver"
FP_SEND_POST_DELIVER = "client.send.post_deliver"
inject.register_points(FP_SEND_PRE_DELIVER, FP_SEND_POST_DELIVER)

# every client-process fault point, for the crash-matrix sweep
CLIENT_FAULT_POINTS = (FP_STAGE_PRE_WRITE, FP_STAGE_PRE_RENAME,
                       FP_SEND_PRE_DELIVER, FP_SEND_POST_DELIVER)


class TransportError(RuntimeError):
    """A delivery attempt failed; the envelope stays spooled."""


class DirectoryTransport:
    """Deliver by atomic rename into the daemon's incoming spool (the
    daemon only ever sees complete envelopes)."""

    def __init__(self, incoming_dir: str):
        self.incoming_dir = incoming_dir

    def send(self, env_path: str) -> None:
        try:
            dest = os.path.join(self.incoming_dir,
                                os.path.basename(env_path))
            fd, tmp = tempfile.mkstemp(prefix=".tmp-deliver-",
                                       dir=self.incoming_dir)
            try:
                with os.fdopen(fd, "wb") as out, open(env_path, "rb") as f:
                    while True:
                        chunk = f.read(1 << 20)
                        if not chunk:
                            break
                        out.write(chunk)
                    out.flush()
                    os.fsync(out.fileno())
                os.replace(tmp, dest)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
        except OSError as e:
            raise TransportError(f"directory delivery failed: {e}") from e

    def poll_status(self) -> dict:
        """Daemon spool depth observed straight from the filesystem
        (same-box deployments): undelivered incoming envelopes plus the
        sibling ``pending/`` unpacked shards — the same number
        ``FleetDaemon.spool_depth()`` reports."""
        try:
            incoming = sum(1 for fn in os.listdir(self.incoming_dir)
                           if fn.endswith(ENVELOPE_SUFFIX))
            pending_dir = os.path.join(
                os.path.dirname(os.path.abspath(self.incoming_dir)),
                "pending")
            pending = 0
            if os.path.isdir(pending_dir):
                pending = sum(1 for fn in os.listdir(pending_dir)
                              if not fn.startswith("."))
        except OSError as e:
            raise TransportError(f"status poll failed: {e}") from e
        return {"spool_depth": incoming + pending}


class SocketTransport:
    """Deliver over the daemon's unix-socket listener (``SocketIngest``):
    u64le length + envelope bytes, reply ``OK <id>`` / ``ERR <reason>``."""

    _LEN = struct.Struct("<Q")

    def __init__(self, socket_path: str, *, timeout_s: float = 30.0):
        self.socket_path = socket_path
        self.timeout_s = timeout_s

    def send(self, env_path: str) -> None:
        try:
            with open(env_path, "rb") as f:
                data = f.read()
            with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
                s.settimeout(self.timeout_s)
                s.connect(self.socket_path)
                s.sendall(self._LEN.pack(len(data)) + data)
                reply = s.makefile("rb").readline().decode().strip()
        except OSError as e:
            raise TransportError(f"socket delivery failed: {e}") from e
        if not reply.startswith("OK"):
            raise TransportError(f"daemon rejected envelope: {reply}")

    def poll_status(self) -> dict:
        """Status poll over the socket: a zero-length frame, to which
        ``SocketIngest`` replies ``OK <status json>``."""
        import json
        try:
            with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as s:
                s.settimeout(self.timeout_s)
                s.connect(self.socket_path)
                s.sendall(self._LEN.pack(0))
                reply = s.makefile("rb").readline().decode().strip()
        except OSError as e:
            raise TransportError(f"status poll failed: {e}") from e
        if not reply.startswith("OK "):
            raise TransportError(f"daemon status poll failed: {reply}")
        try:
            return json.loads(reply[3:])
        except ValueError as e:
            raise TransportError(f"malformed status reply: {e}") from e


@dataclasses.dataclass
class DeliveryReport:
    delivered: List[str] = dataclasses.field(default_factory=list)
    failed: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)       # (name, last error)
    gave_up: bool = False           # restart budget exhausted


class ShardProducer:
    """Bounded-outbox producer: stage locally, deliver with backoff.

    ``clock``/``sleep`` are injectable so tests run the backoff schedule
    without real waiting.
    """

    def __init__(self, outbox_dir: str, transport, *,
                 producer: str = "producer",
                 spool_soft: int = 32, spool_max: int = 64,
                 daemon_spool_soft: Optional[int] = None,
                 policy: Optional[RestartPolicy] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if spool_max < 1 or spool_soft < 1:
            raise ValueError("spool bounds must be >= 1")
        self.outbox_dir = os.path.abspath(outbox_dir)
        self.transport = transport
        self.producer = producer
        self.spool_soft = spool_soft
        self.spool_max = spool_max
        self.daemon_spool_soft = daemon_spool_soft
        self.policy = policy if policy is not None else RestartPolicy(
            backoff_base_s=0.05, backoff_max_s=2.0)
        self.clock = clock
        self.sleep = sleep
        self.throttled = False          # outbox or daemon over soft bound
        self.daemon_spool_depth = 0     # last observed daemon backlog
        self.daemon_backpressured = False
        self.dropped = 0                # envelopes sacrificed, cumulative
        os.makedirs(self.outbox_dir, exist_ok=True)
        sweep_stale_temps(self.outbox_dir)

    # -- outbox -------------------------------------------------------------
    def spooled(self) -> List[str]:
        """Envelope paths, oldest epoch first (header ``meta.epoch``,
        then name — the delivery and drop order)."""
        ranked = []
        for fn in sorted(os.listdir(self.outbox_dir)):
            if fn.startswith(".") or not fn.endswith(ENVELOPE_SUFFIX):
                continue
            path = os.path.join(self.outbox_dir, fn)
            try:
                header, _ = read_header(path)
                epoch = int(header.meta.get("epoch", 0))
            except (ValueError, TypeError):
                epoch = 0
            ranked.append((epoch, fn, path))
        ranked.sort()
        return [path for _, _, path in ranked]

    def stage(self, db_dir: str, *, epoch: int = 0,
              meta: Optional[dict] = None,
              shard_id: Optional[str] = None) -> str:
        """Package ``db_dir`` into the outbox; returns the shard id.
        Never blocks: over the hard bound, the oldest epoch is dropped
        (counted, warned) to make room for the measurement just taken.
        ``shard_id`` overrides the content-derived id — telemetry
        exporters use a deterministic per-epoch id so a re-exported
        epoch dedups at the daemon instead of double-counting."""
        full_meta = dict(meta or {})
        full_meta["epoch"] = int(epoch)
        sid = pack_envelope(
            db_dir, os.path.join(self.outbox_dir, "{id}" + ENVELOPE_SUFFIX),
            shard_id=shard_id, producer=self.producer, meta=full_meta)
        self._enforce_bound()
        # refresh the combined backpressure flag on every enqueue, not
        # just in deliver/tick loops: a producer that only stages (e.g.
        # an exporter between governor ticks) must see its own outbox
        # filling — and the daemon backlog when observable — *before*
        # the governor's next note_backpressure read, or it keeps
        # exporting at full fidelity into a pipe that is already behind
        self.poll_backpressure()
        return sid

    def poll_backpressure(self) -> bool:
        """Refresh ``throttled`` from both ends of the pipe: the local
        outbox depth (soft bound, as before) and — when the transport
        can observe the daemon and ``daemon_spool_soft`` is set — the
        daemon's unfolded spool depth.  A failed poll keeps the last
        observation (polling must never hurt the serving host).  The
        overhead governor consumes the combined flag
        (``OverheadGovernor.note_backpressure``)."""
        poll = getattr(self.transport, "poll_status", None)
        if poll is not None and self.daemon_spool_soft is not None:
            try:
                status = poll()
                self.daemon_spool_depth = int(
                    status.get("spool_depth", 0))
                self.daemon_backpressured = (
                    self.daemon_spool_depth > self.daemon_spool_soft)
            except TransportError:
                pass
        self.throttled = (len(self.spooled()) > self.spool_soft
                          or self.daemon_backpressured)
        return self.throttled

    def _enforce_bound(self) -> None:
        spooled = self.spooled()
        self.throttled = (len(spooled) > self.spool_soft
                          or self.daemon_backpressured)
        overflow = len(spooled) - self.spool_max
        if overflow <= 0:
            return
        victims = spooled[:overflow]     # oldest epochs first
        for path in victims:
            os.unlink(path)
        self.dropped += len(victims)
        warnings.warn(
            f"fleet outbox over spool_max={self.spool_max}: dropped "
            f"{len(victims)} oldest-epoch envelope(s) "
            f"({self.dropped} dropped total); serving is never blocked",
            RuntimeWarning, stacklevel=3)

    # -- delivery -----------------------------------------------------------
    def deliver(self) -> DeliveryReport:
        """Push every spooled envelope, oldest epoch first.  Transport
        failures retry with ``RestartPolicy`` backoff until the restart
        budget for the rolling window is exhausted, then give up (the
        envelopes stay spooled for the next ``deliver``)."""
        report = DeliveryReport()
        for path in self.spooled():
            name = os.path.basename(path)
            while True:
                inject.fault_point(FP_SEND_PRE_DELIVER)
                try:
                    self.transport.send(path)
                except TransportError as e:
                    now = self.clock()
                    self.policy.record_failure(now)
                    if not self.policy.should_restart(now):
                        report.failed.append((name, str(e)))
                        report.gave_up = True
                        return report
                    self.sleep(self.policy.backoff_s())
                    continue
                inject.fault_point(FP_SEND_POST_DELIVER)
                # ack only after the transport confirmed: a crash in
                # the window above re-delivers, and the daemon dedups
                os.unlink(path)
                report.delivered.append(name)
                break
        self.throttled = (len(self.spooled()) > self.spool_soft
                          or self.daemon_backpressured)
        return report
