"""``python -m repro.fleet`` — run the aggregation daemon, deliver
shards, or inspect fleet state from the command line::

    python -m repro.fleet daemon DB --spool SPOOL --retain last=8
    python -m repro.fleet send SHARD_DB... --outbox OUT --to SPOOL/incoming
    python -m repro.fleet status DB --spool SPOOL

``daemon`` honors ``$REPRO_FAULT_POINTS`` / ``$REPRO_FAULT_MODE``
(``repro.ft.inject``) so the CI chaos job and subprocess crash tests
can kill it at any labeled point.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional, Sequence

from repro.ft import inject


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Crash-tolerant fleet aggregation (docs/fleet.md).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    d = sub.add_parser("daemon", help="run the aggregation daemon")
    d.add_argument("db", help="fleet database directory")
    d.add_argument("--spool", required=True, help="spool directory")
    d.add_argument("--retain", default=None, metavar="SPEC",
                   help="retention at fold time, e.g. 'last=8,dedup'")
    d.add_argument("--interval", type=float, default=1.0,
                   help="poll interval seconds (default 1.0)")
    d.add_argument("--max-polls", type=int, default=None,
                   help="exit after N polls (default: run forever)")
    d.add_argument("--socket", default=None, metavar="PATH",
                   help="also accept envelopes on a unix socket")
    d.add_argument("--workers", type=int, default=2,
                   help="merge worker processes (default 2)")

    s = sub.add_parser("send", help="stage and deliver shard databases")
    s.add_argument("shards", nargs="+", help="shard database directories")
    s.add_argument("--outbox", required=True,
                   help="producer outbox directory")
    s.add_argument("--to", default=None, metavar="INCOMING",
                   help="daemon incoming spool directory")
    s.add_argument("--socket", default=None, metavar="PATH",
                   help="daemon unix socket (alternative to --to)")
    s.add_argument("--producer", default="producer")
    s.add_argument("--epoch", type=int, default=0)

    st = sub.add_parser("status", help="print fleet state as JSON")
    st.add_argument("db", help="fleet database directory")
    st.add_argument("--spool", required=True, help="spool directory")

    args = ap.parse_args(argv)

    if args.cmd == "daemon":
        from repro.core.retention import parse_retention
        from repro.fleet.daemon import FleetDaemon, SocketIngest
        if inject.arm_from_env():
            print(f"[fleet] fault injection armed: {inject.armed()}")
        daemon = FleetDaemon(
            args.db, args.spool, n_workers=args.workers,
            retention=parse_retention(args.retain) if args.retain
            else None)
        listener = None
        if args.socket:
            listener = SocketIngest(daemon, args.socket)
            listener.start()
        try:
            polls = daemon.run(interval_s=args.interval,
                               max_polls=args.max_polls)
        finally:
            if listener is not None:
                listener.stop()
        print(f"[fleet] daemon exiting after {polls} poll(s): "
              f"applied {daemon.total_applied}, "
              f"duplicates {daemon.total_duplicates}, "
              f"quarantined {daemon.total_quarantined}")
        return 0

    if args.cmd == "send":
        from repro.fleet.client import (DirectoryTransport, ShardProducer,
                                        SocketTransport)
        if inject.arm_from_env():
            print(f"[fleet] fault injection armed: {inject.armed()}")
        if (args.to is None) == (args.socket is None):
            ap.error("send needs exactly one of --to / --socket")
        transport = DirectoryTransport(args.to) if args.to \
            else SocketTransport(args.socket)
        producer = ShardProducer(args.outbox, transport,
                                 producer=args.producer)
        for shard in args.shards:
            sid = producer.stage(shard, epoch=args.epoch)
            print(f"[fleet] staged {shard} as {sid}")
        report = producer.deliver()
        print(f"[fleet] delivered {len(report.delivered)}, "
              f"failed {len(report.failed)}"
              + (" (gave up)" if report.gave_up else ""))
        return 1 if report.gave_up else 0

    from repro.fleet.daemon import FleetDaemon
    daemon = FleetDaemon(args.db, args.spool)
    print(json.dumps(daemon.status(), indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
