"""Sharded, atomic, async checkpointing with elastic restore.

Design points for 1000+ nodes (DESIGN.md §5):

- **Sharded writes**: every host writes only the *addressable* shards of
  each array, one ``<leaf>.<shard_index>.npy`` file per distinct shard
  (replicated shards are written once, by the lowest-index owner).  No
  host ever materializes a full array.
- **Atomicity**: a checkpoint is staged into ``step_<N>.tmp`` and
  ``os.rename``d to ``step_<N>`` only after every shard file and the
  manifest are durable — a crashed writer leaves no half checkpoint, and
  restore only ever sees complete directories.
- **Async**: ``save(..., block=False)`` snapshots device arrays to host
  (the only synchronous part) and hands the serialization to a background
  thread, overlapping I/O with the next training steps.
- **Elastic restore**: ``restore`` takes *target* shardings that may come
  from a different mesh than the save-time mesh.  Shard files are memmap'd
  and each target shard reads exactly the slice it needs
  (``make_array_from_callback``) — restoring a 512-chip checkpoint onto a
  256-chip mesh (or CPU) touches each byte once.
- **Pipeline state**: the data pipeline is a pure function of (seed, step,
  host), so the manifest's ``step`` *is* the full pipeline state.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return ".".join(parts)


def _leaf_files(leaf: Any) -> List[Tuple[str, Tuple[slice, ...], np.ndarray]]:
    """[(shard_suffix, index, host_array)] for the addressable shards this
    process must write (dedup replicated shards by device order)."""
    if not isinstance(leaf, jax.Array) or not hasattr(leaf, "addressable_shards"):
        return [("s0", (), np.asarray(leaf))]
    seen = set()
    out = []
    for shard in leaf.addressable_shards:
        key = tuple((s.start, s.stop) for s in
                    _norm_index(shard.index, leaf.shape))
        if key in seen:
            continue  # replica of a shard another device already owns
        seen.add(key)
        out.append((f"s{len(out)}", _norm_index(shard.index, leaf.shape),
                    np.asarray(shard.data)))
    return out


def _norm_index(index, shape) -> Tuple[slice, ...]:
    norm = []
    for s, dim in zip(index, shape):
        start = 0 if s.start is None else int(s.start)
        stop = dim if s.stop is None else int(s.stop)
        norm.append(slice(start, stop))
    return tuple(norm)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, *, block: bool = True,
             extra_meta: Optional[dict] = None) -> str:
        """Checkpoint a pytree of (possibly sharded) arrays."""
        self.wait()  # only one async save in flight
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        # synchronous part: snapshot device -> host
        records = []
        for path, leaf in flat:
            name = _path_str(path)
            shards = _leaf_files(leaf)
            dtype = str(shards[0][2].dtype)
            shape = list(leaf.shape) if hasattr(leaf, "shape") else []
            records.append((name, shape, dtype, shards))

        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"

        def write():
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": [],
                        "extra": extra_meta or {}}
            for name, shape, dtype, shards in records:
                entry = {"name": name, "shape": shape, "dtype": dtype,
                         "shards": []}
                for suffix, index, arr in shards:
                    fname = f"{name}.{suffix}.npy"
                    np.save(os.path.join(tmp, fname), arr)
                    entry["shards"].append({
                        "file": fname,
                        "index": [[s.start, s.stop] for s in index],
                    })
                manifest["leaves"].append(entry)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        if block:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        return final

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def restore(self, tree_like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore onto (possibly different) target shardings.

        ``tree_like``: pytree of arrays or ShapeDtypeStructs giving the
        target structure.  ``shardings``: matching pytree of Sharding (or
        None -> host-local numpy arrays).  Returns (step, restored tree).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {e["name"]: e for e in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        sh_flat = (jax.tree_util.tree_leaves(shardings)
                   if shardings is not None else [None] * len(flat))
        assert len(sh_flat) == len(flat)
        out = []
        for (path, leaf), sharding in zip(flat, sh_flat):
            name = _path_str(path)
            entry = by_name[name]
            shape = tuple(entry["shape"])
            dtype = np.dtype(entry["dtype"])
            mmaps = [(tuple(slice(a, b) for a, b in s["index"]),
                      np.load(os.path.join(d, s["file"]), mmap_mode="r"))
                     for s in entry["shards"]]

            def read_slice(index, shape=shape, dtype=dtype, mmaps=mmaps):
                index = _norm_index(index, shape)
                if not shape:
                    return np.asarray(mmaps[0][1])
                buf = np.empty([s.stop - s.start for s in index], dtype)
                for src_index, arr in mmaps:
                    inter = []
                    for tgt, src in zip(index, src_index):
                        lo = max(tgt.start, src.start)
                        hi = min(tgt.stop, src.stop)
                        if lo >= hi:
                            break
                        inter.append((lo, hi, tgt.start, src.start))
                    else:
                        dst_idx = tuple(slice(lo - t0, hi - t0)
                                        for lo, hi, t0, _ in inter)
                        src_idx = tuple(slice(lo - s0, hi - s0)
                                        for lo, hi, _, s0 in inter)
                        buf[dst_idx] = arr[src_idx]
                return buf

            if sharding is None:
                out.append(read_slice(tuple(slice(None) for _ in shape)))
            else:
                out.append(jax.make_array_from_callback(
                    shape, sharding,
                    lambda idx, rs=read_slice: rs(idx)))
        return step, jax.tree_util.tree_unflatten(treedef, out)
