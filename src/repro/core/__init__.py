"""The paper's contribution: HPCToolkit-style measurement & analysis for
JAX/TPU programs.  See DESIGN.md for the GPU->TPU adaptation map."""
from repro.core.profiler import Profiler               # noqa: F401
from repro.core.aggregate import aggregate, Database   # noqa: F401
from repro.core.merge import merge_databases           # noqa: F401
