"""PMS / CMS sparse-cube analysis formats (paper §6.2, Fig. 4).

The analysis result is a sparse cube indexed by (profile, context, metric).
Two complementary layouts, each a stack of modified-CSR planes:

- **PMS (Profile-Major Sparse)**: one plane per profile -> compare metrics
  *within* a thread/stream; plane = CSR over (context -> metric, value).
- **CMS (CCT-Major Sparse)**: one plane per context -> compare a metric
  *across* profiles; plane = sparse ``midxs`` array of (metric id, start)
  pairs (many metrics are empty for a context, so even the CSR row array is
  sparsified — the paper's key refinement), then ``pids`` and ``vals``.

Access costs (asserted by tests, matching §6.2): plane locate O(1) via the
offsets vector, metric locate O(log m) by binary search in midxs, a single
(ctx, metric, profile) value O(log m + log p).

Construction mirrors hpcprof-mpi: workers are assigned profiles (PMS) or
contiguous context ranges balanced by plane bytes (~non-zero count, the
paper's CMS load-balance criterion); an exscan over plane sizes yields
every worker's write offset; workers then fill a preallocated memmap
concurrently without further communication, in bounded-memory rounds
(out-of-core).
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

CMS_MAGIC = b"RCMS"
PMS_MAGIC = b"RPMS"


@dataclasses.dataclass
class ProfileValues:
    """Sparse values of one profile: parallel arrays (ctx, metric, value)."""
    profile_id: int
    ctx: np.ndarray        # (V,) uint32
    metric: np.ndarray     # (V,) uint32
    values: np.ndarray     # (V,) float64


def _exscan(sizes: Sequence[int]) -> List[int]:
    out = [0]
    for s in sizes[:-1]:
        out.append(out[-1] + int(s))
    return out


# =========================================================================
# CMS
# =========================================================================
def write_cms(path: str, profiles: List[ProfileValues], *,
              n_workers: int = 4, max_round_bytes: int = 1 << 28) -> dict:
    """Builds the CCT-major cube.  Returns size stats."""
    # --- transpose to per-context COO (vectorized) --------------------------
    ctx = np.concatenate([p.ctx for p in profiles]) if profiles else \
        np.zeros(0, np.uint32)
    met = np.concatenate([p.metric for p in profiles]) if profiles else \
        np.zeros(0, np.uint32)
    val = np.concatenate([p.values for p in profiles]) if profiles else \
        np.zeros(0, np.float64)
    pid = np.concatenate([np.full(len(p.ctx), p.profile_id, np.uint32)
                          for p in profiles]) if profiles else \
        np.zeros(0, np.uint32)
    # sort by (ctx, metric, profile)
    order = np.lexsort((pid, met, ctx))
    ctx, met, val, pid = ctx[order], met[order], val[order], pid[order]

    uctx, starts = np.unique(ctx, return_index=True)
    bounds = np.append(starts, len(ctx))

    # per-context plane sizes: midx entries + sentinel, pids, vals
    # (vectorized: unique (ctx, metric) pairs -> metric count per context;
    # the pair table is reused below to build the midxs streams)
    pair = (ctx.astype(np.int64) << 32) | met.astype(np.int64)
    upair, up_first = np.unique(pair, return_index=True)
    upair_plane = np.searchsorted(uctx, (upair >> 32))
    m_counts = np.bincount(upair_plane, minlength=len(uctx)).astype(np.int64)
    n_midxs = m_counts + 1  # + sentinel
    nnz = bounds[1:] - bounds[:-1]
    plane_bytes = n_midxs * 12 + nnz * (4 + 8)
    offsets = np.zeros(len(uctx), np.int64)
    np.cumsum(plane_bytes[:-1], out=offsets[1:len(uctx)])

    header = {
        "n_ctx": int(len(uctx)),
        "n_profiles": int(len(profiles)),
        "nnz": int(len(val)),
    }
    hdr = json.dumps(header).encode()
    index_bytes = len(uctx) * 24
    data_start = 4 + 4 + len(hdr) + 4 + index_bytes
    total = data_start + int(plane_bytes.sum())

    with open(path, "wb") as f:
        f.truncate(total)
    mm = np.memmap(path, np.uint8, "r+")
    mm[:4] = np.frombuffer(CMS_MAGIC, np.uint8)
    mm[4:8] = np.frombuffer(struct.pack("<I", len(hdr)), np.uint8)
    mm[8:8 + len(hdr)] = np.frombuffer(hdr, np.uint8)
    p0 = 8 + len(hdr)
    mm[p0:p0 + 4] = np.frombuffer(struct.pack("<I", len(uctx)), np.uint8)
    # context index: (ctx_id u32, nnz u32, abs offset u64, n_midxs u32) = 20B
    # pad to 24 for alignment
    idx = np.zeros((len(uctx), 3), np.int64)
    idx[:, 0] = uctx
    idx[:, 1] = (n_midxs << 32) | nnz
    idx[:, 2] = offsets + data_start
    mm[p0 + 4:p0 + 4 + index_bytes] = np.frombuffer(idx.tobytes(), np.uint8)

    # --- plane fill ---------------------------------------------------------
    # Workers own disjoint, byte-balanced contiguous plane ranges, filled
    # in bounded rounds (out-of-core): each round assembles a run of
    # planes into one segment with array-level scatters (no per-context
    # Python loop, no per-context np.unique) and writes it to the memmap
    # with a single GIL-releasing copy, then flushes.  The scatter's index
    # arrays cost ~_SEG_TEMP_FACTOR transient bytes per output byte, so
    # rounds are sized at max_round_bytes / _SEG_TEMP_FACTOR — per-worker
    # memory stays bounded by ~max_round_bytes.  Same communication-free
    # exscan+fill construction as hpcprof-mpi.
    n_planes = len(uctx)
    cum_pairs = np.concatenate(([0], np.cumsum(m_counts)))
    cum_bytes = np.cumsum(plane_bytes) if n_planes else np.zeros(0, np.int64)
    data_bytes = int(cum_bytes[-1]) if n_planes else 0
    pid_u8 = np.ascontiguousarray(pid.astype("<u4")).view(np.uint8)
    val_u8 = np.ascontiguousarray(val.astype("<f8")).view(np.uint8)

    def runs(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
        """Concatenated [start, start+len) ranges as one index array."""
        total_ = int(lens.sum())
        if total_ == 0:
            return np.zeros(0, np.int64)
        shift = np.concatenate(([0], np.cumsum(lens)[:-1]))
        return np.repeat(starts - shift, lens) + np.arange(total_)

    def build_segment(lo: int, hi: int) -> np.ndarray:
        """All planes [lo, hi) as one contiguous byte segment."""
        base = int(offsets[lo])
        seg = np.empty(int(cum_bytes[hi - 1]) - base, np.uint8)
        p0, p1 = int(cum_pairs[lo]), int(cum_pairs[hi])
        # midxs stream: per plane its (metric, local start) pairs + sentinel
        midxs = np.zeros((p1 - p0) + (hi - lo),
                         dtype=[("m", "<u4"), ("s", "<u8")])
        pair_dest = np.arange(p1 - p0) + (upair_plane[p0:p1] - lo)
        sentinel_dest = (cum_pairs[lo + 1:hi + 1] - p0) + np.arange(hi - lo)
        midxs["m"][pair_dest] = (upair[p0:p1] & 0xFFFFFFFF).astype(np.uint32)
        midxs["s"][pair_dest] = up_first[p0:p1] - bounds[upair_plane[p0:p1]]
        midxs["m"][sentinel_dest] = 0xFFFFFFFF
        midxs["s"][sentinel_dest] = nnz[lo:hi]
        off = offsets[lo:hi] - base
        seg[runs(off, n_midxs[lo:hi] * 12)] = midxs.view(np.uint8)
        b0, b1 = int(bounds[lo]) * 4, int(bounds[hi]) * 4
        seg[runs(off + n_midxs[lo:hi] * 12, nnz[lo:hi] * 4)] = pid_u8[b0:b1]
        seg[runs(off + n_midxs[lo:hi] * 12 + nnz[lo:hi] * 4,
                 nnz[lo:hi] * 8)] = val_u8[b0 * 2:b1 * 2]
        return seg

    # contiguous plane ranges balanced by plane bytes, one per worker
    targets = np.linspace(0, data_bytes, n_workers + 1)[1:-1]
    plane_cuts = [0] + [int(c) for c in
                        np.searchsorted(cum_bytes, targets)] + [n_planes]
    _SEG_TEMP_FACTOR = 10
    seg_budget = max(max_round_bytes // _SEG_TEMP_FACTOR, 1 << 20)

    if data_bytes <= seg_budget:
        # in-budget fast path: one vectorized build, workers only memcpy
        buf = build_segment(0, n_planes) if n_planes else             np.zeros(0, np.uint8)

        def fill(w: int):
            lo = int(offsets[plane_cuts[w]]) if plane_cuts[w] < n_planes                 else data_bytes
            hi = int(offsets[plane_cuts[w + 1]])                 if plane_cuts[w + 1] < n_planes else data_bytes
            mm[data_start + lo:data_start + hi] = buf[lo:hi]
    else:
        # out-of-core: each worker assembles and writes its range in
        # memory-bounded rounds (>= 1 plane per round)
        def fill(w: int):
            lo, hi = plane_cuts[w], plane_cuts[w + 1]
            while lo < hi:
                budget = (int(cum_bytes[lo - 1]) if lo else 0) + seg_budget
                chunk_hi = int(np.searchsorted(cum_bytes, budget,
                                               side="right"))
                chunk_hi = min(max(chunk_hi, lo + 1), hi)
                seg = build_segment(lo, chunk_hi)
                off = data_start + int(offsets[lo])
                mm[off:off + len(seg)] = seg
                if chunk_hi < hi:          # out-of-core round boundary
                    mm.flush()
                lo = chunk_hi

    if n_workers > 1:
        with ThreadPoolExecutor(n_workers) as ex:
            list(ex.map(fill, range(n_workers)))
    else:
        fill(0)
    # release the mapping without a synchronous msync: munmap leaves the
    # dirty pages in the unified page cache (immediately visible to every
    # subsequent reader) and the OS writes them back asynchronously — a
    # blocking flush of the whole cube serialized the aggregation tail
    # for ~1s per cube on this container's filesystem
    del mm
    return {"bytes": total, "nnz": int(len(val)), "n_ctx": int(len(uctx))}


class CMSReader:
    def __init__(self, path: str):
        self._mm = np.memmap(path, np.uint8, "r")
        assert bytes(self._mm[:4]) == CMS_MAGIC
        (hlen,) = struct.unpack("<I", self._mm[4:8])
        self.header = json.loads(bytes(self._mm[8:8 + hlen]))
        p0 = 8 + hlen
        (n_ctx,) = struct.unpack("<I", self._mm[p0:p0 + 4])
        idx = np.frombuffer(self._mm[p0 + 4:p0 + 4 + n_ctx * 24],
                            np.int64).reshape(-1, 3)
        self._ctx_ids = idx[:, 0]
        self._n_midxs = (idx[:, 1] >> 32).astype(np.int64)
        self._nnz = (idx[:, 1] & 0xFFFFFFFF).astype(np.int64)
        self._offsets = idx[:, 2]

    def contexts(self) -> np.ndarray:
        return self._ctx_ids

    def _plane(self, ctx: int):
        i = int(np.searchsorted(self._ctx_ids, ctx))
        if i >= len(self._ctx_ids) or self._ctx_ids[i] != ctx:
            return None
        off = int(self._offsets[i])
        nm = int(self._n_midxs[i])
        nv = int(self._nnz[i])
        midxs = np.frombuffer(self._mm[off:off + nm * 12],
                              dtype=[("m", "<u4"), ("s", "<u8")])
        off += nm * 12
        pids = np.frombuffer(self._mm[off:off + nv * 4], "<u4")
        off += nv * 4
        vals = np.frombuffer(self._mm[off:off + nv * 8], "<f8")
        return midxs, pids, vals

    def metric_values(self, ctx: int, metric: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """All (profile, value) pairs for one (ctx, metric): O(log m)."""
        plane = self._plane(ctx)
        if plane is None:
            return np.zeros(0, np.uint32), np.zeros(0, np.float64)
        midxs, pids, vals = plane
        ms = midxs["m"].astype(np.int64)
        j = int(np.searchsorted(ms[:-1], metric))
        if j >= len(ms) - 1 or ms[j] != metric:
            return np.zeros(0, np.uint32), np.zeros(0, np.float64)
        lo, hi = int(midxs["s"][j]), int(midxs["s"][j + 1])
        return pids[lo:hi], vals[lo:hi]

    def lookup(self, ctx: int, metric: int, profile: int) -> float:
        """O(log m + log p) single-value access (paper complexity claim)."""
        pids, vals = self.metric_values(ctx, metric)
        k = int(np.searchsorted(pids, profile))
        if k < len(pids) and pids[k] == profile:
            return float(vals[k])
        return 0.0

    def plane_triplets(self, ctx: int
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One context plane as ``(profile, metric, value)`` COO arrays,
        in stored (metric-major) order — copies, safe to keep after the
        reader goes away."""
        plane = self._plane(ctx)
        if plane is None:
            z = np.zeros(0, np.int64)
            return z, z, np.zeros(0, np.float64)
        midxs, pids, vals = plane
        starts = midxs["s"].astype(np.int64)     # last entry = sentinel nnz
        counts = starts[1:] - starts[:-1]
        mets = np.repeat(midxs["m"][:-1].astype(np.int64), counts)
        return pids.astype(np.int64), mets, np.array(vals, np.float64)


def read_cms(path: str) -> List[ProfileValues]:
    """Full CMS round-trip: reconstruct every profile's sparse values from
    the CCT-major cube (per-profile arrays in row-major (ctx, metric)
    order — the order ``aggregate`` streams them in)."""
    r = CMSReader(path)
    ctx_l, pid_l, met_l, val_l = [], [], [], []
    for ctx in r.contexts().tolist():
        pids, mets, vals = r.plane_triplets(int(ctx))
        pid_l.append(pids)
        met_l.append(mets)
        val_l.append(vals)
        ctx_l.append(np.full(len(pids), int(ctx), np.int64))
    if not ctx_l:
        return []
    ctx = np.concatenate(ctx_l)
    pid = np.concatenate(pid_l)
    met = np.concatenate(met_l)
    val = np.concatenate(val_l)
    order = np.lexsort((met, ctx, pid))
    ctx, pid, met, val = ctx[order], pid[order], met[order], val[order]
    upids, starts = np.unique(pid, return_index=True)
    bounds = np.append(starts, len(pid))
    return [ProfileValues(int(upids[i]),
                          ctx[bounds[i]:bounds[i + 1]].astype(np.uint32),
                          met[bounds[i]:bounds[i + 1]].astype(np.uint32),
                          val[bounds[i]:bounds[i + 1]])
            for i in range(len(upids))]


# =========================================================================
# PMS
# =========================================================================
def write_pms(path: str, profiles: List[ProfileValues], *,
              n_workers: int = 4) -> dict:
    """Profile-major cube: one CSR plane per profile (work split by
    profile count — the paper's PMS load-balance rule)."""
    sizes = []
    for p in profiles:
        n_ctx_rows = len(np.unique(p.ctx)) + 1
        sizes.append(n_ctx_rows * 12 + len(p.ctx) * 12)
    offsets = _exscan(sizes)
    header = {"n_profiles": len(profiles)}
    hdr = json.dumps(header).encode()
    index_bytes = len(profiles) * 24
    data_start = 8 + len(hdr) + 4 + index_bytes
    total = data_start + sum(sizes)

    with open(path, "wb") as f:
        f.truncate(total)
    mm = np.memmap(path, np.uint8, "r+")
    mm[:4] = np.frombuffer(PMS_MAGIC, np.uint8)
    mm[4:8] = np.frombuffer(struct.pack("<I", len(hdr)), np.uint8)
    mm[8:8 + len(hdr)] = np.frombuffer(hdr, np.uint8)
    p0 = 8 + len(hdr)
    mm[p0:p0 + 4] = np.frombuffer(struct.pack("<I", len(profiles)), np.uint8)
    idx = np.zeros((len(profiles), 3), np.int64)
    for i, p in enumerate(profiles):
        idx[i] = (p.profile_id, len(p.ctx), offsets[i] + data_start)
    mm[p0 + 4:p0 + 4 + index_bytes] = np.frombuffer(idx.tobytes(), np.uint8)

    def fill(i: int):
        p = profiles[i]
        order = np.lexsort((p.metric, p.ctx))
        ctx = p.ctx[order]
        met = p.metric[order]
        vals = p.values[order]
        uc, starts = np.unique(ctx, return_index=True)
        rows = np.zeros((len(uc) + 1, 1),
                        dtype=[("c", "<u4"), ("s", "<u8")])
        rows["c"][:-1, 0] = uc
        rows["s"][:-1, 0] = starts
        rows["c"][-1, 0] = 0xFFFFFFFF
        rows["s"][-1, 0] = len(ctx)
        blob = (rows.tobytes() + met.astype("<u4").tobytes()
                + vals.astype("<f8").tobytes())
        off = int(idx[i, 2])
        mm[off:off + len(blob)] = np.frombuffer(blob, np.uint8)

    if n_workers > 1:
        with ThreadPoolExecutor(n_workers) as ex:
            list(ex.map(fill, range(len(profiles))))
    else:
        for i in range(len(profiles)):
            fill(i)
    del mm     # no synchronous msync — see write_cms
    return {"bytes": total}


class PMSReader:
    def __init__(self, path: str):
        self._mm = np.memmap(path, np.uint8, "r")
        assert bytes(self._mm[:4]) == PMS_MAGIC
        (hlen,) = struct.unpack("<I", self._mm[4:8])
        self.header = json.loads(bytes(self._mm[8:8 + hlen]))
        p0 = 8 + hlen
        (n,) = struct.unpack("<I", self._mm[p0:p0 + 4])
        idx = np.frombuffer(self._mm[p0 + 4:p0 + 4 + n * 24],
                            np.int64).reshape(-1, 3)
        self._pids = idx[:, 0]
        self._nnz = idx[:, 1]
        self._offsets = idx[:, 2]

    def profile_plane(self, profile: int):
        i = int(np.searchsorted(self._pids, profile))
        if i >= len(self._pids) or self._pids[i] != profile:
            return None
        off = int(self._offsets[i])
        nv = int(self._nnz[i])
        # planes are laid out in index order, so the next plane's offset
        # (or the file end) bounds this one: row count falls out without
        # scanning for the sentinel record by record
        end = int(self._offsets[i + 1]) if i + 1 < len(self._offsets) \
            else len(self._mm)
        n_rows = (end - off - nv * 12) // 12
        raw = np.frombuffer(self._mm[off:off + n_rows * 12],
                            dtype=[("c", "<u4"), ("s", "<u8")])
        rows = list(zip(raw["c"].tolist(), raw["s"].tolist()))
        off += n_rows * 12
        mets = np.frombuffer(self._mm[off:off + nv * 4], "<u4")
        off += nv * 4
        vals = np.frombuffer(self._mm[off:off + nv * 8], "<f8")
        return rows, mets, vals

    def context_values(self, profile: int, ctx: int) -> Dict[int, float]:
        plane = self.profile_plane(profile)
        if plane is None:
            return {}
        rows, mets, vals = plane
        cs = np.array([r[0] for r in rows], np.int64)
        j = int(np.searchsorted(cs[:-1], ctx))
        if j >= len(cs) - 1 or cs[j] != ctx:
            return {}
        lo, hi = rows[j][1], rows[j + 1][1]
        return {int(m): float(v) for m, v in zip(mets[lo:hi], vals[lo:hi])}

    def profile_ids(self) -> np.ndarray:
        return self._pids

    def profile_values(self, profile: int) -> Optional[ProfileValues]:
        """One profile's full sparse values, bitwise as written: the plane
        is stored row-major in (ctx, metric), which is exactly the order
        ``aggregate`` emits, so PMS -> ``profile_values`` -> ``write_pms``
        round-trips byte-identically.  Arrays are copies (safe to keep
        while the underlying file is rewritten, e.g. an in-place
        incremental merge)."""
        plane = self.profile_plane(profile)
        if plane is None:
            return None
        rows, mets, vals = plane
        counts = np.diff([r[1] for r in rows])
        ctx = np.repeat(np.array([r[0] for r in rows[:-1]], np.int64),
                        counts)
        return ProfileValues(profile, ctx.astype(np.uint32),
                             np.array(mets, np.uint32),
                             np.array(vals, np.float64))


def read_pms(path: str) -> List[ProfileValues]:
    """Full PMS round-trip: every profile's sparse values, ascending
    profile id (the canonical order ``aggregate`` assigned)."""
    r = PMSReader(path)
    out = []
    for pid in r.profile_ids().tolist():
        pv = r.profile_values(int(pid))
        if pv is not None:
            out.append(pv)
    return out


def dense_cube_nbytes(n_profiles: int, n_ctx: int, n_metrics: int) -> int:
    """Size of the dense (profile x context x metric) cube (§8.2)."""
    return n_profiles * n_ctx * n_metrics * 8
