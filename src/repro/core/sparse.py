"""PMS / CMS sparse-cube analysis formats (paper §6.2, Fig. 4).

The analysis result is a sparse cube indexed by (profile, context, metric).
Two complementary layouts, each a stack of modified-CSR planes:

- **PMS (Profile-Major Sparse)**: one plane per profile -> compare metrics
  *within* a thread/stream; plane = CSR over (context -> metric, value).
- **CMS (CCT-Major Sparse)**: one plane per context -> compare a metric
  *across* profiles; plane = sparse ``midxs`` array of (metric id, start)
  pairs (many metrics are empty for a context, so even the CSR row array is
  sparsified — the paper's key refinement), then ``pids`` and ``vals``.

Access costs (asserted by tests, matching §6.2): plane locate O(1) via the
offsets vector, metric locate O(log m) by binary search in midxs, a single
(ctx, metric, profile) value O(log m + log p).

Construction mirrors hpcprof-mpi: workers are assigned profiles (PMS) or
contexts *balanced by non-zero count* (CMS); an exscan over plane sizes
yields every worker's write offset; workers then fill a preallocated
memmap concurrently without further communication, in bounded-memory
rounds (out-of-core).
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

CMS_MAGIC = b"RCMS"
PMS_MAGIC = b"RPMS"


@dataclasses.dataclass
class ProfileValues:
    """Sparse values of one profile: parallel arrays (ctx, metric, value)."""
    profile_id: int
    ctx: np.ndarray        # (V,) uint32
    metric: np.ndarray     # (V,) uint32
    values: np.ndarray     # (V,) float64


def _exscan(sizes: Sequence[int]) -> List[int]:
    out = [0]
    for s in sizes[:-1]:
        out.append(out[-1] + int(s))
    return out


# =========================================================================
# CMS
# =========================================================================
def write_cms(path: str, profiles: List[ProfileValues], *,
              n_workers: int = 4, max_round_bytes: int = 1 << 28) -> dict:
    """Builds the CCT-major cube.  Returns size stats."""
    # --- transpose to per-context COO (vectorized) --------------------------
    ctx = np.concatenate([p.ctx for p in profiles]) if profiles else \
        np.zeros(0, np.uint32)
    met = np.concatenate([p.metric for p in profiles]) if profiles else \
        np.zeros(0, np.uint32)
    val = np.concatenate([p.values for p in profiles]) if profiles else \
        np.zeros(0, np.float64)
    pid = np.concatenate([np.full(len(p.ctx), p.profile_id, np.uint32)
                          for p in profiles]) if profiles else \
        np.zeros(0, np.uint32)
    # sort by (ctx, metric, profile)
    order = np.lexsort((pid, met, ctx))
    ctx, met, val, pid = ctx[order], met[order], val[order], pid[order]

    uctx, starts = np.unique(ctx, return_index=True)
    bounds = np.append(starts, len(ctx))

    # per-context plane sizes: midx entries + sentinel, pids, vals
    # (vectorized: unique (ctx, metric) pairs -> metric count per context)
    pair = (ctx.astype(np.int64) << 32) | met.astype(np.int64)
    upair_ctx = (np.unique(pair) >> 32).astype(np.int64)
    _, m_counts = np.unique(upair_ctx, return_counts=True)
    n_midxs = m_counts + 1  # + sentinel
    nnz = bounds[1:] - bounds[:-1]
    plane_bytes = n_midxs * 12 + nnz * (4 + 8)
    offsets = np.zeros(len(uctx), np.int64)
    np.cumsum(plane_bytes[:-1], out=offsets[1:len(uctx)])

    header = {
        "n_ctx": int(len(uctx)),
        "n_profiles": int(len(profiles)),
        "nnz": int(len(val)),
    }
    hdr = json.dumps(header).encode()
    index_bytes = len(uctx) * 24
    data_start = 4 + 4 + len(hdr) + 4 + index_bytes
    total = data_start + int(plane_bytes.sum())

    with open(path, "wb") as f:
        f.truncate(total)
    mm = np.memmap(path, np.uint8, "r+")
    mm[:4] = np.frombuffer(CMS_MAGIC, np.uint8)
    mm[4:8] = np.frombuffer(struct.pack("<I", len(hdr)), np.uint8)
    mm[8:8 + len(hdr)] = np.frombuffer(hdr, np.uint8)
    p0 = 8 + len(hdr)
    mm[p0:p0 + 4] = np.frombuffer(struct.pack("<I", len(uctx)), np.uint8)
    # context index: (ctx_id u32, nnz u32, abs offset u64, n_midxs u32) = 20B
    # pad to 24 for alignment
    idx = np.zeros((len(uctx), 3), np.int64)
    idx[:, 0] = uctx
    idx[:, 1] = (n_midxs << 32) | nnz
    idx[:, 2] = offsets + data_start
    mm[p0 + 4:p0 + 4 + index_bytes] = np.frombuffer(idx.tobytes(), np.uint8)

    # --- parallel plane fill: contexts balanced by nnz, bounded rounds ------
    work = list(range(len(uctx)))
    # greedy balance by non-zeros (paper: CMS load-balances on nnz)
    work.sort(key=lambda i: -int(nnz[i]))
    buckets: List[List[int]] = [[] for _ in range(n_workers)]
    loads = [0] * n_workers
    for i in work:
        b = loads.index(min(loads))
        buckets[b].append(i)
        loads[b] += int(nnz[i])

    def fill(bucket: List[int]):
        spent = 0
        for i in bucket:
            lo, hi = bounds[i], bounds[i + 1]
            seg_m = met[lo:hi]
            seg_p = pid[lo:hi]
            seg_v = val[lo:hi]
            um, ustarts = np.unique(seg_m, return_index=True)
            midxs = np.zeros((len(um) + 1, 1),
                             dtype=[("m", "<u4"), ("s", "<u8")])
            midxs["m"][:-1, 0] = um
            midxs["s"][:-1, 0] = ustarts
            midxs["m"][-1, 0] = 0xFFFFFFFF
            midxs["s"][-1, 0] = hi - lo
            off = int(idx[i, 2])
            blob = (midxs.tobytes() + seg_p.astype("<u4").tobytes()
                    + seg_v.astype("<f8").tobytes())
            mm[off:off + len(blob)] = np.frombuffer(blob, np.uint8)
            spent += len(blob)
            if spent >= max_round_bytes:   # out-of-core round boundary
                mm.flush()
                spent = 0

    if n_workers > 1:
        with ThreadPoolExecutor(n_workers) as ex:
            list(ex.map(fill, buckets))
    else:
        for b in buckets:
            fill(b)
    mm.flush()
    return {"bytes": total, "nnz": int(len(val)), "n_ctx": int(len(uctx))}


class CMSReader:
    def __init__(self, path: str):
        self._mm = np.memmap(path, np.uint8, "r")
        assert bytes(self._mm[:4]) == CMS_MAGIC
        (hlen,) = struct.unpack("<I", self._mm[4:8])
        self.header = json.loads(bytes(self._mm[8:8 + hlen]))
        p0 = 8 + hlen
        (n_ctx,) = struct.unpack("<I", self._mm[p0:p0 + 4])
        idx = np.frombuffer(self._mm[p0 + 4:p0 + 4 + n_ctx * 24],
                            np.int64).reshape(-1, 3)
        self._ctx_ids = idx[:, 0]
        self._n_midxs = (idx[:, 1] >> 32).astype(np.int64)
        self._nnz = (idx[:, 1] & 0xFFFFFFFF).astype(np.int64)
        self._offsets = idx[:, 2]

    def contexts(self) -> np.ndarray:
        return self._ctx_ids

    def _plane(self, ctx: int):
        i = int(np.searchsorted(self._ctx_ids, ctx))
        if i >= len(self._ctx_ids) or self._ctx_ids[i] != ctx:
            return None
        off = int(self._offsets[i])
        nm = int(self._n_midxs[i])
        nv = int(self._nnz[i])
        midxs = np.frombuffer(self._mm[off:off + nm * 12],
                              dtype=[("m", "<u4"), ("s", "<u8")])
        off += nm * 12
        pids = np.frombuffer(self._mm[off:off + nv * 4], "<u4")
        off += nv * 4
        vals = np.frombuffer(self._mm[off:off + nv * 8], "<f8")
        return midxs, pids, vals

    def metric_values(self, ctx: int, metric: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """All (profile, value) pairs for one (ctx, metric): O(log m)."""
        plane = self._plane(ctx)
        if plane is None:
            return np.zeros(0, np.uint32), np.zeros(0, np.float64)
        midxs, pids, vals = plane
        ms = midxs["m"].astype(np.int64)
        j = int(np.searchsorted(ms[:-1], metric))
        if j >= len(ms) - 1 or ms[j] != metric:
            return np.zeros(0, np.uint32), np.zeros(0, np.float64)
        lo, hi = int(midxs["s"][j]), int(midxs["s"][j + 1])
        return pids[lo:hi], vals[lo:hi]

    def lookup(self, ctx: int, metric: int, profile: int) -> float:
        """O(log m + log p) single-value access (paper complexity claim)."""
        pids, vals = self.metric_values(ctx, metric)
        k = int(np.searchsorted(pids, profile))
        if k < len(pids) and pids[k] == profile:
            return float(vals[k])
        return 0.0


# =========================================================================
# PMS
# =========================================================================
def write_pms(path: str, profiles: List[ProfileValues], *,
              n_workers: int = 4) -> dict:
    """Profile-major cube: one CSR plane per profile (work split by
    profile count — the paper's PMS load-balance rule)."""
    sizes = []
    for p in profiles:
        n_ctx_rows = len(np.unique(p.ctx)) + 1
        sizes.append(n_ctx_rows * 12 + len(p.ctx) * 12)
    offsets = _exscan(sizes)
    header = {"n_profiles": len(profiles)}
    hdr = json.dumps(header).encode()
    index_bytes = len(profiles) * 24
    data_start = 8 + len(hdr) + 4 + index_bytes
    total = data_start + sum(sizes)

    with open(path, "wb") as f:
        f.truncate(total)
    mm = np.memmap(path, np.uint8, "r+")
    mm[:4] = np.frombuffer(PMS_MAGIC, np.uint8)
    mm[4:8] = np.frombuffer(struct.pack("<I", len(hdr)), np.uint8)
    mm[8:8 + len(hdr)] = np.frombuffer(hdr, np.uint8)
    p0 = 8 + len(hdr)
    mm[p0:p0 + 4] = np.frombuffer(struct.pack("<I", len(profiles)), np.uint8)
    idx = np.zeros((len(profiles), 3), np.int64)
    for i, p in enumerate(profiles):
        idx[i] = (p.profile_id, len(p.ctx), offsets[i] + data_start)
    mm[p0 + 4:p0 + 4 + index_bytes] = np.frombuffer(idx.tobytes(), np.uint8)

    def fill(i: int):
        p = profiles[i]
        order = np.lexsort((p.metric, p.ctx))
        ctx = p.ctx[order]
        met = p.metric[order]
        vals = p.values[order]
        uc, starts = np.unique(ctx, return_index=True)
        rows = np.zeros((len(uc) + 1, 1),
                        dtype=[("c", "<u4"), ("s", "<u8")])
        rows["c"][:-1, 0] = uc
        rows["s"][:-1, 0] = starts
        rows["c"][-1, 0] = 0xFFFFFFFF
        rows["s"][-1, 0] = len(ctx)
        blob = (rows.tobytes() + met.astype("<u4").tobytes()
                + vals.astype("<f8").tobytes())
        off = int(idx[i, 2])
        mm[off:off + len(blob)] = np.frombuffer(blob, np.uint8)

    if n_workers > 1:
        with ThreadPoolExecutor(n_workers) as ex:
            list(ex.map(fill, range(len(profiles))))
    else:
        for i in range(len(profiles)):
            fill(i)
    mm.flush()
    return {"bytes": total}


class PMSReader:
    def __init__(self, path: str):
        self._mm = np.memmap(path, np.uint8, "r")
        assert bytes(self._mm[:4]) == PMS_MAGIC
        (hlen,) = struct.unpack("<I", self._mm[4:8])
        self.header = json.loads(bytes(self._mm[8:8 + hlen]))
        p0 = 8 + hlen
        (n,) = struct.unpack("<I", self._mm[p0:p0 + 4])
        idx = np.frombuffer(self._mm[p0 + 4:p0 + 4 + n * 24],
                            np.int64).reshape(-1, 3)
        self._pids = idx[:, 0]
        self._nnz = idx[:, 1]
        self._offsets = idx[:, 2]

    def profile_plane(self, profile: int):
        i = int(np.searchsorted(self._pids, profile))
        if i >= len(self._pids) or self._pids[i] != profile:
            return None
        off = int(self._offsets[i])
        nv = int(self._nnz[i])
        # rows until sentinel
        rows = []
        while True:
            c, s = struct.unpack("<IQ", self._mm[off:off + 12])
            rows.append((c, s))
            off += 12
            if c == 0xFFFFFFFF:
                break
        mets = np.frombuffer(self._mm[off:off + nv * 4], "<u4")
        off += nv * 4
        vals = np.frombuffer(self._mm[off:off + nv * 8], "<f8")
        return rows, mets, vals

    def context_values(self, profile: int, ctx: int) -> Dict[int, float]:
        plane = self.profile_plane(profile)
        if plane is None:
            return {}
        rows, mets, vals = plane
        cs = np.array([r[0] for r in rows], np.int64)
        j = int(np.searchsorted(cs[:-1], ctx))
        if j >= len(cs) - 1 or cs[j] != ctx:
            return {}
        lo, hi = rows[j][1], rows[j + 1][1]
        return {int(m): float(v) for m, v in zip(mets[lo:hi], vals[lo:hi])}


def dense_cube_nbytes(n_profiles: int, n_ctx: int, n_metrics: int) -> int:
    """Size of the dense (profile x context x metric) cube (§8.2)."""
    return n_profiles * n_ctx * n_metrics * 8
