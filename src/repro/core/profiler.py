"""hpcrun-analogue: the user-facing measurement API (paper §3, §4).

Usage::

    prof = Profiler(out_dir, tracing=True)
    mid = prof.register_module("train_step", compiled.as_text())  # GPU binary
    prof.start()
    with prof.dispatch("kernel", "train_step", stream=0, module_id=mid):
        out = step_fn(...)            # timed; samples synthesized on exit
    prof.flush()
    paths = prof.write()              # per-thread + per-stream profiles

Every dispatch unwinds the *calling* Python stack, inserts a placeholder P
in the thread's CCT, and appends OP/ACTIVITY records to its wait-free
per-thread record ring (channels.RecordRing).  Everything else — the
PC-sample draw (sampling.py), hardware-counter reads, and fine-grained
attribution below P (§4.2) — is **deferred**: the monitor thread
(monitor.py) drains the rings in batches and attributes into per-thread
*shadow* CCTs, which graft into the application threads' trees at flush.
The dispatch path itself is a handful of integer stores and two ring
appends, each publishing one cursor.

Determinism with the draw off-thread: the rng is keyed by the
dispatching thread's stable index and its per-thread dispatch sequence
number (sampling.KeyedRng), never by drain order, so the drawn samples
— and therefore the database bytes — are invariant under any monitor
batching or thread interleaving (given ``bind_thread`` pinning thread
indices when more than one thread dispatches).
"""
from __future__ import annotations

import contextlib
import os
import socket
import sys
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import sampling
from repro.core.cct import (CCT, CCTNode, Frame, HOST, PLACEHOLDER,
                            unwind_host_stack)
from repro.core.channels import RingSet
from repro.core.metrics import MetricRegistry, default_registry
from repro.core.monitor import (ACTIVITY, OP, GpuActivity, GpuOperation,
                                MonitorThread)
from repro.core.profmt import write_profile
from repro.core.structure import HloModule, parse_hlo
from repro.core.trace import TraceWriter, pack_dispatch_ctx

# tool frames pruned from host unwinds (matches unwind_host_stack)
_PRUNE = ("repro/core", "threading.py")


class _ThreadState:
    """Everything one application thread owns.

    Single-writer discipline: the app thread writes ``cct`` (host
    contexts, placeholders), ``seq``, ``counts``, and ``trace`` (cpu
    regions); the monitor thread writes ``shadow``/``shadow_cct``,
    ``trace_chunks``, and ``mon_counts``.  The two meet only at flush,
    when the app threads are quiescent and the shadow grafts into
    ``cct``.  The counter tuples are published with a single reference
    store, so any observer reads a consistent snapshot (the
    ``overhead_counters`` race fix)."""

    __slots__ = ("cct", "trace", "trace_chunks", "ring", "seq", "index",
                 "counts", "mon_counts", "ctx_cache", "ph_cache",
                 "app_node", "shadow", "shadow_cct", "snode_cache")

    def __init__(self, cct: CCT, ring, index: int):
        self.cct = cct
        self.trace: List[tuple] = []     # (t0, t1, ctx_id) cpu regions
        self.trace_chunks: List[np.ndarray] = []   # monitor drain batches
        self.ring = ring
        self.seq = 0                     # per-thread dispatch sequence
        self.index = index               # stable thread index (bindable)
        self.counts = (0, 0, 0)          # (tool_ns, app_ns, dispatches)
        self.mon_counts = (0, 0, 0)      # (kept, dropped, deferred_ns)
        self.ctx_cache: Dict[tuple, CCTNode] = {}   # unwind key -> ctx
        self.ph_cache: Dict[tuple, CCTNode] = {}    # placeholder memo
        self.app_node: Optional[CCTNode] = None     # unwind-off context
        self.shadow: Dict[CCTNode, CCTNode] = {}    # placeholder -> shadow
        self.shadow_cct = CCT()
        # (shadow placeholder, module, op, leaf) -> resolved sample node;
        # monitor-only, cleared with the shadow at graft
        self.snode_cache: Dict[tuple, CCTNode] = {}


class Profiler:
    def __init__(self, out_dir: str, *, registry: Optional[MetricRegistry]
                 = None, tracing: bool = True, n_tracing_threads: int = 1,
                 sample_rate_hz: float = 1e6, instrument: bool = False,
                 rank: int = 0, clock: Callable[[], int] = time.monotonic_ns,
                 rng_seed: Optional[int] = None, unwind: bool = True,
                 tag: Optional[str] = None):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.registry = registry or default_registry()
        self.tracing = tracing
        self.sample_rate_hz = sample_rate_hz
        self.instrument = instrument
        self.rank = rank
        self.clock = clock
        self.unwind = unwind
        # continuous profiling (ISSUE 4): an optional measurement tag
        # (epoch / job segment) that lands in every profile & trace
        # identity and in the file names, so successive measurement
        # windows of one rank stay distinct through aggregation,
        # incremental merge, and the trace.db line index
        self.tag = tag
        # always-on serving knobs (ISSUE 7; repro.serving.governor): the
        # effective PC-sampling rate is sample_rate_hz * sample_scale,
        # capped at sample_cap samples per dispatch, and host unwinds
        # stop at unwind_depth frames (0 = single <app> frame).  All
        # three are safe to mutate between dispatches, which is how the
        # overhead governor throttles measurement at run time without
        # ever turning it off (coarse dispatch timing + tracing stay).
        # With the draw deferred, sample_scale/sample_cap shed
        # *monitor-side* cost (deferred_ns) while unwind_depth and the
        # per-record fixed cost are what remain on the dispatch path.
        self.sample_scale = 1.0
        self.sample_cap: Optional[int] = None
        self.unwind_depth = 64
        self._windows = threading.local()
        # deferred-draw rng: keyed per (thread index, dispatch seq), so
        # sampled values are a pure function of the dispatch identity,
        # not of the monitor's drain order (None = the deterministic
        # expectation-rounding path, as before)
        self._keyed = (sampling.KeyedRng(rng_seed)
                       if rng_seed is not None else None)
        self._rings = RingSet()
        self._monitor = MonitorThread(self._rings, self._on_records,
                                      tracing=tracing,
                                      n_tracing_threads=n_tracing_threads)
        self._threads: Dict[int, _ThreadState] = {}
        self._threads_lock = threading.Lock()
        self._next_index = 0
        self._bound_indices: set = set()
        self._modules: Dict[int, HloModule] = {}
        self._module_names: Dict[int, str] = {}
        self._module_costs: Dict[int, dict] = {}
        self._counters = None        # CounterCollector when enabled
        self._op_ctx_cache: Dict[tuple, tuple] = {}   # monitor-thread only
        # precomputed attribution tables (the registry is fixed at init;
        # name->index lookups per record were a measurable monitor cost)
        reg = self.registry
        self._gpu_kinds = {"kernel": reg.kind("gpu_kernel"),
                           "copy": reg.kind("gpu_copy"),
                           "sync": reg.kind("gpu_sync")}
        ikind = reg.kind("gpu_inst")
        midx = {m: i for i, m in enumerate(ikind.metrics)}
        self._ikind = ikind
        self._inst_cols = (midx["samples"], midx["flops"], midx["bytes"],
                           {s: midx[f"stall_{s}"]
                            for s in ("compute", "memory", "collective")})
        self._stream_ccts: Dict[int, CCT] = {}
        self._stream_nodes: Dict[int, dict] = {}   # tracer node memo
        self._stream_lock = threading.Lock()
        self._started = False
        self._host = socket.gethostname()
        self._monitor.trace_sink = self._stream_profile_sink

    # ------------------------------------------------------------------ #
    def register_module(self, name: str, hlo_text: str,
                        cost: Optional[dict] = None) -> int:
        """Record a loaded 'GPU binary' for later analysis (§3).

        ``cost`` is the module's ``compiled.cost_analysis()`` dict; when
        given, hardware-counter readings (enable_counters) calibrate
        their flop/byte totals against it instead of relying purely on
        the parsed estimates."""
        mid = len(self._modules) + 1
        self._modules[mid] = parse_hlo(hlo_text, name=name)
        self._module_names[mid] = name
        if cost is not None:
            # jax may hand back a single-element list
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            self._module_costs[mid] = dict(cost)
        return mid

    def enable_counters(self, counters, *, replay: bool = True):
        """Turn on kernel-granularity hardware-counter collection
        (paper §6; repro.counters).  Returns the multiplex schedule.

        ``replay=True`` serializes replay passes so every requested
        counter is measured on every kernel execution; ``replay=False``
        rotates counter groups across invocations (single-pass
        best-effort multiplexing).  Must be called identically on every
        rank so aggregated profiles agree on the counter columns.
        Readings happen on the monitor thread as records drain, so the
        rotation order is the per-thread record order (deterministic
        for one dispatching thread)."""
        from repro.counters.collector import CounterCollector
        self._counters = CounterCollector(counters, replay=replay)
        return self._counters.schedule

    def module(self, mid: int) -> HloModule:
        return self._modules[mid]

    def register_kernel_structures(self, mid: int, structures,
                                   matches: Optional[Dict[str, str]] = None
                                   ) -> int:
        """Bind recovered kernel-interior structures
        (``repro.core.kstruct.KernelStructure``) to module ``mid``'s
        ``custom-call`` ops.  Subsequent PC samples descend into the
        kernels' interiors (loops / inlined scopes / source lines)
        instead of stopping at the opaque op.  Returns total ops bound.
        Call before ``start()``: the op-context cache it invalidates is
        owned by the monitor thread once measurement is running."""
        mod = self._modules[mid]
        matches = matches or {}
        bound = 0
        for ks in structures:
            bound += mod.bind_kernel_structure(ks, matches.get(ks.name))
        if bound:
            # interior leaves change the per-op context paths
            self._op_ctx_cache = {
                k: v for k, v in self._op_ctx_cache.items() if k[0] != mid}
        return bound

    def start(self):
        if not self._started:
            self._monitor.start()
            self._started = True
        return self

    def stop(self):
        if self._started:
            self._monitor.stop()
            self._started = False

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.flush()
        self.stop()

    # ------------------------------------------------------------------ #
    def _state(self) -> _ThreadState:
        tid = threading.get_ident()
        st = self._threads.get(tid)
        if st is None:
            with self._threads_lock:
                st = self._threads.get(tid)
                if st is None:
                    st = _ThreadState(CCT(), self._rings.ring_for(tid),
                                      self._alloc_index())
                    self._threads[tid] = st
        return st

    def _alloc_index(self) -> int:
        # caller holds _threads_lock
        i = self._next_index
        while i in self._bound_indices:
            i += 1
        self._next_index = i + 1
        return i

    def bind_thread(self, index: int) -> int:
        """Pin the calling thread's stable index — its profile slot
        (``profile_rR_t<index>.rpro``), its trace lane in the packed
        dispatch ctx, and its deferred-draw rng lane.  Threads that
        never bind get registration-order indices, which is
        deterministic for a single dispatching thread but racy across
        several; byte-identical multi-threaded runs therefore bind each
        worker to a fixed index before its first dispatch."""
        index = int(index)
        if index < 0:
            raise ValueError("thread index must be >= 0")
        tid = threading.get_ident()
        with self._threads_lock:
            st = self._threads.get(tid)
            if st is not None and st.seq:
                raise RuntimeError(
                    "bind_thread must precede the thread's first dispatch")
            if index in self._bound_indices or any(
                    s.index == index for t, s in self._threads.items()
                    if t != tid):
                raise ValueError(f"thread index {index} already in use")
            self._bound_indices.add(index)
            if st is None:
                self._threads[tid] = _ThreadState(
                    CCT(), self._rings.ring_for(tid), index)
            else:
                st.index = index
        return index

    # -- host calling context (memoized unwind) ------------------------- #
    def _dispatch_context(self, st: _ThreadState) -> CCTNode:
        """The calling context for a dispatch on this thread.

        The full unwind (frame objects + per-frame tree inserts) is
        memoized per *call chain*: the key is the (code object, line)
        pair of every live frame — the Python analogue of keying on
        return addresses — so a dispatch loop pays one raw stack walk,
        not an unwind.  Recursion depth is captured because recursive
        frames appear once per activation in the chain."""
        depth = self.unwind_depth
        if self.unwind and depth > 0:
            try:
                # 0=_dispatch_context, 1=_Dispatch.__enter__, 2=the
                # dispatch site (the `with` statement's frame)
                f = sys._getframe(2)
            except ValueError:
                f = None
            key = [depth]
            d = 0
            while f is not None and d < depth:
                key.append(f.f_code)
                key.append(f.f_lineno)
                f = f.f_back
                d += 1
            key = tuple(key)
            node = st.ctx_cache.get(key)
            if node is None:
                frames = [Frame(HOST, c.co_name, c.co_filename, line)
                          for c, line in zip(key[1::2], key[2::2])
                          if not any(p in c.co_filename for p in _PRUNE)]
                node = st.cct.insert_path(frames[::-1])
                st.ctx_cache[key] = node
        else:
            node = st.app_node
            if node is None:
                node = st.app_node = st.cct.insert_path(
                    [Frame(HOST, "<app>", "", 0)])
        wf = getattr(self._windows, "frames", None)
        if wf:
            # window stamping rides the record: the frames are baked
            # into the ctx/placeholder nodes *here*, at dispatch time,
            # so deferred attribution sees the window that was open
            # when the dispatch happened, not drain-time state
            for frame in wf:
                node = st.cct.get_or_insert(node, frame)
        return node

    def _host_context(self, st: _ThreadState, name: str) -> CCTNode:
        # the non-hot-path unwind (cpu_region): full frame construction
        if self.unwind and self.unwind_depth > 0:
            frames = unwind_host_stack(skip=3, max_depth=self.unwind_depth)
        else:
            frames = [Frame(HOST, "<app>", "", 0)]
        node = st.cct.insert_path(frames)
        for wf in self._window_frames():
            node = st.cct.get_or_insert(node, wf)
        return node

    # -- measurement windows (ISSUE 7: per-request serving attribution) --
    def _window_frames(self) -> list:
        frames = getattr(self._windows, "frames", None)
        if frames is None:
            frames = self._windows.frames = []
        return frames

    @contextlib.contextmanager
    def window(self, *frames: Frame):
        """A measurement window: while open on this thread, ``frames``
        are spliced between the unwound host stack and every dispatch
        placeholder / cpu_region, so the aggregated database attributes
        the enclosed GPU and CPU work to the window (the per-request /
        per-phase identities of ``repro.serving.window``).  Windows
        nest; frames ride the CCT the same way ``dispatch_profiles``
        rides ctx bits — no file-format change."""
        stack = self._window_frames()
        n = len(stack)
        stack.extend(frames)
        try:
            yield
        finally:
            del stack[n:]

    @contextlib.contextmanager
    def window_exclusive(self, *frames: Frame):
        """Like ``window`` but *replaces* the thread's current window
        stack for the duration instead of nesting under it.  This is the
        continuous-batching primitive (repro.serving.window.RequestWindow
        .step): overlapping request windows on one serving thread stamp
        each dispatch with exactly one request's frames, so interleaved
        decode steps never double-count under whichever window happened
        to open first."""
        stack = self._window_frames()
        saved = stack[:]
        stack[:] = list(frames)
        try:
            yield
        finally:
            stack[:] = saved

    def overhead_counters(self) -> Dict[str, int]:
        """Cumulative dispatch-path self-accounting (the governor's
        input): tool time vs application time, dispatch count, the
        PC-sample kept/dropped tally under the current throttle, and
        ``deferred_ns`` — monitor-thread time spent on the deferred
        draw/attribution (off the dispatch path, reported for
        visibility).  Every per-thread contribution is published as one
        tuple store per update, so a snapshot taken mid-dispatch is
        always internally consistent (no tool_ns-without-dispatches
        torn reads); kept/dropped lag the dispatch counters by at most
        one monitor drain."""
        tool = app = n = kept = dropped = deferred = 0
        for st in list(self._threads.values()):
            t, a, d = st.counts
            k, dr, df = st.mon_counts
            tool += t
            app += a
            n += d
            kept += k
            dropped += dr
            deferred += df
        return {"tool_ns": tool, "app_ns": app, "dispatches": n,
                "samples_kept": kept, "samples_dropped": dropped,
                "deferred_ns": deferred}

    def dispatch(self, kind: str, name: str, *, stream: int = 0,
                 module_id: Optional[int] = None, nbytes: int = 0,
                 duration_ns: Optional[int] = None) -> "_Dispatch":
        """Times the enclosed GPU operation and attributes it.

        ``duration_ns`` overrides the measured wall time (used when the
        caller has a better device-side estimate, e.g. from events).

        The hot path (``_Dispatch``): memoized host-context lookup, two
        wait-free ring appends (OP at entry, ACTIVITY + trace-lane row
        at exit), and one published counter tuple.  The PC-sample draw,
        counter reads, metric attribution, and trace appends all happen
        on the monitor thread as the ring drains."""
        return _Dispatch(self, kind, name, stream, module_id, nbytes,
                         duration_ns)

    @contextlib.contextmanager
    def cpu_region(self, name: str):
        """Marks CPU work for the trace/blame views."""
        st = self._state()
        node = st.cct.insert_path([Frame(HOST, name, "", 0)],
                                  parent=self._host_context(st, name))
        t0 = self.clock()
        try:
            yield
        finally:
            t1 = self.clock()
            node.metrics.add(self.registry.kind("cpu"), "time_ns", t1 - t0)
            st.trace.append((t0, t1, node.node_id))

    # -- the monitor-side record handler -------------------------------- #
    def _on_records(self, tid: int, payloads: list, lane: np.ndarray):
        """Process one drained ring batch (monitor thread only): the
        deferred PC-sample draw (rng keyed by (thread index, seq) —
        drain-order invariant), deferred counter reads, attribution
        into the thread's shadow CCT, and one buffered trace chunk.
        Returns completed (activity, placeholder) pairs for trace
        routing plus monitor stat increments."""
        t_h0 = time.monotonic_ns()
        st = self._threads[tid]
        keyed = self._keyed
        counters = self._counters
        shadow = st.shadow
        # the dispatching app thread rides the activity record: the
        # tracing threads stamp it into GPU-stream trace events so
        # aggregation can convert their app-thread CCT node ids through
        # this thread's profile (pipeline.traceconv).  One dict per
        # drain, shared read-only by every activity in the batch; only
        # a counter read forks a private copy (its vector is per record)
        shared_meta = {"dispatch_tid": tid}
        acts: List[tuple] = []
        rows: List[int] = []
        n_ops = n_act = n_counter = 0
        kept_add = dropped_add = 0
        lane_py = lane.tolist()    # one bulk convert beats per-field int()
        for i, rec in enumerate(payloads):
            if rec[0] == OP:
                n_ops += 1
                continue
            (_, seq, kind, name, stream, module_id, placeholder,
             nbytes, n_budget, base) = rec
            n_act += 1
            t0, t1, _ctx = lane_py[i]
            samples = None
            meta = shared_meta
            if n_budget:
                mod = self._modules[module_id]
                if n_budget < 0:
                    samples = getattr(mod, "_inst_counts_cache", None)
                    if samples is None:
                        samples = sampling.instruction_counts(mod)
                        mod._inst_counts_cache = samples
                else:
                    rng = (keyed.stream(st.index, seq)
                           if keyed is not None else None)
                    samples = sampling.draw_samples(mod, n_budget, rng)
                    k = 0
                    for s in samples:
                        k += s.count
                    kept_add += k
                    if base > k:
                        dropped_add += base - k
                if counters is not None:
                    meta = {"dispatch_tid": tid,
                            "counters": counters.read(
                                mod, t1 - t0,
                                self._module_costs.get(module_id))}
                    n_counter += 1
            act = GpuActivity(seq, kind, name, stream, t0, t1,
                              bytes=nbytes, samples=samples,
                              module_id=module_id, meta=meta)
            sh = shadow.get(placeholder)
            if sh is None:
                sh = self._shadow_node(st, placeholder)
            self._attribute(st, act, sh)
            rows.append(i)
            acts.append((act, placeholder))
        if rows:
            # one buffered trace chunk per drain (TraceWriter adopts
            # these wholesale at write time — append_chunk)
            st.trace_chunks.append(lane[np.asarray(rows, np.intp)])
        mc = st.mon_counts
        st.mon_counts = (mc[0] + kept_add, mc[1] + dropped_add,
                         mc[2] + (time.monotonic_ns() - t_h0))
        return acts, {"ops": n_ops, "activities": n_act,
                      "counter_records": n_counter}

    def _shadow_node(self, st: _ThreadState, placeholder: CCTNode
                     ) -> CCTNode:
        """The monitor-side stand-in for a dispatch placeholder.  Keyed
        by placeholder *identity* (equal frames under different host
        contexts stay distinct); grafted under the real placeholder at
        flush."""
        sh = st.shadow.get(placeholder)
        if sh is None:
            sh = st.shadow_cct._new_node(placeholder.frame, None)
            st.shadow[placeholder] = sh
        return sh

    @staticmethod
    def _metric_row(node: CCTNode, kind) -> np.ndarray:
        # the kind's dense row on this node, created on first touch —
        # the monitor-side fast path around NodeMetrics.add's
        # name->index scan.  Scalar in-place adds on the row produce
        # bit-identical results to the equivalent add()/add_vec() calls
        # in the same per-record order.
        kinds = node.metrics._kinds
        arr = kinds.get(kind.kind_id)
        if arr is None:
            arr = kinds[kind.kind_id] = np.zeros(len(kind.metrics),
                                                 np.float64)
        return arr

    def _attribute(self, st: _ThreadState, act: GpuActivity,
                   node: CCTNode):
        """Attribute one activity's metrics below ``node`` (the shadow
        placeholder) in the thread's shadow CCT — monitor thread only."""
        kind = self._gpu_kinds.get(act.kind, self._gpu_kinds["kernel"])
        arr = self._metric_row(node, kind)
        arr[0] += 1                      # invocations
        arr[1] += act.duration           # time_ns
        if act.kind == "copy" and act.bytes:
            arr[2] += act.bytes
        if act.meta is not None:
            cvec = act.meta.get("counters")
            if cvec is not None:
                node.metrics.add_vec(self.registry.kind("gpu_counter"),
                                     cvec)
        if act.samples and act.module_id is not None:
            mod = self._modules[act.module_id]
            ops = mod.all_ops()
            total = sum(s.count for s in act.samples) or 1
            # gpu_inst layout: (samples, stall_*, flops, bytes) — four
            # scalar adds per sample on the node's dense row
            ikind = self._ikind
            i_samp, i_fl, i_by, stall_col = self._inst_cols
            kstructs = mod.kernel_structures()
            shadow_cct = st.shadow_cct
            snode_cache = st.snode_cache
            for s in act.samples:
                op = ops[s.op_index] if s.op_index < len(ops) else None
                if op is None:
                    continue
                leaf = getattr(s, "leaf", -1)
                key = (act.module_id, s.op_index, leaf)
                # insert_path is idempotent, so the resolved node memoizes
                # per (shadow placeholder, op context) — repeat dispatches
                # of the same module skip the frame walk entirely
                snode = snode_cache.get((node, key))
                if snode is None:
                    frames = self._op_ctx_cache.get(key)
                    if frames is None:
                        frames = tuple(mod.op_context(op))
                        if leaf >= 0:
                            # kernel-interior descent (kstruct): the leaf's
                            # GPU_FUNC/GPU_LOOP/GPU_OP chain hangs under the
                            # kernel's own GPU_OP context — interiors ride
                            # the database as ordinary tree paths
                            ks = kstructs.get(s.op_index)
                            if ks is not None and leaf < len(ks.leaves):
                                frames = frames + ks.leaf_frames(leaf)
                        self._op_ctx_cache[key] = frames
                    snode = shadow_cct.insert_path(frames, parent=node)
                    snode_cache[(node, key)] = snode
                fl, by = op.flops, op.bytes
                if leaf >= 0:
                    ks = kstructs.get(s.op_index)
                    if ks is not None and leaf < len(ks.leaves):
                        fl, by = ks.leaves[leaf].flops, ks.leaves[leaf].bytes
                sarr = self._metric_row(snode, ikind)
                c = s.count
                sarr[i_samp] += c
                sarr[stall_col[s.stall]] += c
                sarr[i_fl] += fl * c / total
                sarr[i_by] += by * c / total

    def _stream_profile_sink(self, stream: int, pairs: list):
        """Builds per-GPU-stream profiles on the tracing threads — one
        call per drained trace batch, the lock taken once and the
        per-(kind, name) placeholder node memoized."""
        with self._stream_lock:
            cct = self._stream_ccts.get(stream)
            if cct is None:
                cct = self._stream_ccts[stream] = CCT()
                self._stream_nodes[stream] = {}
            memo = self._stream_nodes[stream]
            gpu_kinds = self._gpu_kinds
            for act, _placeholder in pairs:
                key = (act.kind, act.name)
                node = memo.get(key)
                if node is None:
                    node = cct.insert_path(
                        [Frame(PLACEHOLDER, f"{act.kind}:{act.name}",
                               str(stream), 0)])
                    memo[key] = node
                kind = gpu_kinds.get(act.kind, gpu_kinds["kernel"])
                arr = self._metric_row(node, kind)
                arr[0] += 1
                arr[1] += act.duration
                if act.meta is not None:
                    cvec = act.meta.get("counters")
                    if cvec is not None:
                        node.metrics.add_vec(
                            self.registry.kind("gpu_counter"), cvec)

    # -- the shadow graft ------------------------------------------------ #
    def _graft_shadow(self) -> None:
        """Merge every thread's monitor-built shadow tree under its real
        placeholders.  Called at flush/write, when both the dispatching
        threads and the monitor are quiescent (the only moment the two
        single-writer domains may touch).  Idempotent: grafted shadows
        are consumed."""
        for st in list(self._threads.values()):
            if not st.shadow:
                continue
            shadow, st.shadow = st.shadow, {}
            st.shadow_cct = CCT()
            st.snode_cache = {}
            for placeholder, sh in shadow.items():
                self._graft_node(st.cct, placeholder, sh)

    @classmethod
    def _graft_node(cls, cct: CCT, real: CCTNode, sh: CCTNode) -> None:
        real.metrics.merge_from(sh.metrics)
        for frame, child in sh.children.items():
            cls._graft_node(cct, cct.get_or_insert(real, frame), child)

    # ------------------------------------------------------------------ #
    def flush(self, timeout: float = 10.0) -> bool:
        """Quiesce the monitor (all rings + trace channels drained,
        in-flight batches routed), then graft the shadow CCTs into the
        per-thread trees.  Dispatching threads must be quiescent."""
        ok = self._monitor.quiesce(timeout)
        self._graft_shadow()
        return ok

    def write(self) -> Dict[str, str]:
        """Writes all profiles + traces.  Returns {label: path}."""
        self._graft_shadow()    # no-op when flush already ran
        out: Dict[str, str] = {}
        mods = [self._module_names[m] for m in sorted(self._modules)]
        fp = f"{self.tag}_" if self.tag else ""

        def identity(**kw) -> Dict[str, object]:
            ident = {"host": self._host, "rank": self.rank, **kw}
            if self.tag is not None:
                ident["tag"] = self.tag
            return ident

        ordered = sorted(self._threads.items(),
                         key=lambda kv: (kv[1].index, kv[0]))
        for tid, st in ordered:
            i = st.index
            ident = identity(thread=i, type="cpu")
            path = os.path.join(self.out_dir,
                                f"profile_{fp}r{self.rank}_t{i}.rpro")
            write_profile(path, st.cct, self.registry, ident, mods)
            out[f"cpu_{i}"] = path
            tw = TraceWriter(path.replace(".rpro", ".rtrc"), ident)
            # dispatch events arrive as monitor drain chunks (batched
            # trace appends); cpu_region events as scalar tuples.  The
            # reader sorts by start when flagged (§4.4), so the
            # concatenation order only needs to be deterministic.
            for chunk in st.trace_chunks:
                tw.append_chunk(chunk)
            recs = np.asarray(st.trace, np.uint64).reshape(-1, 3)
            tw.append_many(recs[:, 0], recs[:, 1], recs[:, 2])
            tw.close()
            out[f"cpu_trace_{i}"] = tw.path
        with self._stream_lock:
            streams = dict(self._stream_ccts)
        for sid, cct in sorted(streams.items()):
            ident = identity(stream=sid, type="gpu")
            path = os.path.join(self.out_dir,
                                f"profile_{fp}r{self.rank}_s{sid}.rpro")
            write_profile(path, cct, self.registry, ident, mods)
            out[f"gpu_{sid}"] = path
        # GPU stream traces from the tracing threads.  Events carry the
        # dispatching app thread's CCT node id; encode the dispatcher's
        # thread index into the high ctx bits and name its profile in
        # the identity, so aggregation converts every event through the
        # right thread's gmap (no more ctx_unmapped pass-through).
        tid_to_idx = {tid: st.index for tid, st in self._threads.items()}
        for tt in self._monitor._trace_threads:
            for sid, recs in tt.records.items():
                arr = np.asarray(recs, np.int64).reshape(-1, 4)
                idxs = np.asarray([tid_to_idx.get(int(t), -1)
                                   for t in arr[:, 3]], np.int64)
                if len(arr) and (idxs >= 0).all():
                    ctx = pack_dispatch_ctx(idxs, arr[:, 2])
                    used = sorted(set(idxs.tolist()))
                    ident = identity(
                        stream=sid, type="gpu",
                        dispatch_profiles={
                            str(i): f"profile_{fp}r{self.rank}_t{i}.rpro"
                            for i in used})
                else:   # dispatcher unknown: raw node ids, as before
                    ctx = arr[:, 2]
                    ident = identity(stream=sid, type="gpu")
                tw = TraceWriter(
                    os.path.join(self.out_dir,
                                 f"trace_{fp}r{self.rank}_s{sid}.rtrc"),
                    ident)
                tw.append_many(arr[:, 0], arr[:, 1], ctx)
                tw.close()
                out[f"gpu_trace_{sid}"] = tw.path
        return out

    def _ring_wait(self, append, *args) -> None:
        # the ring is full: the monitor is >capacity records behind.
        # Yield the GIL until it catches up (bounded by monitor
        # liveness — the same contract the channel spin had).
        while not append(*args):
            time.sleep(0)

    def build_trace_db(self, out_path: Optional[str] = None) -> str:
        """Post-mortem step next to aggregation: merge this measurement
        directory's per-thread/per-stream trace files into one seekable
        ``trace.db`` (repro.traceview).  Note the merged events carry this
        rank's *local* ctx ids; ``aggregate(..., trace_paths=...)`` builds
        the globally-renumbered trace.db in the database directory.
        """
        from repro.traceview.tracedb import build_db
        out_path = out_path or os.path.join(self.out_dir, "trace.db")
        build_db(self.out_dir, out_path)
        return out_path


class _Dispatch:
    """The dispatch-path context manager — a slotted object instead of a
    ``@contextmanager`` generator (the generator machinery alone cost
    more than the ring appends it brackets).  One instance per dispatch;
    ``__enter__`` publishes the OP record, ``__exit__`` the ACTIVITY
    record + trace-lane row and the thread's counter tuple."""

    __slots__ = ("_p", "_st", "_ctx", "_ph", "_te0", "_t0", "_seq",
                 "kind", "name", "stream", "module_id", "nbytes",
                 "duration_ns")

    def __init__(self, profiler: Profiler, kind: str, name: str,
                 stream: int, module_id: Optional[int], nbytes: int,
                 duration_ns: Optional[int]):
        self._p = profiler
        self.kind = kind
        self.name = name
        self.stream = stream
        self.module_id = module_id
        self.nbytes = nbytes
        self.duration_ns = duration_ns

    def __enter__(self) -> CCTNode:
        p = self._p
        te0 = p.clock()
        self._te0 = te0
        st = p._threads.get(threading.get_ident())
        if st is None:
            st = p._state()
        self._st = st
        ctx = p._dispatch_context(st)
        self._ctx = ctx
        ph_key = (ctx, self.kind, self.name, self.stream)
        ph = st.ph_cache.get(ph_key)
        if ph is None:
            ph = st.cct.get_or_insert(
                ctx, Frame(PLACEHOLDER, f"{self.kind}:{self.name}",
                           str(self.stream), 0))
            st.ph_cache[ph_key] = ph
        self._ph = ph
        seq = st.seq
        st.seq = seq + 1
        self._seq = seq
        rec = (OP, seq, ph)
        if not st.ring.try_append(rec):
            p._ring_wait(st.ring.try_append, rec)
        self._t0 = p.clock()
        return ph

    def __exit__(self, *exc) -> None:
        p = self._p
        st = self._st
        t0 = self._t0
        t1 = p.clock()
        dur = self.duration_ns if self.duration_ns is not None else t1 - t0
        n_budget = 0
        base = 0
        if self.kind == "kernel" and self.module_id in p._modules:
            if p.instrument:
                n_budget = -1           # sentinel: exact op counts
            else:
                dur_s = dur * 1e-9
                rate = p.sample_rate_hz
                base = sampling.sample_budget(dur_s, rate)
                n_budget = sampling.sample_budget(
                    dur_s, rate * p.sample_scale, p.sample_cap)
        rec = (ACTIVITY, self._seq, self.kind, self.name, self.stream,
               self.module_id, self._ph, self.nbytes, n_budget, base)
        t_end = t0 + dur
        ring = st.ring
        if not ring.try_append_timed(rec, t0, t_end, self._ctx.node_id):
            p._ring_wait(ring.try_append_timed, rec, t0, t_end,
                         self._ctx.node_id)
        te1 = p.clock()
        c = st.counts
        st.counts = (c[0] + (t0 - self._te0) + (te1 - t1),
                     c[1] + (t1 - t0), c[2] + 1)     # one atomic publish
