"""hpcrun-analogue: the user-facing measurement API (paper §3, §4).

Usage::

    prof = Profiler(out_dir, tracing=True)
    mid = prof.register_module("train_step", compiled.as_text())  # GPU binary
    prof.start()
    with prof.dispatch("kernel", "train_step", stream=0, module_id=mid):
        out = step_fn(...)            # timed; samples synthesized on exit
    prof.flush()
    paths = prof.write()              # per-thread + per-stream profiles

Every dispatch unwinds the *calling* Python stack, inserts a placeholder P
in the thread's CCT, and communicates with the monitor thread over wait-free
channels (monitor.py).  Fine-grained attribution (§4.2) hangs HLO-op
contexts below P using hpcstruct-analogue structure info (structure.py) and
the PC-sampling analogue (sampling.py).
"""
from __future__ import annotations

import contextlib
import itertools
import os
import socket
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import sampling
from repro.core.cct import (CCT, CCTNode, Frame, PLACEHOLDER,
                            unwind_host_stack)
from repro.core.channels import ChannelSet
from repro.core.metrics import MetricRegistry, default_registry
from repro.core.monitor import (ACTIVITY, OP, GpuActivity, GpuOperation,
                                MonitorThread)
from repro.core.profmt import write_profile
from repro.core.structure import HloModule, parse_hlo
from repro.core.trace import TraceWriter, pack_dispatch_ctx


class _ThreadState:
    def __init__(self, cct: CCT):
        self.cct = cct
        self.trace: List[tuple] = []     # (t0, t1, ctx_id) CPU-side trace


class Profiler:
    def __init__(self, out_dir: str, *, registry: Optional[MetricRegistry]
                 = None, tracing: bool = True, n_tracing_threads: int = 1,
                 sample_rate_hz: float = 1e6, instrument: bool = False,
                 rank: int = 0, clock: Callable[[], int] = time.monotonic_ns,
                 rng_seed: Optional[int] = None, unwind: bool = True,
                 tag: Optional[str] = None):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.registry = registry or default_registry()
        self.tracing = tracing
        self.sample_rate_hz = sample_rate_hz
        self.instrument = instrument
        self.rank = rank
        self.clock = clock
        self.unwind = unwind
        # continuous profiling (ISSUE 4): an optional measurement tag
        # (epoch / job segment) that lands in every profile & trace
        # identity and in the file names, so successive measurement
        # windows of one rank stay distinct through aggregation,
        # incremental merge, and the trace.db line index
        self.tag = tag
        # always-on serving knobs (ISSUE 7; repro.serving.governor): the
        # effective PC-sampling rate is sample_rate_hz * sample_scale,
        # capped at sample_cap samples per dispatch, and host unwinds
        # stop at unwind_depth frames (0 = single <app> frame).  All
        # three are safe to mutate between dispatches, which is how the
        # overhead governor throttles measurement at run time without
        # ever turning it off (coarse dispatch timing + tracing stay).
        self.sample_scale = 1.0
        self.sample_cap: Optional[int] = None
        self.unwind_depth = 64
        # overhead self-accounting: time spent in the dispatch path
        # itself (entry bookkeeping + exit attribution) vs time in the
        # application region — the governor's feedback signal
        self.tool_ns = 0
        self.app_ns = 0
        self.n_dispatches = 0
        self.samples_kept = 0
        self.samples_dropped = 0
        self._windows = threading.local()
        self._rng = (np.random.default_rng(rng_seed)
                     if rng_seed is not None else None)
        self._corr = itertools.count(1)
        self._channels = ChannelSet()
        self._monitor = MonitorThread(self._channels, tracing=tracing,
                                      n_tracing_threads=n_tracing_threads)
        self._threads: Dict[int, _ThreadState] = {}
        self._threads_lock = threading.Lock()
        self._modules: Dict[int, HloModule] = {}
        self._module_names: Dict[int, str] = {}
        self._module_costs: Dict[int, dict] = {}
        self._counters = None        # CounterCollector when enabled
        self._op_ctx_cache: Dict[tuple, tuple] = {}
        self._stream_ccts: Dict[int, CCT] = {}
        self._stream_lock = threading.Lock()
        self._started = False
        self._host = socket.gethostname()
        self._monitor.trace_sink = self._stream_profile_sink

    # ------------------------------------------------------------------ #
    def register_module(self, name: str, hlo_text: str,
                        cost: Optional[dict] = None) -> int:
        """Record a loaded 'GPU binary' for later analysis (§3).

        ``cost`` is the module's ``compiled.cost_analysis()`` dict; when
        given, hardware-counter readings (enable_counters) calibrate
        their flop/byte totals against it instead of relying purely on
        the parsed estimates."""
        mid = len(self._modules) + 1
        self._modules[mid] = parse_hlo(hlo_text, name=name)
        self._module_names[mid] = name
        if cost is not None:
            # jax may hand back a single-element list
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            self._module_costs[mid] = dict(cost)
        return mid

    def enable_counters(self, counters, *, replay: bool = True):
        """Turn on kernel-granularity hardware-counter collection
        (paper §6; repro.counters).  Returns the multiplex schedule.

        ``replay=True`` serializes replay passes so every requested
        counter is measured on every kernel execution; ``replay=False``
        rotates counter groups across invocations (single-pass
        best-effort multiplexing).  Must be called identically on every
        rank so aggregated profiles agree on the counter columns."""
        from repro.counters.collector import CounterCollector
        self._counters = CounterCollector(counters, replay=replay)
        return self._counters.schedule

    def module(self, mid: int) -> HloModule:
        return self._modules[mid]

    def register_kernel_structures(self, mid: int, structures,
                                   matches: Optional[Dict[str, str]] = None
                                   ) -> int:
        """Bind recovered kernel-interior structures
        (``repro.core.kstruct.KernelStructure``) to module ``mid``'s
        ``custom-call`` ops.  Subsequent PC samples descend into the
        kernels' interiors (loops / inlined scopes / source lines)
        instead of stopping at the opaque op.  Returns total ops bound."""
        mod = self._modules[mid]
        matches = matches or {}
        bound = 0
        for ks in structures:
            bound += mod.bind_kernel_structure(ks, matches.get(ks.name))
        if bound:
            # interior leaves change the per-op context paths
            self._op_ctx_cache = {
                k: v for k, v in self._op_ctx_cache.items() if k[0] != mid}
        return bound

    def start(self):
        if not self._started:
            self._monitor.start()
            self._started = True
        return self

    def stop(self):
        if self._started:
            self._monitor.stop()
            self._started = False

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.flush()
        self.stop()

    # ------------------------------------------------------------------ #
    def _state(self) -> _ThreadState:
        tid = threading.get_ident()
        st = self._threads.get(tid)
        if st is None:
            with self._threads_lock:
                st = self._threads.setdefault(tid, _ThreadState(CCT()))
        return st

    def _host_context(self, st: _ThreadState, name: str) -> CCTNode:
        if self.unwind and self.unwind_depth > 0:
            frames = unwind_host_stack(skip=3, max_depth=self.unwind_depth)
        else:
            frames = [Frame("host", "<app>", "", 0)]
        node = st.cct.insert_path(frames)
        for wf in self._window_frames():
            node = st.cct.get_or_insert(node, wf)
        return node

    # -- measurement windows (ISSUE 7: per-request serving attribution) --
    def _window_frames(self) -> list:
        frames = getattr(self._windows, "frames", None)
        if frames is None:
            frames = self._windows.frames = []
        return frames

    @contextlib.contextmanager
    def window(self, *frames: Frame):
        """A measurement window: while open on this thread, ``frames``
        are spliced between the unwound host stack and every dispatch
        placeholder / cpu_region, so the aggregated database attributes
        the enclosed GPU and CPU work to the window (the per-request /
        per-phase identities of ``repro.serving.window``).  Windows
        nest; frames ride the CCT the same way ``dispatch_profiles``
        rides ctx bits — no file-format change."""
        stack = self._window_frames()
        n = len(stack)
        stack.extend(frames)
        try:
            yield
        finally:
            del stack[n:]

    @contextlib.contextmanager
    def window_exclusive(self, *frames: Frame):
        """Like ``window`` but *replaces* the thread's current window
        stack for the duration instead of nesting under it.  This is the
        continuous-batching primitive (repro.serving.window.RequestWindow
        .step): overlapping request windows on one serving thread stamp
        each dispatch with exactly one request's frames, so interleaved
        decode steps never double-count under whichever window happened
        to open first."""
        stack = self._window_frames()
        saved = stack[:]
        stack[:] = list(frames)
        try:
            yield
        finally:
            stack[:] = saved

    def overhead_counters(self) -> Dict[str, int]:
        """Cumulative dispatch-path self-accounting (the governor's
        input): tool time vs application time, dispatch count, and the
        PC-sample kept/dropped tally under the current throttle."""
        return {"tool_ns": self.tool_ns, "app_ns": self.app_ns,
                "dispatches": self.n_dispatches,
                "samples_kept": self.samples_kept,
                "samples_dropped": self.samples_dropped}

    @contextlib.contextmanager
    def dispatch(self, kind: str, name: str, *, stream: int = 0,
                 module_id: Optional[int] = None, nbytes: int = 0,
                 duration_ns: Optional[int] = None):
        """Times the enclosed GPU operation and attributes it.

        ``duration_ns`` overrides the measured wall time (used when the
        caller has a better device-side estimate, e.g. from events).
        """
        te0 = self.clock()
        st = self._state()
        ch = self._channels.channel_for(threading.get_ident())
        ctx = self._host_context(st, name)
        placeholder = st.cct.get_or_insert(
            ctx, Frame(PLACEHOLDER, f"{kind}:{name}", str(stream), 0))
        corr = next(self._corr)
        op = GpuOperation(corr, kind, name, stream, placeholder, module_id)
        while not ch.operation.try_push((OP, op)):
            self._drain_activities(st, ch)
        t0 = self.clock()
        try:
            yield placeholder
        finally:
            t1 = self.clock()
            dur = duration_ns if duration_ns is not None else t1 - t0
            samples = None
            # the dispatching app thread rides the activity record: the
            # tracing threads stamp it into GPU-stream trace events so
            # aggregation can convert their app-thread CCT node ids
            # through this thread's profile (pipeline.traceconv)
            meta = {"dispatch_tid": threading.get_ident()}
            if kind == "kernel" and module_id in self._modules:
                mod = self._modules[module_id]
                if self.instrument:
                    samples = sampling.instruction_counts(mod)
                else:
                    samples = sampling.pc_samples(
                        mod, dur * 1e-9,
                        self.sample_rate_hz * self.sample_scale,
                        self._rng, cap=self.sample_cap)
                    kept = sum(s.count for s in samples)
                    base = max(1, int(dur * 1e-9 * self.sample_rate_hz))
                    self.samples_kept += kept
                    self.samples_dropped += max(0, base - kept)
                if self._counters is not None:
                    # the counter reading rides the activity record
                    # through the same SPSC channels (§4.1, §6)
                    meta["counters"] = self._counters.read(
                        mod, dur, self._module_costs.get(module_id))
            act = GpuActivity(corr, kind, name, stream, t0, t0 + dur,
                              bytes=nbytes, samples=samples,
                              module_id=module_id, meta=meta)
            while not ch.operation.try_push((ACTIVITY, act)):
                self._drain_activities(st, ch)
            st.trace.append((t0, t0 + dur, ctx.node_id))
            self._drain_activities(st, ch)
            te1 = self.clock()
            self.tool_ns += (t0 - te0) + (te1 - t1)
            self.app_ns += t1 - t0
            self.n_dispatches += 1

    @contextlib.contextmanager
    def cpu_region(self, name: str):
        """Marks CPU work for the trace/blame views."""
        st = self._state()
        node = st.cct.insert_path([Frame("host", name, "", 0)],
                                  parent=self._host_context(st, name))
        t0 = self.clock()
        try:
            yield
        finally:
            t1 = self.clock()
            node.metrics.add(self.registry.kind("cpu"), "time_ns", t1 - t0)
            st.trace.append((t0, t1, node.node_id))

    # ------------------------------------------------------------------ #
    def _drain_activities(self, st: _ThreadState, ch):
        while True:
            batch = ch.activity.try_pop_many(256)
            if not batch:
                return
            for act, placeholder in batch:
                self._attribute(st, act, placeholder)

    def _attribute(self, st: _ThreadState, act: GpuActivity,
                   placeholder: CCTNode):
        reg = self.registry
        kind_name = {"kernel": "gpu_kernel", "copy": "gpu_copy",
                     "sync": "gpu_sync"}.get(act.kind, "gpu_kernel")
        kind = reg.kind(kind_name)
        placeholder.metrics.add(kind, "invocations", 1)
        placeholder.metrics.add(kind, "time_ns", act.duration)
        if kind_name == "gpu_copy" and act.bytes:
            placeholder.metrics.add(kind, "bytes", act.bytes)
        if act.meta is not None:
            cvec = act.meta.get("counters")
            if cvec is not None:
                placeholder.metrics.add_vec(reg.kind("gpu_counter"), cvec)
        if act.samples and act.module_id is not None:
            mod = self._modules[act.module_id]
            ops = mod.all_ops()
            total = sum(s.count for s in act.samples) or 1
            ikind = reg.kind("gpu_inst")
            # kind layout: (samples, stall_compute, stall_memory,
            # stall_collective, flops, bytes) — one vectorized add per
            # sample (4 name-indexed adds per sample dominated overhead)
            midx = {m: i for i, m in enumerate(ikind.metrics)}
            stall_col = {s: midx[f"stall_{s}"]
                         for s in ("compute", "memory", "collective")}
            i_samp, i_fl, i_by = midx["samples"], midx["flops"], midx["bytes"]
            vec = np.zeros(len(ikind.metrics))
            kstructs = mod.kernel_structures()
            for s in act.samples:
                op = ops[s.op_index] if s.op_index < len(ops) else None
                if op is None:
                    continue
                leaf = getattr(s, "leaf", -1)
                key = (act.module_id, s.op_index, leaf)
                frames = self._op_ctx_cache.get(key)
                if frames is None:
                    frames = tuple(mod.op_context(op))
                    if leaf >= 0:
                        # kernel-interior descent (kstruct): the leaf's
                        # GPU_FUNC/GPU_LOOP/GPU_OP chain hangs under the
                        # kernel's own GPU_OP context — interiors ride
                        # the database as ordinary tree paths
                        ks = kstructs.get(s.op_index)
                        if ks is not None and leaf < len(ks.leaves):
                            frames = frames + ks.leaf_frames(leaf)
                    self._op_ctx_cache[key] = frames
                node = st.cct.insert_path(list(frames), parent=placeholder)
                fl, by = op.flops, op.bytes
                if leaf >= 0:
                    ks = kstructs.get(s.op_index)
                    if ks is not None and leaf < len(ks.leaves):
                        fl, by = ks.leaves[leaf].flops, ks.leaves[leaf].bytes
                vec[:] = 0.0
                vec[i_samp] = s.count
                vec[stall_col[s.stall]] = s.count
                vec[i_fl] = fl * s.count / total
                vec[i_by] = by * s.count / total
                node.metrics.add_vec(ikind, vec)

    def _stream_profile_sink(self, stream: int, act: GpuActivity,
                             placeholder: CCTNode):
        """Builds per-GPU-stream profiles on the tracing threads."""
        with self._stream_lock:
            cct = self._stream_ccts.setdefault(stream, CCT())
        node = cct.insert_path(
            [Frame(PLACEHOLDER, f"{act.kind}:{act.name}", str(stream), 0)])
        kind = self.registry.kind("gpu_kernel" if act.kind == "kernel"
                                  else f"gpu_{act.kind}")
        node.metrics.add(kind, "invocations", 1)
        node.metrics.add(kind, "time_ns", act.duration)
        if act.meta is not None:
            cvec = act.meta.get("counters")
            if cvec is not None:
                node.metrics.add_vec(self.registry.kind("gpu_counter"),
                                     cvec)

    # ------------------------------------------------------------------ #
    def flush(self, timeout: float = 10.0) -> bool:
        ok = self._monitor.quiesce(timeout)
        for tid, st in list(self._threads.items()):
            ch = self._channels.channel_for(tid)
            # app-thread drain is normally done on that thread; at flush the
            # owning threads are quiescent, so the ownership transfers here.
            self._drain_activities(st, ch)
        return ok

    def write(self) -> Dict[str, str]:
        """Writes all profiles + traces.  Returns {label: path}."""
        out: Dict[str, str] = {}
        mods = [self._module_names[m] for m in sorted(self._modules)]
        fp = f"{self.tag}_" if self.tag else ""

        def identity(**kw) -> Dict[str, object]:
            ident = {"host": self._host, "rank": self.rank, **kw}
            if self.tag is not None:
                ident["tag"] = self.tag
            return ident

        for i, (tid, st) in enumerate(sorted(self._threads.items())):
            ident = identity(thread=i, type="cpu")
            path = os.path.join(self.out_dir,
                                f"profile_{fp}r{self.rank}_t{i}.rpro")
            write_profile(path, st.cct, self.registry, ident, mods)
            out[f"cpu_{i}"] = path
            tw = TraceWriter(path.replace(".rpro", ".rtrc"), ident)
            recs = np.asarray(st.trace, np.uint64).reshape(-1, 3)
            tw.append_many(recs[:, 0], recs[:, 1], recs[:, 2])
            tw.close()
            out[f"cpu_trace_{i}"] = tw.path
        with self._stream_lock:
            streams = dict(self._stream_ccts)
        for sid, cct in sorted(streams.items()):
            ident = identity(stream=sid, type="gpu")
            path = os.path.join(self.out_dir,
                                f"profile_{fp}r{self.rank}_s{sid}.rpro")
            write_profile(path, cct, self.registry, ident, mods)
            out[f"gpu_{sid}"] = path
        # GPU stream traces from the tracing threads.  Events carry the
        # dispatching app thread's CCT node id; encode the dispatcher's
        # thread index into the high ctx bits and name its profile in
        # the identity, so aggregation converts every event through the
        # right thread's gmap (no more ctx_unmapped pass-through).
        tid_to_idx = {tid: i
                      for i, tid in enumerate(sorted(self._threads))}
        for tt in self._monitor._trace_threads:
            for sid, recs in tt.records.items():
                arr = np.asarray(recs, np.int64).reshape(-1, 4)
                idxs = np.asarray([tid_to_idx.get(int(t), -1)
                                   for t in arr[:, 3]], np.int64)
                if len(arr) and (idxs >= 0).all():
                    ctx = pack_dispatch_ctx(idxs, arr[:, 2])
                    used = sorted(set(idxs.tolist()))
                    ident = identity(
                        stream=sid, type="gpu",
                        dispatch_profiles={
                            str(i): f"profile_{fp}r{self.rank}_t{i}.rpro"
                            for i in used})
                else:   # dispatcher unknown: raw node ids, as before
                    ctx = arr[:, 2]
                    ident = identity(stream=sid, type="gpu")
                tw = TraceWriter(
                    os.path.join(self.out_dir,
                                 f"trace_{fp}r{self.rank}_s{sid}.rtrc"),
                    ident)
                tw.append_many(arr[:, 0], arr[:, 1], ctx)
                tw.close()
                out[f"gpu_trace_{sid}"] = tw.path
        return out

    def build_trace_db(self, out_path: Optional[str] = None) -> str:
        """Post-mortem step next to aggregation: merge this measurement
        directory's per-thread/per-stream trace files into one seekable
        ``trace.db`` (repro.traceview).  Note the merged events carry this
        rank's *local* ctx ids; ``aggregate(..., trace_paths=...)`` builds
        the globally-renumbered trace.db in the database directory.
        """
        from repro.traceview.tracedb import build_db
        out_path = out_path or os.path.join(self.out_dir, "trace.db")
        build_db(self.out_dir, out_path)
        return out_path
