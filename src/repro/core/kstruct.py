"""Kernel-interior structure recovery — ``hpcstruct`` for Pallas kernels
(paper §5 applied *inside* the GPU binary; §7 PC-sampling attribution).

The HLO-level structure parse (``repro.core.structure``) stops at op
granularity: a ``pl.pallas_call`` compiles to one opaque ``custom-call``
HLO op, so an entire flash-attention kernel gets exactly one context no
matter how hot its inner loops are.  HPCToolkit recovers kernel
interiors by disassembling the GPU binary (nvdisasm/Dyninst); our
"binary" for a Pallas kernel is the *kernel jaxpr* — the traced program
``pallas_call`` lowers, which carries per-equation ``source_info``:

- the user-frame traceback gives **source lines** and the **inlined
  scope chain** (``pl.when`` bodies and helper functions appear as
  nested frames, exactly the inline chains §5 recovers from DWARF);
- ``scan``/``while`` equations (``jax.lax.fori_loop``) and the
  sequential grid dimensions give the **loop nest**;
- equation avals give a per-leaf roofline weight (the PC-sampling
  descent weights) and a stall class (compute vs memory bound —
  THAPI-style classification, PAPERS.md).

``KernelStructure.from_function`` traces the kernel's host wrapper with
``jax.make_jaxpr`` and recovers a ``GPU_FUNC -> GPU_LOOP -> GPU_OP``
``Frame`` tree mirroring the HLO path's shapes.  ``structure.HloModule
.bind_kernel_structure`` attaches it to the matching ``custom-call``
ops; ``sampling.pc_samples`` then descends into bound ops, distributing
each op's samples over interior leaves (two-level draw, governor cap
preserved exactly); ``profiler._attribute`` splices the leaf frames
under the op's GPU context, so the interiors ride the canonical
database contract as ordinary tree paths (byte-deterministic through
``aggregate()``/``merge_databases`` — pinned in tests/test_kstruct.py).

Structures are plain data: hand-building one (tests, goldens, non-JAX
backends) needs only ``KernelLeaf`` tuples — tracing is just the
recovery front end.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cct import Frame, GPU_FUNC, GPU_LOOP, GPU_OP

# chip constants shared with sampling.py (kept literal to avoid an
# import cycle; sampling asserts they agree)
PEAK_FLOPS = 197e12            # bf16 FLOP/s per chip
VMEM_BW = 2.2e13               # ~bytes/s VMEM<->vector-unit bandwidth

# transcendental primitives get the same 10x element weight the HLO
# cost model uses (structure._estimate_costs)
_TRANSCENDENTAL = frozenset({
    "exp", "exp2", "expm1", "log", "log1p", "tanh", "rsqrt", "sqrt",
    "pow", "integer_pow", "logistic", "sin", "cos", "erf", "erf_inv"})

# Ref load/store primitives: the kernel's memory traffic analogue
_MEMORY = frozenset({"get", "swap", "masked_load", "masked_swap",
                     "load", "store"})

# never-sampled bookkeeping primitives (cf. sampling._NON_INST)
_NON_INST = frozenset({"program_id", "num_programs", "broadcast_in_dim",
                       "convert_element_type", "reshape", "squeeze",
                       "transpose"})


@dataclasses.dataclass(frozen=True)
class KernelLeaf:
    """One sampled 'instruction' inside a kernel: a (scope chain, source
    line) group of jaxpr equations."""
    frames: Tuple[Frame, ...]   # GPU_LOOP/GPU_FUNC chain + GPU_OP leaf
    weight: float               # modeled seconds (roofline max term)
    stall: str                  # "compute" | "memory"
    flops: float = 0.0
    bytes: float = 0.0

    @property
    def line(self) -> int:
        return self.frames[-1].line


class KernelStructure:
    """The kernel-interior analogue of ``structure.HloModule``: a
    GPU_FUNC root, loop/scope frames, and weighted GPU_OP leaves."""

    def __init__(self, name: str, file: str, line: int,
                 leaves: Sequence[KernelLeaf],
                 grid: Tuple[int, ...] = ()):
        self.name = name
        self.file = file
        self.line = line
        self.grid = tuple(grid)
        self.leaves: Tuple[KernelLeaf, ...] = tuple(leaves)
        self.root = Frame(GPU_FUNC, name, file, line)
        self._p: Optional[np.ndarray] = None

    def __repr__(self) -> str:
        return (f"KernelStructure({self.name!r}, {len(self.leaves)} "
                f"leaves, grid={self.grid})")

    # -- totals (the counter-collector refinement inputs) -----------------
    @property
    def total_flops(self) -> float:
        return sum(lf.flops for lf in self.leaves)

    @property
    def total_bytes(self) -> float:
        return sum(lf.bytes for lf in self.leaves)

    @property
    def active_s(self) -> float:
        return sum(lf.weight for lf in self.leaves)

    def leaf_frames(self, i: int) -> Tuple[Frame, ...]:
        """Full interior frame path for leaf ``i`` (root included) — what
        the profiler splices under the kernel's GPU_OP context."""
        return (self.root,) + self.leaves[i].frames

    # -- sample descent ---------------------------------------------------
    def leaf_p(self) -> np.ndarray:
        """Normalized leaf weights (cached — the descent runs on the
        dispatch path, cf. sampling._op_weights_cache)."""
        if self._p is None:
            w = np.asarray([lf.weight for lf in self.leaves], np.float64)
            total = w.sum()
            self._p = w / total if total > 0 else \
                np.full(len(w), 1.0 / max(len(w), 1))
        return self._p

    def distribute(self, count: int, rng=None) -> List[Tuple[int, int]]:
        """Apportion ``count`` samples over leaves; returns non-zero
        ``(leaf_index, count)`` pairs summing to exactly ``count`` (the
        governor's per-dispatch cap survives the descent unchanged).

        Deterministic mode uses largest-remainder apportionment (floor +
        remainder ranking), so the two-level draw is a pure function of
        (structure, count); with ``rng`` it is one multinomial."""
        if count <= 0 or not self.leaves:
            return []
        p = self.leaf_p()
        if rng is not None:
            counts = rng.multinomial(int(count), p)
        else:
            exact = count * p
            counts = np.floor(exact).astype(np.int64)
            short = int(count - counts.sum())
            if short > 0:
                # ties broken by leaf order: stable + deterministic
                order = np.argsort(-(exact - counts), kind="stable")
                counts[order[:short]] += 1
        return [(int(i), int(counts[i])) for i in np.nonzero(counts)[0]]

    # -- recovery front ends ---------------------------------------------
    @classmethod
    def from_function(cls, fn, *example_args, name: Optional[str] = None,
                      grid_loops: Optional[Dict[int, str]] = None,
                      **kwargs) -> "KernelStructure":
        """Trace ``fn(*example_args, **kwargs)`` (the host wrapper that
        issues the ``pallas_call``) and recover the first Pallas kernel
        found.  ``grid_loops`` names the *sequential* grid axes (TPU
        executes the grid in order; the scratch-carrying innermost axis
        is the kernel's outer loop), e.g. ``{4: "kv_blocks"}``."""
        import functools
        import jax
        closed = jax.make_jaxpr(functools.partial(fn, **kwargs))(
            *example_args)
        eqn = _find_pallas_call(closed.jaxpr)
        if eqn is None:
            raise ValueError(f"no pallas_call found tracing {fn!r}")
        return cls.from_pallas_eqn(eqn, name=name, grid_loops=grid_loops)

    @classmethod
    def from_pallas_eqn(cls, eqn, name: Optional[str] = None,
                        grid_loops: Optional[Dict[int, str]] = None
                        ) -> "KernelStructure":
        """Recover from one ``pallas_call`` equation of an outer jaxpr."""
        inner = eqn.params["jaxpr"]
        kname, kfile, kline = _kernel_ident(eqn, inner)
        name = name or kname
        base = os.path.basename(kfile)
        grid = tuple(int(g) for g in
                     getattr(eqn.params.get("grid_mapping"), "grid", ()) or ())
        # sequential grid axes become the outermost loop frames
        loop_prefix: Tuple[Frame, ...] = tuple(
            Frame(GPU_LOOP, f"grid:{gname}", base, kline)
            for _, gname in sorted((grid_loops or {}).items()))
        acc = _LeafAccumulator(kname, kfile, base)
        _walk_jaxpr(inner, acc, loop_prefix, 1.0)
        return cls(name, base, kline, acc.build(), grid=grid)


# --------------------------------------------------------------------------
# jaxpr walk
# --------------------------------------------------------------------------
def _find_pallas_call(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            return eqn
        for p in eqn.params.values():
            sub = getattr(p, "jaxpr", None)
            if sub is not None:
                found = _find_pallas_call(sub)
                if found is not None:
                    return found
    return None


def _kernel_ident(eqn, inner) -> Tuple[str, str, int]:
    """(function name, file, def line) of the kernel callable."""
    nsi = eqn.params.get("name_and_src_info")
    kname = getattr(nsi, "name", None) or "kernel"
    for e in inner.eqns:
        frames = _user_frames(e)
        for fr in frames:
            if fr.function_name == kname:
                return kname, fr.file_name, int(fr.start_line)
        if frames:   # name didn't match any frame: innermost file wins
            return kname, frames[0].file_name, int(frames[0].start_line)
    return kname, "?", 0


def _user_frames(eqn):
    try:
        from jax._src import source_info_util
        return list(source_info_util.user_frames(eqn.source_info))
    except Exception:
        return []


def _aval_elems(aval) -> int:
    shape = getattr(aval, "shape", ())
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _aval_bytes(aval) -> int:
    dt = getattr(aval, "dtype", None)
    return _aval_elems(aval) * (dt.itemsize if dt is not None else 4)


def _eqn_costs(eqn) -> Tuple[float, float]:
    """(flops, bytes) roofline estimate for one kernel equation —
    mirrors structure._estimate_costs at jaxpr granularity."""
    prim = eqn.primitive.name
    out_elems = sum(_aval_elems(v.aval) for v in eqn.outvars)
    if prim in _MEMORY:
        moved = max(sum(_aval_bytes(v.aval) for v in eqn.outvars),
                    max((_aval_bytes(v.aval) for v in eqn.invars
                         if hasattr(v, "aval")), default=0))
        return 0.0, float(moved)
    if prim == "dot_general":
        ((lc, _), _) = eqn.params["dimension_numbers"]
        lhs = eqn.invars[0].aval
        k = 1
        for d in lc:
            k *= int(lhs.shape[d])
        return 2.0 * out_elems * k, 0.0
    if prim in _TRANSCENDENTAL:
        return 10.0 * out_elems, 0.0
    if prim.startswith("reduce_") or prim.startswith("cum"):
        in_elems = sum(_aval_elems(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        return float(in_elems), 0.0
    if prim in _NON_INST:
        return 0.0, 0.0
    return float(out_elems), 0.0


class _LeafAccumulator:
    """Groups equations by (loop chain, inline scope chain, source line)
    into deterministic, first-occurrence-ordered leaves."""

    def __init__(self, kernel_fn: str, kernel_file: str, base: str):
        self.kernel_fn = kernel_fn
        self.kernel_file = kernel_file
        self.base = base
        self._groups: Dict[tuple, dict] = {}

    def _scopes_and_line(self, eqn) -> Tuple[Tuple[Frame, ...], int]:
        frames = _user_frames(eqn)
        # innermost-first; keep the chain inside the kernel function
        chain = []
        for fr in frames:
            if fr.function_name == self.kernel_fn:
                break
            if fr.file_name != self.kernel_file:
                break
            chain.append(fr)
        line = int(frames[0].start_line) if frames else 0
        scopes = []
        for i, fr in enumerate(reversed(chain)):     # outermost first
            outer = chain[len(chain) - i] if len(chain) - i < len(chain) \
                else None
            # scope frame line = the call site in the enclosing frame
            site = int(frames[len(chain) - i].start_line) \
                if len(chain) - i < len(frames) else int(fr.start_line)
            scopes.append(Frame(GPU_FUNC, fr.function_name, self.base, site))
        return tuple(scopes), line

    def add(self, eqn, loops: Tuple[Frame, ...], trip: float) -> None:
        flops, nbytes = _eqn_costs(eqn)
        prim = eqn.primitive.name
        if prim in _NON_INST and flops == 0.0 and nbytes == 0.0:
            return
        scopes, line = self._scopes_and_line(eqn)
        key = (loops, scopes, line)
        g = self._groups.get(key)
        if g is None:
            g = self._groups[key] = {
                "order": len(self._groups), "flops": 0.0, "bytes": 0.0,
                "prims": {}}
        g["flops"] += flops * trip
        g["bytes"] += nbytes * trip
        w = max(flops / PEAK_FLOPS, nbytes / VMEM_BW)
        g["prims"][prim] = g["prims"].get(prim, 0.0) + w

    def build(self) -> List[KernelLeaf]:
        leaves = []
        for (loops, scopes, line), g in sorted(
                self._groups.items(), key=lambda kv: kv[1]["order"]):
            # dominant primitive names the leaf (ties: alphabetical)
            dom = max(sorted(g["prims"]), key=lambda p: g["prims"][p])
            t_c = g["flops"] / PEAK_FLOPS
            t_m = g["bytes"] / VMEM_BW
            weight = max(t_c, t_m, 1.0 / PEAK_FLOPS)
            leaf = Frame(GPU_OP, dom, self.base, line)
            leaves.append(KernelLeaf(
                frames=loops + scopes + (leaf,), weight=weight,
                stall="memory" if t_m > t_c else "compute",
                flops=g["flops"], bytes=g["bytes"]))
        return leaves


def _walk_jaxpr(jaxpr, acc: _LeafAccumulator, loops: Tuple[Frame, ...],
                trip: float) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "cond":
            # pl.when / lax.cond: branch bodies keep the current loop
            # chain; the branch function appears as an inline scope via
            # its traceback frames
            for br in eqn.params["branches"]:
                _walk_jaxpr(br.jaxpr, acc, loops, trip)
            continue
        if prim == "scan":
            length = float(eqn.params.get("length", 1) or 1)
            frames = _user_frames(eqn)
            line = int(frames[0].start_line) if frames else 0
            lf = Frame(GPU_LOOP, f"loop@{line}", acc.base, line)
            _walk_jaxpr(eqn.params["jaxpr"].jaxpr, acc, loops + (lf,),
                        trip * length)
            continue
        if prim == "while":
            frames = _user_frames(eqn)
            line = int(frames[0].start_line) if frames else 0
            lf = Frame(GPU_LOOP, f"loop@{line}", acc.base, line)
            # trip count is dynamic; leaves keep the loop frame, weight
            # scales by 1 (cf. structure.loop_depth's static chains)
            _walk_jaxpr(eqn.params["body_jaxpr"].jaxpr, acc, loops + (lf,),
                        trip)
            continue
        sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
        if sub is not None and prim != "pallas_call":
            _walk_jaxpr(getattr(sub, "jaxpr", sub), acc, loops, trip)
            continue
        acc.add(eqn, loops, trip)
