"""Derived metrics (paper §4.5, §7.1).

hpcviewer lets the user author spreadsheet-like formulas over measured
metrics; hpcprof provides the built-in cross-profile statistics
(sum/min/mean/max/stddev/CoV — computed in aggregate.py).  This module is
the formula half: a safe AST-walking evaluator over named metric columns.

Paper examples reproduced here and in examples/:

- Warp issue rate   W = S / (S + S_stall)
- sync diff         diff = sync_count - kernel_count   (PeleC, §8.4.1)
- registers used    regs = registers_sum / invocations (the "odd raw
  metrics then divide" trick of §4.5)
"""
from __future__ import annotations

import ast
import math
from typing import Dict, Mapping

import numpy as np

_ALLOWED_FUNCS = {
    "sqrt": np.sqrt, "log": np.log, "log2": np.log2, "exp": np.exp,
    "abs": np.abs, "min": np.minimum, "max": np.maximum,
    "where": np.where,
}
_ALLOWED_NODES = (
    ast.Expression, ast.BinOp, ast.UnaryOp, ast.Name, ast.Load, ast.Call,
    ast.Constant, ast.Add, ast.Sub, ast.Mult, ast.Div, ast.Pow, ast.USub,
    ast.UAdd, ast.Compare, ast.Gt, ast.GtE, ast.Lt, ast.LtE, ast.Eq,
    ast.NotEq, ast.IfExp,
)


def sanitize(name: str) -> str:
    """Metric names like ``gpu_kernel/time_ns`` -> identifier."""
    return name.replace("/", "__").replace("-", "_").replace(".", "_")


class DerivedMetric:
    def __init__(self, name: str, formula: str):
        self.name = name
        self.formula = formula
        self._tree = ast.parse(formula, mode="eval")
        for node in ast.walk(self._tree):
            if not isinstance(node, _ALLOWED_NODES):
                raise ValueError(
                    f"disallowed syntax {type(node).__name__} in formula")
            if isinstance(node, ast.Call):
                if not (isinstance(node.func, ast.Name)
                        and node.func.id in _ALLOWED_FUNCS):
                    raise ValueError("only whitelisted functions allowed")

    def evaluate(self, columns: Mapping[str, np.ndarray]) -> np.ndarray:
        env = {sanitize(k): v for k, v in columns.items()}

        def ev(node):
            if isinstance(node, ast.Expression):
                return ev(node.body)
            if isinstance(node, ast.Constant):
                return node.value
            if isinstance(node, ast.Name):
                if node.id in env:
                    return env[node.id]
                raise KeyError(f"unknown metric {node.id!r}")
            if isinstance(node, ast.BinOp):
                a, b = ev(node.left), ev(node.right)
                op = type(node.op)
                with np.errstate(divide="ignore", invalid="ignore"):
                    if op is ast.Add:
                        return a + b
                    if op is ast.Sub:
                        return a - b
                    if op is ast.Mult:
                        return a * b
                    if op is ast.Div:
                        return np.where(np.asarray(b) != 0,
                                        np.divide(a, np.where(
                                            np.asarray(b) != 0, b, 1)), 0.0)
                    if op is ast.Pow:
                        return a ** b
                raise ValueError(op)
            if isinstance(node, ast.UnaryOp):
                v = ev(node.operand)
                return -v if isinstance(node.op, ast.USub) else +v
            if isinstance(node, ast.Call):
                args = [ev(a) for a in node.args]
                return _ALLOWED_FUNCS[node.func.id](*args)
            if isinstance(node, ast.Compare):
                a = ev(node.left)
                b = ev(node.comparators[0])
                op = type(node.ops[0])
                table = {ast.Gt: np.greater, ast.GtE: np.greater_equal,
                         ast.Lt: np.less, ast.LtE: np.less_equal,
                         ast.Eq: np.equal, ast.NotEq: np.not_equal}
                return table[op](a, b)
            if isinstance(node, ast.IfExp):
                return np.where(ev(node.test), ev(node.body), ev(node.orelse))
            raise ValueError(type(node))

        return ev(self._tree)


def database_columns(db, stat: str = "sum") -> Dict[str, np.ndarray]:
    """Per-context metric columns from a Database for formula evaluation."""
    mat = db.stats[stat]
    return {name: mat[:, i] for i, name in enumerate(db.metrics)}


# paper-example formulas, ready to use
WARP_ISSUE_RATE = DerivedMetric(
    "warp_issue_rate",
    "gpu_inst__samples / (gpu_inst__samples + gpu_inst__stall_compute"
    " + gpu_inst__stall_memory + gpu_inst__stall_collective)")
SYNC_DIFF = DerivedMetric(
    "sync_minus_kernels",
    "gpu_sync__invocations - gpu_kernel__invocations")
REGISTERS_USED = DerivedMetric(
    "registers_used",
    "gpu_kernel__registers_sum / gpu_kernel__invocations")
GPU_UTILIZATION = DerivedMetric(
    "gpu_utilization",
    "gpu_kernel__time_ns / (cpu__time_ns + gpu_kernel__time_ns)")

# ---------------------------------------------------------------------------
# Hardware-counter derived metrics (paper §6; repro.counters).  All are
# ratios of gpu_counter columns, so the zero-division policy (0) makes
# them vanish at contexts with no counter data.
# ---------------------------------------------------------------------------
from repro.core.sampling import PEAK_FLOPS as _PEAK_FLOPS  # noqa: E402

# modeled busy time over elapsed time, clamped into [0, 1]
ACHIEVED_OCCUPANCY = DerivedMetric(
    "achieved_occupancy",
    "min(gpu_counter__active_ns / gpu_counter__elapsed_ns, 1.0)")
# fraction of the chip's peak FLOP/s actually achieved
FLOP_EFFICIENCY = DerivedMetric(
    "flop_efficiency",
    f"gpu_counter__flops / (gpu_counter__elapsed_ns * {_PEAK_FLOPS * 1e-9})")
# arithmetic-intensity inverse: memory traffic per flop
BYTES_PER_FLOP = DerivedMetric(
    "bytes_per_flop",
    "gpu_counter__hbm_bytes / gpu_counter__flops")
# mean measurement passes per kernel launch (1 unless replay-multiplexed)
REPLAY_PASS_COUNT = DerivedMetric(
    "replay_pass_count",
    "gpu_counter__replay_passes / gpu_kernel__invocations")

COUNTER_DERIVED = (ACHIEVED_OCCUPANCY, FLOP_EFFICIENCY, BYTES_PER_FLOP,
                   REPLAY_PASS_COUNT)
