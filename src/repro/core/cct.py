"""Heterogeneous calling context trees (paper §3, §4.1, §4.6).

A CCT node identifies a *frame*.  In HPCToolkit a frame is a
(load module, offset) machine-instruction pair; in the JAX/TPU adaptation a
frame is one of:

- ``host``        — a Python stack frame (file, line, function) on an
                    application thread;
- ``placeholder`` — a GPU operation placeholder `P` (kernel launch, copy,
                    sync) inserted under the host context that invoked it;
- ``gpu_op``      — an HLO op / Pallas block inside a compiled module
                    (module id + op index), the "GPU instruction" analogue;
- ``gpu_func``    — a GPU-side function/scope (inline scope, loop or
                    computation recovered by hpcstruct-analogue analysis).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.metrics import MetricRegistry, NodeMetrics

HOST = "host"
PLACEHOLDER = "placeholder"
GPU_OP = "gpu_op"
GPU_FUNC = "gpu_func"
GPU_LOOP = "gpu_loop"


def tree_depths(parents: np.ndarray) -> np.ndarray:
    """Per-node depth (root = 0) for a parent-id array, via vectorized
    parent jumps: O(max_depth) passes.  The one implementation behind
    ``GlobalTree.depths``, ``Database.depths``, and the traceview
    raster's depth projection."""
    parents = np.asarray(parents, np.int64)
    depth = np.zeros(len(parents), np.int64)
    cur = parents.copy()
    while True:
        mask = cur >= 0
        if not mask.any():
            break
        depth[mask] += 1
        cur[mask] = parents[cur[mask]]
    return depth


@dataclasses.dataclass(frozen=True)
class Frame:
    kind: str
    name: str               # function name / op name / placeholder label
    module: str = ""        # file or load-module name
    line: int = 0           # source line or op index

    def pretty(self) -> str:
        if self.kind == HOST:
            return f"{self.name} @ {self.module}:{self.line}"
        if self.kind == PLACEHOLDER:
            return f"<gpu op {self.name}>"
        if self.kind == GPU_LOOP:
            return f"loop at {self.module}:{self.line}"
        return self.name


class CCTNode:
    __slots__ = ("frame", "parent", "children", "metrics", "node_id")

    def __init__(self, frame: Frame, parent: Optional["CCTNode"],
                 node_id: int):
        self.frame = frame
        self.parent = parent
        self.children: Dict[Frame, CCTNode] = {}
        self.metrics = NodeMetrics()
        self.node_id = node_id

    def walk(self) -> Iterator["CCTNode"]:
        yield self
        for c in self.children.values():
            yield from c.walk()

    def path(self) -> List[Frame]:
        out = []
        node = self
        while node.parent is not None:
            out.append(node.frame)
            node = node.parent
        return out[::-1]


class CCT:
    """One calling context tree (per CPU thread or GPU stream profile)."""

    ROOT = Frame("root", "<program root>")

    def __init__(self):
        self._next_id = 0
        self.root = self._new_node(self.ROOT, None)

    def _new_node(self, frame: Frame, parent) -> CCTNode:
        node = CCTNode(frame, parent, self._next_id)
        self._next_id += 1
        return node

    def get_or_insert(self, parent: CCTNode, frame: Frame) -> CCTNode:
        child = parent.children.get(frame)
        if child is None:
            child = self._new_node(frame, parent)
            parent.children[frame] = child
        return child

    def insert_path(self, frames: List[Frame],
                    parent: Optional[CCTNode] = None) -> CCTNode:
        node = parent if parent is not None else self.root
        for f in frames:
            node = self.get_or_insert(node, f)
        return node

    def nodes(self) -> List[CCTNode]:
        return list(self.root.walk())

    @property
    def n_nodes(self) -> int:
        return self._next_id

    def node_by_id(self) -> Dict[int, CCTNode]:
        return {n.node_id: n for n in self.root.walk()}


def unwind_host_stack(skip: int = 0, max_depth: int = 64,
                      prune_modules: Tuple[str, ...] = ("repro/core",
                                                        "threading.py"),
                      ) -> List[Frame]:
    """Unwind the current Python call stack into host frames, innermost
    last.  Frames from the tool itself are pruned (the paper prunes helper
    threads and tool frames the same way, §4.4)."""
    import sys
    frames: List[Frame] = []
    try:
        f = sys._getframe(skip + 1)
    except ValueError:
        return frames
    depth = 0
    while f is not None and depth < max_depth:
        fname = f.f_code.co_filename
        if not any(p in fname for p in prune_modules):
            frames.append(Frame(HOST, f.f_code.co_name, fname, f.f_lineno))
        f = f.f_back
        depth += 1
    return frames[::-1]
