"""Program structure recovery — the ``hpcstruct`` analogue (paper §5).

HPCToolkit analyzes GPU binaries (nvdisasm / IGA / Dyninst) to map machine
instructions to source lines, loop nests, and inlined call chains.  Our
"GPU binary" is a compiled HLO module (``compiled.as_text()``): it carries

- ``FileNames`` / ``FunctionNames`` / ``FileLocations`` / ``StackFrames``
  tables — the DWARF analogue, but with *complete* inline chains
  (``parent_frame_id`` links), fixing exactly the deficiency the paper
  laments in §9 "Attribution";
- per-op ``metadata={op_name="jit(f)/scope/..." stack_frame_id=N}`` — the
  JAX name-stack, i.e. the high-level-model scope chain (the RAJA/Kokkos
  template-instantiation problem of §1 solved at the metadata level);
- explicit computation boundaries, ``while`` loops (loop recovery), and
  ``fusion``/``call``/``to_apply`` edges (the static call graph §6.3 needs).

This module parses all of that, estimates per-op roofline costs (the weight
source for the PC-sampling analogue), and exposes the static call graph.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

from repro.core.cct import Frame, GPU_FUNC, GPU_LOOP, GPU_OP

# dtype -> bytes per element
_DT = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
       "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
       "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
       "f8e4m3fn": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_META_RE = re.compile(
    r'metadata=\{[^}]*?op_name="([^"]*)"(?:[^}]*?stack_frame_id=(\d+))?')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")


def parse_shape(type_str: str) -> Tuple[int, int]:
    """Returns (total elements, total bytes) over all leaves of a possibly
    tuple-typed string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DT[dt]
    return elems, nbytes


@dataclasses.dataclass
class HloOp:
    name: str
    opcode: str
    comp: str                      # owning computation
    type_str: str
    out_elems: int
    out_bytes: int
    operands: Tuple[str, ...]
    op_name: str = ""
    frame_id: int = 0
    callees: Tuple[str, ...] = ()
    attrs: str = ""
    index: int = 0                 # position within the module
    flops: float = 0.0
    bytes: float = 0.0
    group_size: int = 1            # collective group size
    trip_count: int = 1            # while ops: known_trip_count from XLA

    @property
    def collective_kind(self) -> str:
        """Base collective opcode ("all-reduce", ...) with any async
        ``-start``/``-done`` suffix removed, or "" for non-collectives.

        NB: this must strip a *suffix*, not a character set —
        ``"reduce-scatter".rstrip("-start")`` eats the trailing ``r``
        (rstrip takes characters, not a substring) and previously
        misclassified reduce-scatter via that path."""
        opc = self.opcode
        for suffix in ("-start", "-done"):
            if opc.endswith(suffix):
                opc = opc[: -len(suffix)]
                break
        return opc if opc in COLLECTIVES else ""

    @property
    def is_collective(self) -> bool:
        return bool(self.collective_kind)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[HloOp]
    is_entry: bool = False


@dataclasses.dataclass
class StackFrame:
    function: str
    file: str
    line: int
    parent: int                    # 0 = none


@dataclasses.dataclass
class HloModule:
    name: str
    computations: Dict[str, Computation]
    entry: str
    frames: Dict[int, StackFrame]
    ops: Dict[str, HloOp]

    _all_ops_cache: Optional[List[HloOp]] = None

    # -- derived ----------------------------------------------------------
    def all_ops(self) -> List[HloOp]:
        if self._all_ops_cache is None:
            self._all_ops_cache = [op for c in self.computations.values()
                                   for op in c.ops]
        return self._all_ops_cache

    def frame_chain(self, frame_id: int, max_depth: int = 64) -> List[Frame]:
        """Inline call chain (outermost first) for a stack_frame_id."""
        chain: List[Frame] = []
        fid = frame_id
        seen = 0
        while fid and fid in self.frames and seen < max_depth:
            fr = self.frames[fid]
            chain.append(Frame(GPU_FUNC, fr.function, fr.file, fr.line))
            fid = 0 if fr.parent == fid else fr.parent
            seen += 1
        return chain[::-1]

    def callers(self) -> Dict[str, List[HloOp]]:
        """computation name -> call-site ops."""
        out: Dict[str, List[HloOp]] = {c: [] for c in self.computations}
        for op in self.all_ops():
            for callee in op.callees:
                if callee in out:
                    out[callee].append(op)
        return out

    def loop_depth(self) -> Dict[str, List[HloOp]]:
        """computation name -> chain of enclosing while-ops (outer first).

        Cached: the call graph is immutable after parse, and op_context
        runs this on the dispatch path for every fresh PC-sample op."""
        cached = getattr(self, "_loop_depth_cache", None)
        if cached is not None:
            return cached
        callers = self.callers()
        memo: Dict[str, List[HloOp]] = {}

        def chain(comp: str, seen) -> List[HloOp]:
            if comp in memo:
                return memo[comp]
            if comp in seen:
                return []
            seen = seen | {comp}
            sites = callers.get(comp, [])
            if not sites:
                memo[comp] = []
                return []
            site = sites[0]  # first caller approximation (cf. §6.3)
            parent_chain = chain(site.comp, seen)
            own = [site] if site.opcode == "while" else []
            memo[comp] = parent_chain + own
            return memo[comp]

        for c in self.computations:
            chain(c, frozenset())
        self._loop_depth_cache = memo
        return memo

    def op_context(self, op: HloOp) -> List[Frame]:
        """Structure frames for an op: scope chain from op_name, enclosing
        loops, inline chain, then the op itself — what hpcstruct feeds the
        calling-context expansion (§6.1)."""
        frames: List[Frame] = []
        if op.op_name:
            parts = [p for p in op.op_name.split("/") if p]
            for p in parts[:-1]:
                frames.append(Frame(GPU_FUNC, p))
        for loop_op in self.loop_depth().get(op.comp, []):
            frames.append(Frame(GPU_LOOP, loop_op.name,
                                loop_op.op_name, loop_op.index))
        chain = self.frame_chain(op.frame_id)
        if chain:
            frames.extend(chain[-2:])  # innermost inline frames
        frames.append(Frame(GPU_OP, f"{op.opcode}:{op.name}", self.name,
                            op.index))
        return frames

    def collective_ops(self) -> List[HloOp]:
        """Collective *initiation* ops: sync spellings and async
        ``-start`` halves.  ``-done`` completions are classified
        collective (is_collective) but carry no payload of their own, so
        byte accounting skips them to avoid double counting."""
        return [op for op in self.all_ops()
                if op.collective_kind and not op.opcode.endswith("-done")]

    # -- kernel-interior structures (repro.core.kstruct) ------------------
    def bind_kernel_structure(self, ks, match: Optional[str] = None) -> int:
        """Attach a ``kstruct.KernelStructure`` to every ``custom-call``
        op whose ``op_name`` / attrs mention ``match`` (default: the
        structure's kernel name).  This is the §5 binding step: the
        opaque GPU binary region (a Pallas kernel behind a custom-call)
        gets its recovered interior structure, so pc_samples can descend
        into it.  Returns the number of ops bound."""
        needle = match or ks.name
        bound = 0
        for op in self.all_ops():
            if op.opcode != "custom-call":
                continue
            if needle in op.op_name or needle in op.attrs:
                if not hasattr(self, "_kernel_structs"):
                    self._kernel_structs = {}
                self._kernel_structs[op.index] = ks
                bound += 1
        if bound:
            # op weights and counter totals change: bound custom-calls
            # gain the kernel's modeled interior cost (custom-call
            # parses with flops=0)
            self._op_weights_cache = None
            self._op_p_cache = None
            self._op_cdf_cache = None
            self._counter_cache = None
        return bound

    def kernel_structures(self) -> Dict[int, object]:
        """op index -> bound KernelStructure (empty if none bound)."""
        return getattr(self, "_kernel_structs", None) or {}

    def comp_multipliers(self) -> Dict[str, float]:
        """Computation -> expected execution count.

        XLA's HloCostAnalysis counts a while body ONCE regardless of trip
        count (verified empirically), so scan-over-layers undercounts
        flops/bytes by ~n_layers.  We fix that here: each computation's
        multiplier is the sum over its call sites of the caller's
        multiplier, times the site's known_trip_count when the site is a
        ``while``."""
        callers = self.callers()
        memo: Dict[str, float] = {}

        def mult(comp: str, seen=frozenset()) -> float:
            if comp in memo:
                return memo[comp]
            if comp in seen:
                return 1.0
            sites = callers.get(comp, [])
            if not sites:
                m = 1.0  # entry (or dead) computation
            else:
                m = 0.0
                for site in sites:
                    sm = mult(site.comp, seen | {comp})
                    if site.opcode == "while":
                        sm *= max(site.trip_count, 1)
                    m += sm
            memo[comp] = m
            return m

        for c in self.computations:
            mult(c)
        return memo

    def fused_comps(self) -> frozenset:
        """Computations reached via fusion/call/to_apply (their ops live in
        registers/VMEM; HBM traffic is carried by the boundary op)."""
        out = set()
        for op in self.all_ops():
            if op.opcode in ("fusion", "call", "reduce", "map", "sort",
                             "scatter", "reduce-window", "select-and-scatter",
                             "all-reduce", "reduce-scatter"):
                out.update(op.callees)
        return frozenset(out)

    def total_costs(self) -> Dict[str, float]:
        """Module-level {flops, bytes} x {once, scaled}.

        ``once`` mirrors XLA cost-analysis semantics (every computation
        counted a single time); ``scaled`` applies comp_multipliers.  The
        ratio scaled/once is how roofline.py corrects
        ``compiled.cost_analysis()`` for loop trip counts."""
        mults = self.comp_multipliers()
        fused = self.fused_comps()
        out = {"flops_once": 0.0, "flops_scaled": 0.0,
               "bytes_once": 0.0, "bytes_scaled": 0.0}
        for comp in self.computations.values():
            m = mults.get(comp.name, 1.0)
            for op in comp.ops:
                if op.opcode in ("fusion", "call", "while", "conditional"):
                    flops = 0.0     # callees counted with their own mult
                else:
                    flops = op.flops
                nbytes = 0.0 if comp.name in fused else op.bytes
                out["flops_once"] += flops
                out["flops_scaled"] += flops * m
                out["bytes_once"] += nbytes
                out["bytes_scaled"] += nbytes * m
        return out

    def cost_scale(self) -> Tuple[float, float]:
        """(flops_ratio, bytes_ratio) to apply to cost_analysis numbers."""
        t = self.total_costs()
        fr = t["flops_scaled"] / t["flops_once"] if t["flops_once"] else 1.0
        br = t["bytes_scaled"] / t["bytes_once"] if t["bytes_once"] else 1.0
        return max(fr, 1.0), max(br, 1.0)

    def call_graph(self):
        """(nodes, edges): nodes = computation names; edges =
        {(caller, callee): n_call_sites}."""
        edges: Dict[Tuple[str, str], int] = {}
        for op in self.all_ops():
            for callee in op.callees:
                key = (op.comp, callee)
                edges[key] = edges.get(key, 0) + 1
        return list(self.computations), edges


def _estimate_costs(op: HloOp, ops: Dict[str, HloOp],
                    comps: Dict[str, Computation]) -> Tuple[float, float]:
    """(flops, bytes) roofline estimate for one op."""
    in_bytes = sum(ops[o].out_bytes for o in op.operands if o in ops)
    nbytes = float(in_bytes + op.out_bytes)
    opc = op.opcode
    flops = 0.0
    if opc == "dot":
        # flops = 2 * out_elems * K;  K = lhs_elems / (out "lhs part")
        lhs = ops.get(op.operands[0]) if op.operands else None
        if lhs is not None and op.out_elems:
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
            k = 1
            if m and m.group(1):
                dims_m = _SHAPE_RE.search(lhs.type_str)
                if dims_m and dims_m.group(2):
                    dims = [int(d) for d in dims_m.group(2).split(",")]
                    for ci in m.group(1).split(","):
                        ci = int(ci)
                        if ci < len(dims):
                            k *= dims[ci]
            flops = 2.0 * op.out_elems * k
        else:
            flops = 2.0 * op.out_elems
    elif opc == "convolution":
        flops = 2.0 * op.out_elems * max(1, in_bytes // max(op.out_bytes, 1))
    elif opc in ("fusion", "call"):
        for cname in op.callees:
            comp = comps.get(cname)
            if comp:
                flops += sum(o.flops for o in comp.ops)
        # fusion reads inputs + writes outputs once
    elif opc == "reduce":
        flops = float(sum(ops[o].out_elems for o in op.operands[:1]
                          if o in ops))
    elif opc in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                 "logistic", "sine", "cosine"):
        flops = 10.0 * op.out_elems      # transcendental weight
    elif opc in ("add", "subtract", "multiply", "divide", "maximum",
                 "minimum", "compare", "select", "and", "or", "xor",
                 "negate", "abs", "floor", "ceil", "clamp"):
        flops = float(op.out_elems)
    return flops, nbytes


def parse_hlo(text: str, name: str = "module") -> HloModule:
    """Parse a (compiled or lowered) HLO module text dump."""
    m = re.match(r"HloModule\s+([\w.\-]+)", text)
    if m:
        name = m.group(1)

    # --- metadata tables ---------------------------------------------------
    def table(section: str) -> Dict[int, str]:
        out: Dict[int, str] = {}
        sec = re.search(rf"^{section}\n((?:\d+ .*\n)+)", text, re.M)
        if sec:
            for line in sec.group(1).strip().splitlines():
                i, _, rest = line.partition(" ")
                out[int(i)] = rest.strip().strip('"')
        return out

    files = table("FileNames")
    funcs = table("FunctionNames")
    locs: Dict[int, Tuple[int, int, int]] = {}
    sec = re.search(r"^FileLocations\n((?:\d+ .*\n)+)", text, re.M)
    if sec:
        for line in sec.group(1).strip().splitlines():
            i, _, rest = line.partition(" ")
            fm = re.search(r"file_name_id=(\d+) function_name_id=(\d+) "
                           r"line=(\d+)", rest)
            if fm:
                locs[int(i)] = (int(fm.group(1)), int(fm.group(2)),
                                int(fm.group(3)))
    frames: Dict[int, StackFrame] = {}
    sec = re.search(r"^StackFrames\n((?:\d+ .*\n)+)", text, re.M)
    if sec:
        for line in sec.group(1).strip().splitlines():
            i, _, rest = line.partition(" ")
            fm = re.search(r"file_location_id=(\d+)(?: parent_frame_id=(\d+))?",
                           rest)
            if fm:
                loc = locs.get(int(fm.group(1)), (0, 0, 0))
                parent = int(fm.group(2) or 0)
                fid = int(i)
                frames[fid] = StackFrame(
                    funcs.get(loc[1], "?"), files.get(loc[0], "?"), loc[2],
                    0 if parent == fid else parent)

    # --- computations & ops -------------------------------------------------
    comps: Dict[str, Computation] = {}
    ops: Dict[str, HloOp] = {}
    entry = ""
    cur: Optional[Computation] = None
    index = 0
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            cm = _COMP_RE.match(line)
            if cm:
                cur = Computation(cm.group(2), [], bool(cm.group(1)))
                comps[cur.name] = cur
                if cm.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        _, opname, type_str, opcode, rest = om.groups()
        elems, nbytes = parse_shape(type_str)
        # operand names: %foo tokens inside the call parens (first level ok)
        operand_names = tuple(re.findall(r"%([\w.\-]+)", rest.split("),")[0]
                                         if ")," in rest else rest))
        meta = _META_RE.search(line)
        op = HloOp(
            name=opname, opcode=opcode, comp=cur.name, type_str=type_str,
            out_elems=elems, out_bytes=nbytes, operands=operand_names,
            op_name=meta.group(1) if meta else "",
            frame_id=int(meta.group(2)) if meta and meta.group(2) else 0,
            callees=tuple(_CALLS_RE.findall(line)),
            attrs=line, index=index)
        if opcode == "while":
            tm = _TRIP_RE.search(line)
            if tm:
                op.trip_count = int(tm.group(1))
        gm = _GROUPS_RE.search(line)
        if gm:
            op.group_size = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl and gl.group(1):
                first = gl.group(1).split("}")[0].strip("{} ")
                op.group_size = max(1, len([t for t in first.split(",")
                                            if t.strip() != ""]))
        cur.ops.append(op)
        ops[opname] = op
        index += 1

    # cost estimation needs two passes (fusion sums inner-computation flops)
    for op in ops.values():
        if op.opcode not in ("fusion", "call"):
            op.flops, op.bytes = _estimate_costs(op, ops, comps)
    for op in ops.values():
        if op.opcode in ("fusion", "call"):
            op.flops, op.bytes = _estimate_costs(op, ops, comps)

    return HloModule(name=name, computations=comps, entry=entry,
                     frames=frames, ops=ops)


def collective_bytes(module: HloModule) -> Dict[str, float]:
    """Per-collective-kind operand bytes and modeled wire bytes (per device).

    Wire model (ring): all-reduce 2(g-1)/g x operand; all-gather (g-1) x
    operand (operand = local shard); reduce-scatter / all-to-all (g-1)/g x
    operand; collective-permute 1 x operand.
    """
    out = {"operand_bytes": 0.0, "wire_bytes": 0.0}
    per_kind: Dict[str, float] = {}
    mults = module.comp_multipliers()
    for op in module.collective_ops():
        in_bytes = sum(module.ops[o].out_bytes for o in op.operands
                       if o in module.ops)
        # collectives inside while bodies (e.g. MoE all-to-all under
        # scan-over-layers) execute trip_count times
        in_bytes *= max(mults.get(op.comp, 1.0), 1.0)
        g = max(op.group_size, 1)
        kind = op.collective_kind
        if kind == "all-reduce":
            wire = 2.0 * (g - 1) / g * in_bytes
        elif kind == "all-gather":
            wire = float((g - 1)) * in_bytes
        elif kind in ("reduce-scatter", "all-to-all"):
            wire = (g - 1) / g * in_bytes
        else:  # collective-permute
            wire = float(in_bytes)
        out["operand_bytes"] += in_bytes
        out["wire_bytes"] += wire
        per_kind[kind] = per_kind.get(kind, 0.0) + in_bytes
    out.update({f"operand_bytes/{k}": v for k, v in per_kind.items()})
    return out
