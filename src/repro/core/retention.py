"""Merge-time database retention policies for continuous profiling.

A long-running job that extends its database every epoch
(``aggregate(..., base_db=...)``, ``Profiler(tag="epochN")``) grows
without bound; the ROADMAP's windowed-database item asks for retiring
old measurement windows **without recomputation**.  A
``RetentionPolicy`` does exactly that at merge time
(``merge_databases(..., retention=...)``): it filters the canonical
profile multiset — epochs beyond the keep window, duplicates, overflow
beyond a profile cap — and the merge then rebuilds the tree from the
surviving profiles' recorded context **coverage** (``coverage.npz``),
so the retained database is byte-identical to re-aggregating the
surviving profile set from scratch (pinned in tests/test_retention.py).

Policy semantics (composable; applied dedup -> window -> last -> max):

- ``dedup``            — identity-level dedup: among profiles whose
  identity JSON is identical (e.g. a database merged with itself, or a
  rank re-measured without a distinguishing ``tag``), keep the
  canonically-first one; exact-duplicate trace lines collapse too.
  Idempotent.
- ``since_epoch=TAG``  — the time-windowed database: keep epochs whose
  tag orders >= TAG (natural order: ``epoch10`` after ``epoch2``).
- ``keep_last_epochs=N`` — keep only the N newest distinct epochs.
- ``max_profiles=M``   — compaction cap: retire whole oldest epochs
  until <= M profiles remain; if a single epoch still exceeds M, drop
  canonically-first profiles **and their trace lines** (sub-epoch trace
  compaction: a line is dropped iff its identity belonged to a dropped
  profile and no surviving profile shares it; lines whose identity
  matches no profile at all are conservatively kept).

Profiles without a ``tag`` are not epoch-scoped: the epoch policies
(``since_epoch`` / ``keep_last_epochs``) always keep them.

CLI spec (``--retain`` on ``python -m repro.core.aggregate`` and
``python -m repro.core.merge``)::

    --retain "last=2,max=64,since=epoch3,dedup"
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline.database import profile_sort_key


# --------------------------------------------------------------------------
# Policy + spec parsing
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetentionPolicy:
    keep_last_epochs: Optional[int] = None
    since_epoch: Optional[str] = None
    max_profiles: Optional[int] = None
    dedup: bool = False

    def __post_init__(self):
        for name in ("keep_last_epochs", "max_profiles"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"retention: {name} must be >= 1, "
                                 f"got {v}")

    @property
    def is_noop(self) -> bool:
        return (self.keep_last_epochs is None and self.since_epoch is None
                and self.max_profiles is None and not self.dedup)


def parse_retention(spec: str) -> RetentionPolicy:
    """Parse a ``--retain`` spec: comma-separated ``last=N``, ``since=TAG``,
    ``max=M``, ``dedup`` (order-free)."""
    kw = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        key, _, value = part.partition("=")
        if key == "dedup" and not value:
            kw["dedup"] = True
        elif key == "last" and value:
            kw["keep_last_epochs"] = int(value)
        elif key == "max" and value:
            kw["max_profiles"] = int(value)
        elif key == "since" and value:
            kw["since_epoch"] = value
        else:
            raise ValueError(
                f"retention spec {spec!r}: cannot parse {part!r} "
                "(expected last=N, since=TAG, max=M, dedup)")
    return RetentionPolicy(**kw)


def epoch_key(tag: str) -> tuple:
    """Natural sort key for epoch tags: digit runs compare numerically,
    so ``epoch10`` orders after ``epoch2``."""
    return tuple(int(tok) if tok.isdigit() else tok
                 for tok in re.split(r"(\d+)", tag) if tok)


# --------------------------------------------------------------------------
# Application
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RetentionReport:
    kept_profiles: int = 0
    dropped_profiles: int = 0
    deduped_profiles: int = 0
    dropped_epochs: List[str] = dataclasses.field(default_factory=list)
    kept_lines: int = 0
    dropped_lines: int = 0

    def summary(self) -> str:
        parts = [f"retention: kept {self.kept_profiles} profile(s)"]
        if self.deduped_profiles:
            parts.append(f"deduped {self.deduped_profiles}")
        if self.dropped_profiles:
            parts.append(f"retired {self.dropped_profiles}")
        if self.dropped_epochs:
            parts.append("epochs retired: "
                         + " ".join(self.dropped_epochs))
        if self.dropped_lines:
            parts.append(f"trace lines dropped: {self.dropped_lines}")
        return "; ".join(parts)


def _tag(identity: dict) -> Optional[str]:
    tag = identity.get("tag")
    return str(tag) if tag is not None else None


def _line_fingerprint(td) -> tuple:
    return (json.dumps(td.identity, sort_keys=True),
            np.asarray(td.starts, np.int64).tobytes(),
            np.asarray(td.ends, np.int64).tobytes(),
            np.asarray(td.ctx, np.int64).tobytes())


def apply_retention(entries: Sequence[tuple], trace_lines: Sequence,
                    policy: RetentionPolicy
                    ) -> Tuple[list, list, RetentionReport]:
    """Filter the profile multiset and its trace lines.

    ``entries`` are ``(identity, ctx, metric, values, coverage)`` tuples
    against one canonical ctx-id space (what ``merge_databases`` holds
    after the union remap); ``trace_lines`` are ``TraceData``.  Returns
    the surviving subsets (canonically ordered) and a report.  The
    caller is responsible for restricting the tree to the survivors'
    coverage (``merge_databases`` does).
    """
    report = RetentionReport()
    items = sorted(entries,
                   key=lambda e: profile_sort_key(e[0], e[1], e[2], e[3]))
    lines = list(trace_lines)
    n_in, lines_in = len(items), len(lines)

    if policy.dedup:
        seen, kept = set(), []
        for e in items:
            key = json.dumps(e[0], sort_keys=True)
            if key in seen:
                continue
            seen.add(key)
            kept.append(e)
        report.deduped_profiles = len(items) - len(kept)
        items = kept
        seen_l, kept_l = set(), []
        for td in lines:
            fp = _line_fingerprint(td)
            if fp in seen_l:
                continue
            seen_l.add(fp)
            kept_l.append(td)
        lines = kept_l

    def retire_epochs(retired: set):
        nonlocal items, lines
        if not retired:
            return
        report.dropped_epochs.extend(sorted(retired, key=epoch_key))
        items = [e for e in items if _tag(e[0]) not in retired]
        lines = [td for td in lines if _tag(td.identity) not in retired]

    tags = sorted({t for t in (_tag(e[0]) for e in items) if t is not None},
                  key=epoch_key)
    if policy.since_epoch is not None:
        cut = epoch_key(policy.since_epoch)
        retire_epochs({t for t in tags if epoch_key(t) < cut})
        tags = [t for t in tags if epoch_key(t) >= cut]
    if policy.keep_last_epochs is not None \
            and len(tags) > policy.keep_last_epochs:
        retire_epochs(set(tags[:-policy.keep_last_epochs]))
        tags = tags[-policy.keep_last_epochs:]

    if policy.max_profiles is not None:
        while len(items) > policy.max_profiles:
            alive = sorted({t for t in (_tag(e[0]) for e in items)
                            if t is not None}, key=epoch_key)
            if len(alive) > 1:
                retire_epochs({alive[0]})
            else:
                # one (or no) epoch left: cap by dropping canonically-
                # first profiles, and compact their trace lines too —
                # a line goes iff its identity belonged to a dropped
                # profile and no survivor shares it (lines matching no
                # profile at all are conservatively kept)
                dropped = items[:len(items) - policy.max_profiles]
                items = items[len(items) - policy.max_profiles:]
                kept_ids = {json.dumps(e[0], sort_keys=True)
                            for e in items}
                orphaned = {json.dumps(e[0], sort_keys=True)
                            for e in dropped} - kept_ids
                if orphaned:
                    lines = [td for td in lines
                             if json.dumps(td.identity, sort_keys=True)
                             not in orphaned]
                break

    report.kept_profiles = len(items)
    report.dropped_profiles = n_in - len(items) - report.deduped_profiles
    report.kept_lines = len(lines)
    report.dropped_lines = lines_in - len(lines)
    return items, lines, report
