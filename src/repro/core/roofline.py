"""Roofline analysis driven by the tool itself (deliverable (g); DESIGN.md
§3).  Consumes ``compiled.cost_analysis()`` + the hpcstruct-analogue HLO
parse and reports the three terms per (arch x shape x mesh):

    compute    = HLO_FLOPs / (chips x peak FLOP/s)
    memory     = HLO_bytes / (chips x HBM bandwidth)
    collective = collective wire bytes / (chips x link bandwidth)

cost_analysis on an SPMD-partitioned module reports *per-device* flops and
bytes, so dividing by per-chip peaks directly equals the prompt's
total/(chips x peak) form.  Collective bytes are NOT in cost_analysis: they
are summed from the partitioned HLO text over all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand sizes, with a
ring-model wire multiplier (structure.collective_bytes).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.core.structure import HloModule, collective_bytes, parse_hlo

# TPU v5e-class constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 5.0e10           # bytes/s per link (prompt: ~50 GB/s/link)


@dataclasses.dataclass
class RooflineReport:
    name: str
    mesh: str
    chips: int
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    coll_operand_bytes: float
    coll_wire_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_total: float
    bytes_per_dev: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound estimate (sum) and its max lower bound
        are both useful; we report max (perfect overlap) as the step time
        and keep the individual terms visible."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_total — remat/padding/dispatch waste."""
        total_hlo = self.hlo_flops_per_dev * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Roofline-model MFU: useful model flops / (chips*peak*step_time)."""
        denom = self.chips * PEAK_FLOPS * self.step_time
        return self.model_flops_total / denom if denom else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term pins execution to its roof: the
        fraction of step time the dominant resource is busy doing useful
        work.  For compute-bound this equals MFU."""
        if self.dominant == "compute":
            return self.mfu
        return (self.t_compute / self.step_time) if self.step_time else 0.0

    def row(self) -> dict:
        return {
            "name": self.name, "mesh": self.mesh, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops_total,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "hlo_bytes_per_dev": self.hlo_bytes_per_dev,
            "coll_operand_bytes_per_dev": self.coll_operand_bytes,
            "coll_wire_bytes_per_dev": self.coll_wire_bytes,
            "useful_ratio": self.useful_ratio,
            "mfu_model": self.mfu,
            "step_time_s": self.step_time,
        }


def analyze(name: str, mesh_desc: str, chips: int, cost: Dict[str, float],
            hlo_text: Optional[str] = None,
            module: Optional[HloModule] = None,
            model_flops_total: float = 0.0,
            peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
            ici_bw: float = ICI_BW) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    if module is None:
        module = parse_hlo(hlo_text or "", name=name)
    # XLA cost analysis counts while bodies once; scale by the parsed
    # trip-count-aware ratio (structure.HloModule.cost_scale).
    fr, br = module.cost_scale()
    flops *= fr
    nbytes *= br
    coll = collective_bytes(module)
    return RooflineReport(
        name=name, mesh=mesh_desc, chips=chips,
        hlo_flops_per_dev=flops,
        hlo_bytes_per_dev=nbytes,
        coll_operand_bytes=coll["operand_bytes"],
        coll_wire_bytes=coll["wire_bytes"],
        t_compute=flops / peak_flops,
        t_memory=nbytes / hbm_bw,
        t_collective=coll["wire_bytes"] / ici_bw,
        model_flops_total=model_flops_total,
        bytes_per_dev={k: v for k, v in coll.items()
                       if k.startswith("operand_bytes/")},
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS convention: 6*N*D for training (N = params, D = tokens;
    active params for MoE), 2*N*D for prefill, 2*N_active*B per decoded
    token."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def markdown_table(rows) -> str:
    cols = ["name", "mesh", "chips", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "model_flops",
            "useful_ratio", "mfu_model", "step_time_s"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        vals = []
        for c in cols:
            v = r[c] if isinstance(r, dict) else getattr(r, c)
            vals.append(f"{v:.3e}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(vals) + " |")
    return "\n".join(out)
