"""Stage contracts: the dataclasses the pipeline phases hand each other.

Every phase of the aggregation pipeline (acquire -> unify -> expand ->
stats -> traceconv -> write) consumes and produces one of these, so the
stages compose the same way whether they run inline (serial driver), on
threads, or in worker processes (``pipeline.driver``).  The contracts
are deliberately plain — numpy arrays, lists, dicts — so a
``ShardResult`` pickles cheaply across a ``ProcessPoolExecutor`` pipe.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.cct import Frame
from repro.core.profmt import ProfileData
from repro.core.sparse import ProfileValues


@dataclasses.dataclass
class UnifiedProfile:
    """One loaded profile after unification (phase 2 output, per file)."""
    path: str
    prof: ProfileData
    gmap: np.ndarray            # local node id -> canonical global ctx id


@dataclasses.dataclass
class Unification:
    """Phase-2 contract: the canonical global tree + per-profile maps."""
    frames: List[Frame]         # canonical order (see unify.canonical_order)
    parents: np.ndarray
    profiles: List[UnifiedProfile]
    unify_s: float = 0.0

    @property
    def metrics(self) -> List[str]:
        return self.profiles[0].prof.metrics if self.profiles else []


@dataclasses.dataclass
class ProfileEntry:
    """Phase-4 contract: one profile's inclusive sparse values against
    canonical ctx ids, plus the set of ctx ids the profile's CCT touched
    (``coverage`` — what retention policies need to rebuild the exact
    survivor tree, ``repro.core.retention``)."""
    identity: dict
    ctx: np.ndarray             # (V,) int64, row-major sorted with metric
    metric: np.ndarray          # (V,) int64
    values: np.ndarray          # (V,) float64
    coverage: np.ndarray        # (C,) int64, sorted unique ctx ids

    def astuple(self):
        return (self.identity, self.ctx, self.metric, self.values,
                self.coverage)


@dataclasses.dataclass
class ShardResult:
    """What a shard worker hands back to the fold (phases 1-4 over a
    subset of the profiles; no trace work, no disk output).

    Duck-type compatible with ``repro.core.merge.LoadedShard``: the same
    ``merge_databases`` fold consumes either, which is what makes the
    parallel driver's output byte-identical to the serial path by
    construction (the merge contract, docs/aggregation.md).
    """
    frames: List[Frame]
    parents: np.ndarray
    metrics: List[str]
    identities: Dict[int, dict]                 # profile id -> identity
    pvals: List[ProfileValues]                  # shard-canonical ctx ids
    coverage: Dict[int, np.ndarray]             # profile id -> ctx id set
    gmaps: Dict[str, np.ndarray]                # path -> local->shard map
    trace_lines: list = dataclasses.field(default_factory=list)
    unify_s: float = 0.0
    stats_s: float = 0.0
    out_dir: Optional[str] = None               # label for diagnostics
