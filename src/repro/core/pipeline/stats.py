"""Phase 4 — statistic generation (paper §6.1, §6.2).

Per profile, metric values are scatter-added into a sparse
(ctx, metric) COO set and propagated up the tree with a vectorized
level-order sweep (one grouped ``np.add.at`` per tree level, deepest
first); workers share *nothing* — per-profile partial accumulators are
folded once, in canonical profile order, inside
``pipeline.database.write_database`` (the paper's communication-free
workers after exscan).  The FP addition order reproduces the dense
reverse-id reference sweep bit for bit (tests/test_aggregate_equiv.py).
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import List, Tuple

import numpy as np

from repro.core.cct import tree_depths
from repro.core.pipeline.contracts import (ProfileEntry, UnifiedProfile,
                                           Unification)
from repro.core.profmt import ProfileData


def _group_sum_ordered(keys: np.ndarray, vals: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Sum ``vals`` grouped by ``keys``, accumulating within each group in
    the array order of equal keys (stable sort + one unbuffered
    ``np.add.at``) — the FP addition order therefore matches a sequential
    scatter loop over the same data."""
    order = np.argsort(keys, kind="stable")
    ks, vs = keys[order], vals[order]
    uk, counts = np.unique(ks, return_counts=True)
    gidx = np.repeat(np.arange(len(uk)), counts)
    out = np.zeros(len(uk))
    np.add.at(out, gidx, vs)
    return uk, out


def _profile_inclusive_sparse(prof: ProfileData, gmap: np.ndarray,
                              parents: np.ndarray, depth: np.ndarray,
                              n_metrics: int
                              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One profile's inclusive (ctx, metric, value) triplets against the
    global tree, fully sparse.

    Exclusive values are scatter-added into COO keyed by
    ``ctx * n_metrics + metric``; inclusive propagation is a level-order
    sweep from the deepest tree level to the root — per level one grouped
    ``np.add.at`` folds the (already-inclusive) child entries into their
    parents.  Children are folded in decreasing global-id order after the
    parent's own exclusive value, which reproduces, bit for bit, the FP
    addition order of the classic dense reverse-id sweep (see
    docs/aggregation.md and tests/test_aggregate_equiv.py).
    """
    n_values = len(prof.values)
    if n_values == 0 or n_metrics == 0:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros(0, np.float64)
    ranges = prof.ranges
    starts, counts = ranges[:, 1], ranges[:, 2]
    if (len(ranges) and starts[0] == 0
            and starts[-1] + counts[-1] == n_values
            and np.array_equal(starts[1:], starts[:-1] + counts[:-1])):
        node_of_value = np.repeat(gmap[ranges[:, 0]], counts)
    else:   # non-contiguous layout: rare, keep the per-range fill
        node_of_value = np.zeros(n_values, np.int64)
        for nid, start, count in ranges:
            node_of_value[start:start + count] = gmap[int(nid)]
    keys = node_of_value * n_metrics + prof.value_mids.astype(np.int64)
    uk, val = _group_sum_ordered(keys, prof.values)
    ctx = uk // n_metrics
    met = uk % n_metrics

    dd = depth[ctx]
    maxd = int(dd.max()) if len(dd) else 0
    for lvl in range(maxd, 0, -1):
        sel = dd == lvl
        if not sel.any():
            continue
        s_ctx, s_met, s_val = ctx[sel], met[sel], val[sel]
        # children fold into a parent in decreasing id order (stable), the
        # order the dense reverse-id sweep adds them in
        o = np.argsort(-s_ctx, kind="stable")
        up_keys = parents[s_ctx[o]] * n_metrics + s_met[o]
        plv = dd == lvl - 1
        # parent's own (exclusive) entry first, then its children
        cat_keys = np.concatenate([ctx[plv] * n_metrics + met[plv], up_keys])
        cat_vals = np.concatenate([val[plv], s_val[o]])
        uk2, nv = _group_sum_ordered(cat_keys, cat_vals)
        keep = ~plv
        ctx = np.concatenate([ctx[keep], uk2 // n_metrics])
        met = np.concatenate([met[keep], uk2 % n_metrics])
        val = np.concatenate([val[keep], nv])
        dd = depth[ctx]

    nz = val != 0.0          # match np.nonzero() on the dense matrix
    ctx, met, val = ctx[nz], met[nz], val[nz]
    o = np.argsort(ctx * n_metrics + met, kind="stable")  # row-major order
    return ctx[o], met[o], val[o]


def profile_coverage(up: UnifiedProfile) -> np.ndarray:
    """The set of canonical ctx ids this profile's CCT mapped into —
    sorted unique, always including the root.  Recorded per profile in
    the database (``coverage.npz``) so retention policies can rebuild
    the exact tree a re-aggregation of the surviving profiles would
    build (``repro.core.retention``)."""
    node_ids = up.prof.node_ids
    if len(node_ids) == 0:
        return np.zeros(1, np.int64)
    return np.unique(up.gmap[node_ids]).astype(np.int64)


def generate_stats(uni: Unification, *,
                   n_workers: int = 4) -> List[ProfileEntry]:
    """Run phase 4 over every unified profile.  Workers are
    communication-free: each returns its profile's sparse triplets; the
    partial accumulators are folded in ``write_database``, once, in
    canonical profile order — no shared state, no lock, deterministic."""
    metrics = uni.metrics
    n_metrics = len(metrics)
    parents = np.asarray(uni.parents, np.int64)
    depth = tree_depths(parents)

    def gen(up: UnifiedProfile) -> ProfileEntry:
        ctx, met, val = _profile_inclusive_sparse(up.prof, up.gmap, parents,
                                                  depth, n_metrics)
        return ProfileEntry(up.prof.identity, ctx, met, val,
                            profile_coverage(up))

    with ThreadPoolExecutor(max(1, n_workers)) as ex:
        return list(ex.map(gen, uni.profiles))
