"""Phase 5 — trace conversion (paper §6.1, §4.4).

Trace files are rewritten in terms of global ctx ids (vectorized gather
+ bulk ``TraceWriter.append_many``) and merged into one seekable
``trace.db`` (repro.traceview).  Three cases per ``.rtrc``:

- a trace with a matching ``.rpro`` basename converts through that
  profile's gmap (CPU-thread traces);
- a GPU-stream trace written by ``Profiler.write()`` records the
  *dispatching app thread* per event (the thread index rides the high
  ctx bits, ``trace.DISPATCH_CTX_SHIFT``; the identity's
  ``dispatch_profiles`` maps thread index -> profile basename): each
  event converts through its dispatcher's gmap — heterogeneous traces
  land on real database ctx ids;
- anything else (or a dispatch trace whose profiles were not part of
  this aggregation) passes through verbatim with a ``ctx_unmapped``
  identity flag, which downstream composition (``repro.core.merge``)
  honours by copying the line unchanged.
"""
from __future__ import annotations

import os
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.trace import (DISPATCH_CTX_MASK, DISPATCH_CTX_SHIFT,
                              TraceWriter, read_trace, read_trace_header)


def required_profiles(tpath: str, identity: Optional[dict],
                      profile_paths) -> List[str]:
    """The profile paths a trace needs for exact ctx conversion, resolved
    against the given profile set — the same resolution rule
    ``convert_traces`` applies, exposed so tools (and the contract
    tests) can ask "which profiles must accompany this trace?" without
    converting.  The shard driver deliberately does NOT use it: phase 5
    runs in-parent against every gmap, so traces never constrain the
    partition.  ``identity`` may be ``None`` to read it from the trace
    header."""
    direct = tpath.replace(".rtrc", ".rpro")
    if direct in profile_paths:
        return [direct]
    if identity is None:
        try:
            identity = read_trace_header(tpath).get("identity", {})
        except (OSError, ValueError):
            return []
    dp = identity.get("dispatch_profiles")
    if not dp:
        return []
    base = os.path.dirname(tpath)
    cands = [os.path.join(base, bname) for bname in dp.values()]
    return [c for c in cands if c in profile_paths]


def _convert_dispatch(td, gmaps_by_idx: Dict[int, np.ndarray], tpath: str
                      ) -> np.ndarray:
    """Per-event conversion through each event's dispatcher gmap."""
    enc = np.asarray(td.ctx, np.int64)
    idxs = enc >> DISPATCH_CTX_SHIFT
    nodes = enc & DISPATCH_CTX_MASK
    gids = np.zeros(len(enc), np.int64)
    bad = 0
    for i in np.unique(idxs):
        gmap = gmaps_by_idx[int(i)]
        sel = idxs == i
        node = nodes[sel]
        valid = (node >= 0) & (node < len(gmap))
        bad += int((~valid).sum())
        gids[sel] = np.where(valid,
                             gmap[np.clip(node, 0, len(gmap) - 1)], 0)
    if bad:
        warnings.warn(
            f"{tpath}: {bad} trace event(s) reference ctx ids outside "
            "the dispatching thread's id map; attributing them to the "
            "root context", RuntimeWarning)
    return gids


def convert_traces(trace_paths: Sequence[str],
                   gmaps: Dict[str, np.ndarray],
                   out_dir: str) -> List[str]:
    """Rewrite every trace into ``out_dir`` with global ctx ids.
    ``gmaps`` maps profile path -> local-node-id -> global-ctx-id.
    Returns the converted paths (input order, deduplicated)."""
    converted: List[str] = []
    for tpath in trace_paths:
        td = read_trace(tpath)
        identity = td.identity
        gmap = gmaps.get(tpath.replace(".rtrc", ".rpro"))
        dispatch: Optional[Dict[int, np.ndarray]] = None
        if gmap is None:
            dp = identity.get("dispatch_profiles") or {}
            base = os.path.dirname(tpath)
            found = {int(i): gmaps.get(os.path.join(base, bname))
                     for i, bname in dp.items()}
            if dp and all(g is not None for g in found.values()):
                dispatch = found
                # the encoding is consumed here; the converted trace
                # carries plain database ctx ids like any other line
                identity = {k: v for k, v in identity.items()
                            if k != "dispatch_profiles"}
            else:
                # no matching profile(s): ctx ids pass through unmapped
                # (e.g. a gpu-stream trace aggregated without its rank's
                # thread profiles).  Mark the line so downstream
                # composition (repro.core.merge) copies it verbatim
                # instead of remapping ids that were never database ctx
                # ids.
                identity = {**identity, "ctx_unmapped": True}
        out = TraceWriter(os.path.join(out_dir, os.path.basename(tpath)),
                          identity)
        if dispatch is not None:
            gids = _convert_dispatch(td, dispatch, tpath)
        elif gmap is None:
            gids = td.ctx
        else:
            valid = (td.ctx >= 0) & (td.ctx < len(gmap))
            if not valid.all():
                warnings.warn(
                    f"{tpath}: {int((~valid).sum())} trace event(s) "
                    "reference ctx ids outside the profile's id map; "
                    "attributing them to the root context", RuntimeWarning)
            gids = np.where(valid,
                            gmap[np.clip(td.ctx, 0, len(gmap) - 1)], 0)
        out.append_many(td.starts, td.ends, gids)
        out.close()
        if out.path in converted:
            warnings.warn(
                f"{tpath}: basename collides with another trace path; "
                "the earlier converted trace was overwritten",
                RuntimeWarning)
        else:
            converted.append(out.path)
    return converted


def build_trace_db(converted: Sequence[str], out_dir: str, *,
                   pyramid: bool = False, parents=None) -> None:
    """Post-mortem merge into the seekable trace.db (traceview, §4.4):
    the converted traces already carry global ctx ids, so the merged
    database is directly renderable against the Database.

    ``pyramid=True`` also builds the ``trace.pyr`` tile pyramid
    (repro.traceview.pyramid) from the fresh trace.db and the final CCT
    ``parents`` — the opt-in phase-5 variant of the lazy
    ``ensure_pyramid`` cache."""
    from repro.traceview.tracedb import build_db
    db_path = os.path.join(out_dir, "trace.db")
    with build_db(list(converted), db_path):
        pass
    if pyramid:
        if parents is None:
            raise ValueError("trace pyramid build requires the CCT parents")
        from repro.traceview.pyramid import build_pyramid
        build_pyramid(db_path, parents).close()
