"""The on-disk database: reader (``Database``) and the single shared
writer (``write_database``) behind both ``aggregate()`` and
``repro.core.merge.merge_databases``.

Canonical-database contract (docs/aggregation.md): every output byte —
tree, stats, CMS/PMS cubes, coverage — is a pure function of the
*profile set*.  Context ids are canonical (``pipeline.unify``); profile
ids are assigned here in canonical identity order (``profile_sort_key``).

Files in a database directory::

    meta.json      tree, metrics, profile identities, cube info, timing
    stats.npz      sum/min/mean/max/std/cov/count per (ctx, metric)
    metrics.cms    CCT-major sparse cube      (repro.core.sparse)
    metrics.pms    profile-major sparse cube  (repro.core.sparse)
    coverage.npz   per-profile ctx-id coverage sets (retention input)
    trace.db       merged traces (repro.traceview), when traces were given
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cct import Frame, tree_depths
from repro.core.pipeline.contracts import ProfileEntry
from repro.core.sparse import ProfileValues, write_cms, write_pms

STATS = ("sum", "min", "mean", "max", "std", "cov")


def _ident_int(identity: dict, *keys) -> int:
    for k in keys:
        v = identity.get(k)
        if v is not None:
            try:
                return int(v)
            except (TypeError, ValueError):
                return 0
    return 0


def profile_sort_key(identity: dict, ctx: np.ndarray, met: np.ndarray,
                     val: np.ndarray) -> tuple:
    """Canonical profile order: host, rank, CPU threads before GPU
    streams, thread/stream index (the trace.db line order), then the full
    identity JSON, then a digest of the value triplets as a content
    tie-break — a pure function of the profile, never of input order."""
    digest = hashlib.sha256(
        np.ascontiguousarray(ctx.astype("<u4")).tobytes()
        + np.ascontiguousarray(met.astype("<u4")).tobytes()
        + np.ascontiguousarray(val.astype("<f8")).tobytes()).hexdigest()
    return (str(identity.get("host", "")), _ident_int(identity, "rank"),
            0 if identity.get("type", "cpu") == "cpu" else 1,
            _ident_int(identity, "thread", "stream"),
            json.dumps(identity, sort_keys=True), digest)


def ancestor_closure(ids: np.ndarray, parents: np.ndarray) -> np.ndarray:
    """Sorted unique ``ids`` plus all their ancestors (and the root) —
    the fallback coverage for callers that hand ``write_database`` bare
    4-tuples, and the tree-restriction primitive retention uses."""
    parents = np.asarray(parents, np.int64)
    keep = np.zeros(len(parents), bool)
    keep[0] = True
    keep[np.asarray(ids, np.int64)] = True
    frontier = keep.copy()
    while frontier.any():
        up = parents[np.nonzero(frontier)[0]]
        up = up[up >= 0]
        frontier = np.zeros(len(parents), bool)
        frontier[up[~keep[up]]] = True
        keep |= frontier
    return np.nonzero(keep)[0].astype(np.int64)


# --------------------------------------------------------------------------
# Reader
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Database:
    out_dir: str
    frames: List[Frame]
    parents: np.ndarray
    metrics: List[str]
    profile_ids: Dict[int, dict]            # profile id -> identity
    stats: Dict[str, np.ndarray]            # stat -> (n_ctx, n_metrics)
    inclusive: bool = True
    # CSR children index, built lazily on first children_of() call
    _child_order: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False)
    _child_parents: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False)
    _depths: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False)

    @classmethod
    def load(cls, out_dir: str) -> "Database":
        with open(os.path.join(out_dir, "meta.json")) as f:
            meta = json.load(f)
        frames = [Frame(*f) for f in meta["frames"]]
        data = np.load(os.path.join(out_dir, "stats.npz"))
        stats = {k: data[k] for k in data.files}
        return cls(out_dir, frames, np.asarray(meta["parents"]),
                   meta["metrics"],
                   {int(k): v for k, v in meta["profiles"].items()}, stats)

    def metric_id(self, name: str) -> int:
        return self.metrics.index(name)

    def children_of(self, gid: int) -> List[int]:
        """Children of a context, via a precomputed CSR index (a stable
        argsort of the parent array) instead of an O(n) scan per call."""
        if self._child_order is None:
            parents = np.asarray(self.parents, np.int64)
            order = np.argsort(parents, kind="stable")
            # publish _child_parents first: a concurrent caller passing the
            # None-check above must find both arrays populated
            self._child_parents = parents[order]
            self._child_order = order
        lo, hi = np.searchsorted(self._child_parents, [gid, gid + 1])
        return [int(i) for i in self._child_order[lo:hi]]

    def depths(self) -> np.ndarray:
        """Per-context depth (root = 0), cached — the traceview raster and
        interval stats project contexts through this."""
        if self._depths is None:
            self._depths = tree_depths(self.parents)
        return self._depths

    def coverage(self) -> Optional[Dict[int, np.ndarray]]:
        """Per-profile ctx-coverage sets (``coverage.npz``), or ``None``
        for databases written before coverage was recorded."""
        return load_coverage(self.out_dir)

    def trace_db_path(self) -> str:
        return os.path.join(self.out_dir, "trace.db")

    def cms_path(self) -> str:
        return os.path.join(self.out_dir, "metrics.cms")

    def pms_path(self) -> str:
        return os.path.join(self.out_dir, "metrics.pms")

    def coverage_path(self) -> str:
        return os.path.join(self.out_dir, "coverage.npz")


def load_coverage(out_dir: str) -> Optional[Dict[int, np.ndarray]]:
    path = os.path.join(out_dir, "coverage.npz")
    if not os.path.exists(path):
        return None
    data = np.load(path)
    ids, offsets = data["ids"], data["offsets"]
    return {i: ids[offsets[i]:offsets[i + 1]]
            for i in range(len(offsets) - 1)}


# --------------------------------------------------------------------------
# Writer (shared with repro.core.merge)
# --------------------------------------------------------------------------
def write_database(out_dir: str, frames: List[Frame], parents: np.ndarray,
                   metrics: List[str],
                   profiles: Sequence,
                   *, n_workers: int, t0: float,
                   timing_base: Optional[dict] = None) -> Database:
    """Fold per-profile inclusive triplets into the on-disk database.

    ``profiles`` is a sequence of ``ProfileEntry`` (or bare
    ``(identity, ctx, metric, value[, coverage])`` tuples) against
    canonical context ids, in *any* order: profiles are sorted into
    canonical order here (``profile_sort_key``), so stats accumulation,
    the CMS/PMS cubes, coverage, and ``meta.json`` come out
    byte-identical for any arrival order — the single writer behind both
    ``aggregate()`` and ``merge_databases()``.
    """
    os.makedirs(out_dir, exist_ok=True)
    n_ctx = len(frames)
    n_metrics = len(metrics)
    prepped = []
    for item in profiles:
        ident, ctx, met, val, *rest = (
            item.astuple() if isinstance(item, ProfileEntry) else item)
        ctx = np.asarray(ctx, np.int64)
        met = np.asarray(met, np.int64)
        val = np.asarray(val, np.float64)
        o = np.lexsort((met, ctx))          # row-major, defensive re-sort
        ctx, met, val = ctx[o], met[o], val[o]
        cover = (np.asarray(rest[0], np.int64) if rest
                 else ancestor_closure(ctx, parents))
        prepped.append((profile_sort_key(ident, ctx, met, val),
                        ident, ctx, met, val, cover))
    prepped.sort(key=lambda it: it[0])

    identities: Dict[int, dict] = {}
    pvals: List[ProfileValues] = []
    covers: List[np.ndarray] = []
    acc_sum = np.zeros((n_ctx, n_metrics))
    acc_min = np.full((n_ctx, n_metrics), np.inf)
    acc_max = np.full((n_ctx, n_metrics), -np.inf)
    acc_sumsq = np.zeros((n_ctx, n_metrics))
    acc_count = np.zeros((n_ctx, n_metrics))
    for pidx, (_, ident, ctx, met, val, cover) in enumerate(prepped):
        identities[pidx] = ident
        pvals.append(ProfileValues(pidx, ctx.astype(np.uint32),
                                   met.astype(np.uint32), val))
        covers.append(cover)
        idx = (ctx, met)
        acc_sum[idx] += val           # (ctx, metric) pairs unique per profile
        np.minimum.at(acc_min, idx, val)
        np.maximum.at(acc_max, idx, val)
        acc_sumsq[idx] += val ** 2
        acc_count[idx] += 1

    count = np.maximum(acc_count, 1)
    mean = acc_sum / count
    var = np.maximum(acc_sumsq / count - mean ** 2, 0.0)
    std = np.sqrt(var)
    stats = {
        "sum": acc_sum,
        "min": np.where(np.isfinite(acc_min), acc_min, 0.0),
        "mean": mean,
        "max": np.where(np.isfinite(acc_max), acc_max, 0.0),
        "std": std,
        "cov": np.where(mean != 0, std / np.maximum(np.abs(mean), 1e-30),
                        0.0),
        "count": acc_count,
    }

    cms_info = write_cms(os.path.join(out_dir, "metrics.cms"), pvals,
                         n_workers=n_workers)
    pms_info = write_pms(os.path.join(out_dir, "metrics.pms"), pvals,
                         n_workers=n_workers)
    cov_ids = (np.concatenate(covers) if covers else np.zeros(0, np.int64))
    cov_off = np.zeros(len(covers) + 1, np.int64)
    np.cumsum([len(c) for c in covers], out=cov_off[1:])
    np.savez(os.path.join(out_dir, "coverage.npz"),
             ids=cov_ids.astype(np.int64), offsets=cov_off)

    meta = {
        "frames": [[f.kind, f.name, f.module, f.line] for f in frames],
        "parents": [int(p) for p in parents],
        "metrics": metrics,
        "profiles": {str(i): ident for i, ident in identities.items()},
        "cms": cms_info, "pms": pms_info,
        "timing": {**(timing_base or {}),
                   "total_s": time.monotonic() - t0},
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f)
    np.savez(os.path.join(out_dir, "stats.npz"), **stats)
    return Database(out_dir, frames, np.asarray(parents), metrics,
                    identities, stats)
