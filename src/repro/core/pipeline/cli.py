"""``python -m repro.core.aggregate`` — aggregate measurement output
into a database from the command line.

Inputs are ``.rpro`` profile files, ``.rtrc`` trace files, and/or
measurement directories (expanded to the profiles and traces inside).
The shard driver and retention policy ride the same flags the API
exposes::

    python -m repro.core.aggregate MEASURE_DIR -o DB --workers 4
    python -m repro.core.aggregate epoch9/ -o DB --base DB --retain last=4
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

from repro.core.pipeline.acquire import expand_inputs
from repro.core.pipeline.driver import DRIVERS


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.aggregate",
        description="Aggregate .rpro profiles (+ .rtrc traces) into a "
                    "performance database (docs/pipeline.md).")
    ap.add_argument("inputs", nargs="+",
                    help="profile/trace files or measurement directories")
    ap.add_argument("-o", "--out", required=True,
                    help="output database directory")
    ap.add_argument("--workers", type=int, default=None,
                    help="shard-driver worker count (default: "
                         "$REPRO_AGG_WORKERS, else 4 for parallel "
                         "drivers)")
    ap.add_argument("--driver", choices=DRIVERS, default=None,
                    help="shard executor (default: $REPRO_AGG_DRIVER, "
                         "else process when --workers > 1, else serial)")
    ap.add_argument("--ranks", type=int, default=4,
                    help="unification ranks inside a shard (default 4)")
    ap.add_argument("--threads", type=int, default=4,
                    help="per-rank threads inside a shard (default 4)")
    ap.add_argument("--base", default=None, metavar="DB",
                    help="extend an existing database (incremental epoch "
                         "mode; may equal --out)")
    ap.add_argument("--retain", default=None, metavar="SPEC",
                    help="retention policy applied at merge time, e.g. "
                         "'last=2,max=64,dedup' (repro.core.retention)")
    ap.add_argument("--no-trace-db", action="store_true",
                    help="skip building the merged trace.db")
    ap.add_argument("--trace-pyramid", action="store_true",
                    help="also build the trace.pyr tile pyramid next to "
                         "trace.db (O(tile) zoom/pan; docs/traceview.md)")
    args = ap.parse_args(argv)

    from repro.core.aggregate import aggregate
    from repro.core.merge import summarize
    from repro.core.retention import parse_retention

    profiles, traces = expand_inputs(args.inputs)
    db = aggregate(
        profiles, args.out, n_ranks=args.ranks, n_threads=args.threads,
        trace_paths=traces, trace_db=not args.no_trace_db,
        trace_pyramid=args.trace_pyramid,
        base_db=args.base, workers=args.workers, driver=args.driver,
        retention=parse_retention(args.retain) if args.retain else None)
    print(f"AGGREGATE  {len(profiles)} profile(s), {len(traces)} "
          f"trace(s)" + (f" + base {args.base}" if args.base else ""))
    print(summarize(db, [args.out]).split("\n", 2)[2])
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
