"""Staged aggregation pipeline (paper §6.1), one module per phase.

The ``hpcprof`` analogue is an explicitly staged pipeline; this package
gives each paper phase its own module behind a dataclass stage contract
(``contracts``), plus a pluggable shard driver:

- ``acquire``   — phase 1: input acquisition + round-robin distribution
- ``unify``     — phase 2: call-path unification into the global CCT,
  canonical renumbering (``GlobalTree``, ``canonical_order``)
- ``expand``    — phase 3: calling-context expansion against structure
- ``stats``     — phase 4: sparse statistic generation
- ``traceconv`` — phase 5: trace conversion to global ctx ids
- ``database``  — the on-disk database writer/reader shared with
  ``repro.core.merge`` (``Database``, ``write_database``)
- ``driver``    — serial / thread / process executors over profile
  shards, folded through ``merge_databases`` (docs/pipeline.md)
- ``cli``       — ``python -m repro.core.aggregate``

``repro.core.aggregate`` remains the public façade: every name that was
importable from it before the decomposition still is.
"""
from repro.core.pipeline.acquire import Acquisition, acquire  # noqa: F401
from repro.core.pipeline.contracts import (ProfileEntry,  # noqa: F401
                                           ShardResult, UnifiedProfile,
                                           Unification)
from repro.core.pipeline.database import (Database,  # noqa: F401
                                          profile_sort_key, write_database)
from repro.core.pipeline.expand import make_expander  # noqa: F401
from repro.core.pipeline.stats import generate_stats  # noqa: F401
from repro.core.pipeline.traceconv import convert_traces  # noqa: F401
from repro.core.pipeline.unify import (GlobalTree,  # noqa: F401
                                       apply_order, canonical_order, unify)
