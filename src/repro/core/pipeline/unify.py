"""Phase 2 — call-path unification (paper §6.1) + canonical renumbering.

Each rank unifies its profiles' CCTs into a rank-local tree; rank trees
merge up a reduction tree to the root, yielding the global calling
context tree and a local->global id mapping per profile.  The tree is
then renumbered into **canonical** BFS/frame-key order
(``canonical_order``), the heart of the canonical-database contract
(docs/aggregation.md): database bytes become a pure function of the
profile set, independent of ``n_ranks`` / ``n_threads`` / path order —
which is what makes shard databases composable (``repro.core.merge``)
and the parallel shard driver byte-identical by construction
(``pipeline.driver``).
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cct import Frame, GPU_OP, tree_depths
from repro.core.pipeline.acquire import Acquisition
from repro.core.pipeline.contracts import UnifiedProfile, Unification
from repro.core.profmt import FRAME_KIND_IDX, ProfileData, read_profile

_GPU_OP_KIND = FRAME_KIND_IDX[GPU_OP]


# --------------------------------------------------------------------------
# Global tree under construction
# --------------------------------------------------------------------------
class GlobalTree:
    """Global CCT built by merging per-profile trees.

    Frames are interned into an integer id table (strings interned once,
    then a frame is a (kind, name id, module id, line) key), and children
    are resolved through a dict keyed by the packed integer
    ``(parent << 32) | frame_id`` — per-node tuple/Frame hashing is off the
    hot path entirely; ``merge_paths`` computes each profile's frame ids
    with array-level gathers over the profile's string table.
    """

    def __init__(self):
        self.frames: List[Frame] = [Frame("root", "<program root>")]
        self.parents: List[int] = [-1]
        self._children: Dict[int, int] = {}      # (parent<<32)|fid -> gid
        self._strings: Dict[str, int] = {}       # string intern table
        self._key_fids: Dict[Tuple[int, int, int, int], int] = {}
        self._frame_of_fid: List[Frame] = []     # fid -> canonical Frame
        self._frame_cache: Dict[Frame, int] = {}  # fast path for child()

    # -- interning ----------------------------------------------------------
    def _intern_string(self, s: str) -> int:
        i = self._strings.get(s)
        if i is None:
            i = len(self._strings)
            self._strings[s] = i
        return i

    def _fid_for_key(self, key: Tuple[int, int, int, int],
                     frame: Frame) -> int:
        fid = self._key_fids.get(key)
        if fid is None:
            fid = len(self._frame_of_fid)
            self._key_fids[key] = fid
            self._frame_of_fid.append(frame)
        return fid

    def intern_frame(self, frame: Frame) -> int:
        fid = self._frame_cache.get(frame)
        if fid is None:
            kind = FRAME_KIND_IDX.get(frame.kind)
            if kind is None:   # kinds outside the profile format's table
                kind = -2 - self._intern_string(frame.kind)
            key = (kind, self._intern_string(frame.name),
                   self._intern_string(frame.module), int(frame.line))
            fid = self._fid_for_key(key, frame)
            self._frame_cache[frame] = fid
        return fid

    # -- tree construction ---------------------------------------------------
    def _child_fid(self, parent: int, fid: int) -> int:
        key = (parent << 32) | fid
        gid = self._children.get(key)
        if gid is None:
            gid = len(self.frames)
            self.frames.append(self._frame_of_fid[fid])
            self.parents.append(parent)
            self._children[key] = gid
        return gid

    def child(self, parent: int, frame: Frame) -> int:
        return self._child_fid(parent, self.intern_frame(frame))

    def _profile_fids(self, prof: ProfileData) -> np.ndarray:
        """Per-node global frame ids, resolved with one dict lookup per
        *unique* frame (array-level dedup) instead of one per node."""
        if prof.frame_kinds is None:
            return np.fromiter((self.intern_frame(f) for f in prof.frames),
                               np.int64, len(prof.frames))
        gsid = np.fromiter((self._intern_string(s) for s in prof.strings),
                           np.int64, len(prof.strings)) \
            if prof.strings else np.zeros(0, np.int64)
        rows = np.stack([prof.frame_kinds,
                         gsid[prof.frame_name_sids],
                         gsid[prof.frame_mod_sids],
                         prof.frame_lines], axis=1)
        uniq, first, inv = np.unique(rows, axis=0, return_index=True,
                                     return_inverse=True)
        fids_u = np.empty(len(uniq), np.int64)
        for j in range(len(uniq)):
            r = uniq[j]
            fids_u[j] = self._fid_for_key(
                (int(r[0]), int(r[1]), int(r[2]), int(r[3])),
                prof.frames[int(first[j])])
        return fids_u[inv.ravel()]

    def merge_paths(self, prof: ProfileData,
                    expand=None) -> np.ndarray:
        """Insert one profile's tree; returns local node id -> global id."""
        n = len(prof.node_ids)
        local_to_global = np.zeros(int(prof.node_ids.max()) + 1 if n else 1,
                                   np.int64)
        fids = self._profile_fids(prof).tolist()
        node_ids = prof.node_ids.tolist()
        parents = prof.parents.tolist()
        is_gpu = (prof.frame_kinds == _GPU_OP_KIND).tolist() \
            if (expand is not None and prof.frame_kinds is not None) else None
        l2g = local_to_global.tolist()
        children = self._children
        frames_out, parents_out = self.frames, self.parents
        frame_of_fid = self._frame_of_fid
        # profiles store nodes in creation order: parents precede children
        for i in range(n):
            par = parents[i]
            if par < 0:
                l2g[node_ids[i]] = 0
                continue
            gpar = l2g[par]
            if expand is not None and (
                    is_gpu[i] if is_gpu is not None
                    else prof.frames[i].kind == GPU_OP):
                for f in expand(prof.frames[i], prof):
                    gpar = self.child(gpar, f)
                l2g[node_ids[i]] = gpar
                continue
            key = (gpar << 32) | fids[i]
            gid = children.get(key)
            if gid is None:
                gid = len(frames_out)
                frames_out.append(frame_of_fid[fids[i]])
                parents_out.append(gpar)
                children[key] = gid
            l2g[node_ids[i]] = gid
        local_to_global[:] = l2g
        return local_to_global

    def merge_tree(self, other: "GlobalTree") -> np.ndarray:
        """Merge another tree into this one (reduction-tree step),
        vectorized.

        Bitwise-identical to ``merge_tree_reference`` (pinned in
        tests/test_merge_tree_vector.py) by this argument: within one
        merge the children keys ``(mapped_parent << 32) | fid`` are
        globally unique (the mapping is injective by induction on
        depth), so whether a node hits an existing child or misses is
        independent of visit order, and any child of a missing parent
        must itself miss — its key's parent id is >= the pre-merge node
        count, which no existing key contains.  That lets the merge run
        as three batch phases instead of one dict transaction per node:

        A. classify hit/miss level-by-level (dict lookups only for
           nodes whose parent hit);
        B. number the misses ``base + rank`` in gid order — exactly the
           ids the sequential loop hands out;
        C. batch-append frames/parents and bulk-update the children
           index with the final ids.
        """
        n = len(other.frames)
        mapping = np.zeros(n, np.int64)
        if n <= 1:
            return mapping
        parents = np.asarray(other.parents, np.int64)
        # per-node global frame ids (index 0 unused: the roots align)
        fids = np.zeros(n, np.int64)
        frames = other.frames
        intern = self.intern_frame
        for gid in range(1, n):
            fids[gid] = intern(frames[gid])
        children = self._children
        depth = tree_depths(parents)
        is_miss = np.zeros(n, bool)
        for lvl in range(1, int(depth.max()) + 1):
            idx = np.nonzero(depth == lvl)[0]
            par_miss = is_miss[parents[idx]]
            is_miss[idx[par_miss]] = True       # miss parent -> miss child
            cand = idx[~par_miss]
            keys = ((mapping[parents[cand]] << 32) | fids[cand]).tolist()
            got = np.fromiter((children.get(k, -1) for k in keys),
                              np.int64, len(cand))
            hit = got >= 0
            mapping[cand[hit]] = got[hit]
            is_miss[cand[~hit]] = True
        miss = np.nonzero(is_miss)[0]           # gid order == visit order
        if len(miss):
            base = len(self.frames)
            mapping[miss] = base + np.arange(len(miss))
            new_parents = mapping[parents[miss]]
            fof = self._frame_of_fid
            self.frames.extend(fof[int(f)] for f in fids[miss])
            self.parents.extend(new_parents.tolist())
            children.update(zip(
                ((new_parents << 32) | fids[miss]).tolist(),
                mapping[miss].tolist()))
        return mapping

    def merge_tree_reference(self, other: "GlobalTree") -> np.ndarray:
        """The sequential merge loop ``merge_tree`` vectorizes; kept as
        the equivalence oracle (tests assert bitwise-equal trees and
        mappings between the two on randomized inputs)."""
        mapping = np.zeros(len(other.frames), np.int64)
        m = mapping.tolist()
        other_parents = other.parents
        for gid in range(1, len(other.frames)):
            m[gid] = self.child(m[other_parents[gid]], other.frames[gid])
        mapping[:] = m
        return mapping

    def topo_order(self) -> np.ndarray:
        return np.arange(len(self.frames))  # creation order is topological

    def depths(self) -> np.ndarray:
        """Per-node depth (root = 0), see ``cct.tree_depths``."""
        return tree_depths(self.parents)


# --------------------------------------------------------------------------
# Canonicalization: the database-bytes-are-a-pure-function contract
# --------------------------------------------------------------------------
def canonical_order(frames: List[Frame], parents) -> np.ndarray:
    """Old context id -> canonical id.

    Canonical numbering is a BFS of the tree with each node's children
    visited in sorted frame-key order ``(kind, name, module, line)`` —
    a pure function of the tree's *shape*, independent of the insertion
    order that built it.  Properties the pipeline relies on:

    - topological: a parent's canonical id precedes all its children's
      (so the reverse-id / level-order inclusive sweeps stay valid);
    - the relative order of any two children of one parent is decided by
      frame-key comparison alone, so it is identical in every tree that
      contains both — per-profile inclusive values come out bitwise
      identical whether a profile is aggregated inside a shard or inside
      the full union (the heart of the ``merge_databases`` byte-identity
      contract, docs/aggregation.md);
    - restriction-stable: dropping an ancestor-closed subset of nodes
      (retention, ``repro.core.retention``) and compressing ids
      preserves canonical order, because the numbering is lexicographic
      in (depth, parent id, frame key) and all three survive the
      restriction unchanged.
    """
    n = len(frames)
    parents = np.asarray(parents, np.int64)
    key_rank = {k: i for i, k in enumerate(sorted(
        {(f.kind, f.name, f.module, f.line) for f in frames}))}
    frank = np.fromiter(
        (key_rank[(f.kind, f.name, f.module, f.line)] for f in frames),
        np.int64, n)
    depth = tree_depths(parents)
    new_id = np.zeros(n, np.int64)
    done = 1                       # root keeps id 0
    for lvl in range(1, int(depth.max()) + 1 if n > 1 else 1):
        idx = np.nonzero(depth == lvl)[0]
        if len(idx) == 0:
            break
        order = np.lexsort((frank[idx], new_id[parents[idx]]))
        new_id[idx[order]] = np.arange(done, done + len(idx))
        done += len(idx)
    return new_id


def apply_order(frames: List[Frame], parents, new_id: np.ndarray
                ) -> Tuple[List[Frame], np.ndarray]:
    """Permute a (frames, parents) tree by an old->new id map."""
    parents = np.asarray(parents, np.int64)
    frames_c: List[Frame] = list(frames)
    for old, new in enumerate(new_id.tolist()):
        frames_c[new] = frames[old]
    parents_c = np.full(len(frames), -1, np.int64)
    has_par = parents >= 0
    parents_c[new_id[has_par]] = new_id[parents[has_par]]
    return frames_c, parents_c


# --------------------------------------------------------------------------
# The phase-2 stage
# --------------------------------------------------------------------------
def unify(acq: Acquisition, *, n_threads: int = 4,
          expand=None) -> Unification:
    """Unify every rank's profiles and canonicalize the global tree.

    Threads are the dynamic per-thread tasks inside a rank; rank trees
    fold into the root rank's tree (the hpcprof-mpi reduction step),
    and every profile's local->global map is composed with the rank
    conversion and the canonical renumbering, so downstream stages only
    ever see canonical ctx ids.
    """
    t0 = time.monotonic()

    def unify_rank(paths: Sequence[str]):
        tree = GlobalTree()
        profs: List[Tuple[str, ProfileData, np.ndarray]] = []

        def load(path):
            return path, read_profile(path)
        with ThreadPoolExecutor(max(1, n_threads)) as ex:
            loaded = list(ex.map(load, paths))
        for path, prof in loaded:
            mapping = tree.merge_paths(prof, expand)
            profs.append((path, prof, mapping))
        return tree, profs

    with ThreadPoolExecutor(max(1, len(acq.rank_paths))) as ex:
        rank_results = list(ex.map(unify_rank, acq.rank_paths))

    # reduction tree (arity = n_threads) to the root rank
    trees = [r[0] for r in rank_results]
    mappings: List[Optional[np.ndarray]] = [None] * len(trees)
    root = trees[0]
    for i in range(1, len(trees)):
        mappings[i] = root.merge_tree(trees[i])

    # canonical context renumbering: database ids are a pure function of
    # the profile set, independent of n_ranks / path order (merge contract)
    new_id = canonical_order(root.frames, root.parents)
    frames_c, parents_c = apply_order(root.frames, root.parents, new_id)

    # broadcast: convert each profile's local->rank mapping to ->canonical
    profiles: List[UnifiedProfile] = []
    for r, (tree, profs) in enumerate(rank_results):
        conv = mappings[r]
        for path, prof, mapping in profs:
            gmap = mapping if conv is None else conv[mapping]
            profiles.append(UnifiedProfile(path, prof, new_id[gmap]))

    return Unification(frames_c, parents_c, profiles,
                       unify_s=time.monotonic() - t0)
