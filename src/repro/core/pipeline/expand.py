"""Phase 3 — calling-context expansion (paper §6.1).

Flat GPU-op frames are expanded against hpcstruct-analogue structure
files (lines / loops / inlined scopes).  Profiles measured with runtime
expansion skip this (see profiler.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.cct import Frame
from repro.core.profmt import ProfileData
from repro.core.structure import HloModule


def make_expander(structures: Dict[str, HloModule]):
    """Returns expand(frame, prof) -> [Frame, ...] using structure files."""
    cache: Dict[Tuple[str, int], tuple] = {}

    def expand(frame: Frame, prof: ProfileData):
        mod = structures.get(frame.module)
        if mod is None:
            return (frame,)
        key = (frame.module, frame.line)   # line == op index for GPU_OP
        frames = cache.get(key)
        if frames is None:
            ops = mod.all_ops()
            if frame.line < len(ops):
                frames = tuple(mod.op_context(ops[frame.line]))
            else:
                frames = (frame,)
            cache[key] = frames
        return frames

    return expand
