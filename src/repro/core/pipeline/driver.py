"""The pluggable shard driver: serial / thread / process execution of
the aggregation pipeline (docs/pipeline.md).

The serial path runs the five stages inline — this *is* the classic
one-shot ``aggregate()``.  The parallel paths round-robin the profiles
into shards, run phases 1-4 per shard on an executor —
``ProcessPoolExecutor`` escapes the GIL for the Python-heavy
unification loop — fold the in-memory ``ShardResult``s through
``repro.core.merge.merge_databases``, and convert traces in-parent
against the final tree (composed ``remaps_out`` gmaps).
Because shard aggregation is canonical (pipeline.unify), the fold is
**byte-identical to the serial one-shot by construction** (the merge
contract, docs/aggregation.md; property-tested in
tests/test_merge_properties.py, benchmarked in
benchmarks/bench_pipeline.py).

Driver selection: the ``driver=`` / ``workers=`` arguments of
``aggregate()``, else the ``REPRO_AGG_DRIVER`` / ``REPRO_AGG_WORKERS``
environment (CI runs the tier-1 suite once with
``REPRO_AGG_DRIVER=process``), else serial.
"""
from __future__ import annotations

import os
import pickle
import time
import warnings
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pipeline.acquire import acquire
from repro.core.pipeline.contracts import ShardResult
from repro.core.pipeline.database import Database, write_database
from repro.core.pipeline.expand import make_expander
from repro.core.pipeline.stats import generate_stats
from repro.core.pipeline.traceconv import build_trace_db, convert_traces
from repro.core.pipeline.unify import unify
from repro.core.sparse import ProfileValues

ENV_DRIVER = "REPRO_AGG_DRIVER"
ENV_WORKERS = "REPRO_AGG_WORKERS"
DRIVERS = ("serial", "thread", "process")

# one cached process pool (keyed by its worker count): startup is paid
# once per interpreter, not once per aggregate() call, and requesting a
# different worker count retires the old pool so idle workers never
# accumulate across counts
_PROCESS_POOLS: Dict[int, ProcessPoolExecutor] = {}


def resolve_driver(driver: Optional[str],
                   workers: Optional[int]) -> Tuple[str, int]:
    """Explicit arguments beat the environment beats serial.  A worker
    count > 1 — from either source — implies the process driver unless
    a driver was named explicitly."""
    if workers is None:
        env_w = os.environ.get(ENV_WORKERS)
        workers = int(env_w) if env_w else None
    if driver is None:
        driver = os.environ.get(ENV_DRIVER) or None
    if driver is None:
        driver = "process" if (workers or 0) > 1 else "serial"
    if driver not in DRIVERS:
        raise ValueError(f"unknown aggregation driver {driver!r}; "
                         f"expected one of {DRIVERS}")
    if workers is None:
        workers = 4 if driver != "serial" else 1
    return driver, max(1, int(workers))


# --------------------------------------------------------------------------
# Serial path (the classic one-shot pipeline)
# --------------------------------------------------------------------------
def run_serial(profile_paths: Sequence[str], out_dir: str, *,
               n_ranks: int = 4, n_threads: int = 4,
               structures=None, trace_paths: Sequence[str] = (),
               trace_db: bool = True, trace_pyramid: bool = False,
               timing: Optional[dict] = None) -> Database:
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.monotonic()
    expand = make_expander(structures) if structures else None

    # phases 1-2(-3): acquisition, unification (+ expansion), canonical ids
    uni = unify(acquire(profile_paths, n_ranks), n_threads=n_threads,
                expand=expand)
    t_unify = time.monotonic() - t0

    # phase 4: statistic generation (parallel over profiles)
    entries = generate_stats(uni, n_workers=n_ranks * n_threads)
    t_stats = time.monotonic() - t0 - t_unify

    # phase 5: trace conversion (vectorized gather through gmap)
    gmaps = {up.path: up.gmap for up in uni.profiles}
    converted = convert_traces(trace_paths, gmaps, out_dir)
    if converted and trace_db:
        build_trace_db(converted, out_dir, pyramid=trace_pyramid,
                       parents=uni.parents)

    db = write_database(out_dir, uni.frames, uni.parents, uni.metrics,
                        entries, n_workers=n_ranks * n_threads,
                        t0=t0, timing_base={"unify_s": t_unify,
                                            "stats_s": t_stats})
    if timing is not None:
        _load_timing(out_dir, timing)
    return db


def _load_timing(out_dir: str, timing: dict) -> None:
    import json
    with open(os.path.join(out_dir, "meta.json")) as f:
        timing.update(json.load(f)["timing"])


# --------------------------------------------------------------------------
# Shard planning
# --------------------------------------------------------------------------
def plan_shards(profile_paths: Sequence[str],
                n_shards: int) -> List[List[str]]:
    """Round-robin the profiles over at most ``n_shards`` shards.

    *Any* partition folds to the same bytes (the merge contract,
    property-tested in tests/test_merge_properties.py), and phase 5 runs
    in-parent against the final tree, so traces never constrain the
    partition — even a GPU-stream trace whose dispatcher thread profiles
    land in different shards converts exactly as in the serial path.
    """
    shards: List[List[str]] = [[] for _ in range(max(1, n_shards))]
    for i, p in enumerate(profile_paths):
        shards[i % len(shards)].append(p)
    return [sh for sh in shards if sh]


# --------------------------------------------------------------------------
# Shard worker (top-level: picklable for ProcessPoolExecutor)
# --------------------------------------------------------------------------
def run_shard_stages(shard_paths: Sequence[str],
                     structures=None) -> ShardResult:
    """Phases 1-4 over one shard, entirely in memory: no trace work, no
    disk output — the fold (``merge_databases``) and the driver's final
    trace conversion consume the result."""
    t0 = time.monotonic()
    expand = make_expander(structures) if structures else None
    uni = unify(acquire(shard_paths, 1), n_threads=1, expand=expand)
    entries = generate_stats(uni, n_workers=1)
    identities: Dict[int, dict] = {}
    pvals: List[ProfileValues] = []
    coverage: Dict[int, np.ndarray] = {}
    for i, e in enumerate(entries):
        identities[i] = e.identity
        pvals.append(ProfileValues(i, e.ctx.astype(np.uint32),
                                   e.metric.astype(np.uint32), e.values))
        coverage[i] = e.coverage
    return ShardResult(uni.frames, np.asarray(uni.parents, np.int64),
                       uni.metrics, identities, pvals, coverage,
                       {up.path: up.gmap for up in uni.profiles},
                       unify_s=uni.unify_s,
                       stats_s=time.monotonic() - t0 - uni.unify_s)


def _process_pool(workers: int) -> ProcessPoolExecutor:
    ex = _PROCESS_POOLS.get(workers)
    if ex is None:
        for old in _PROCESS_POOLS.values():   # at most one pool alive
            old.shutdown(wait=False)
        _PROCESS_POOLS.clear()
        ex = ProcessPoolExecutor(max_workers=workers)
        _PROCESS_POOLS[workers] = ex
    return ex


# infrastructure failures the process driver degrades serially on: a
# dead/unusable pool, or arguments the executor cannot pickle across
# the pipe.  Deterministic task errors (a corrupt profile file, say)
# propagate unchanged — re-running them serially would only hit the
# same error again, slower.
_POOL_ERRORS = (BrokenProcessPool, pickle.PicklingError, TypeError,
                AttributeError)


def _execute_shards(driver: str, workers: int,
                    tasks: List[Sequence[str]],
                    structures) -> List[ShardResult]:
    if driver == "thread":
        with ThreadPoolExecutor(workers) as ex:
            return list(ex.map(lambda t: run_shard_stages(t, structures),
                               tasks))
    try:
        ex = _process_pool(workers)
        futs = [ex.submit(run_shard_stages, t, structures) for t in tasks]
        return [f.result() for f in futs]
    except _POOL_ERRORS as e:
        _PROCESS_POOLS.pop(workers, None)
        warnings.warn(
            f"process shard driver failed ({type(e).__name__}: {e}); "
            "retrying the shards serially — output is unaffected (the "
            "fold is byte-identical by construction)", RuntimeWarning)
        return [run_shard_stages(t, structures) for t in tasks]


# --------------------------------------------------------------------------
# The driver
# --------------------------------------------------------------------------
def run(profile_paths: Sequence[str], out_dir: str, *,
        n_ranks: int = 4, n_threads: int = 4, structures=None,
        trace_paths: Sequence[str] = (), trace_db: bool = True,
        trace_pyramid: bool = False,
        timing: Optional[dict] = None, workers: Optional[int] = None,
        driver: Optional[str] = None) -> Database:
    """Aggregate ``profile_paths`` into ``out_dir`` under the selected
    driver.  All drivers produce byte-identical databases; the parallel
    ones are faster once shard work dominates the fold (>= ~16 profiles
    on this container, benchmarks/bench_pipeline.py)."""
    driver, workers = resolve_driver(driver, workers)
    profile_paths = list(profile_paths)
    trace_paths = list(trace_paths)
    serial_kw = dict(n_ranks=n_ranks, n_threads=n_threads,
                     structures=structures, trace_paths=trace_paths,
                     trace_db=trace_db, trace_pyramid=trace_pyramid,
                     timing=timing)
    if driver == "serial" or workers <= 1 or len(profile_paths) < 2:
        return run_serial(profile_paths, out_dir, **serial_kw)

    shards = plan_shards(profile_paths, workers)
    if len(shards) < 2:
        return run_serial(profile_paths, out_dir, **serial_kw)

    from repro.core.merge import merge_databases

    t0 = time.monotonic()
    results = _execute_shards(driver, workers, shards, structures)
    t_shards = time.monotonic() - t0

    # the fold: byte-identical to one-shot over the union (merge contract)
    remaps: List[np.ndarray] = []
    db = merge_databases(results, out_dir, n_workers=n_ranks * n_threads,
                         trace_db=False, remaps_out=remaps)

    # phase 5 runs in-parent against the *final* canonical tree: compose
    # each profile's local->shard map with its shard's ->final remap, so
    # converted traces (and trace.db) match the serial path byte for byte
    gmaps: Dict[str, np.ndarray] = {}
    for res, remap in zip(results, remaps):
        for path, g in res.gmaps.items():
            gmaps[path] = remap[g]
    converted = convert_traces(trace_paths, gmaps, out_dir)
    if converted and trace_db:
        build_trace_db(converted, out_dir, pyramid=trace_pyramid,
                       parents=db.parents)

    if timing is not None:
        _load_timing(out_dir, timing)
        timing.update({"driver": driver, "workers": workers,
                       "n_shards": len(results), "shard_wall_s": t_shards,
                       "fold_s": time.monotonic() - t0 - t_shards})
    return db
