"""Phase 1 — input acquisition (paper §6.1).

Profile files are listed and distributed evenly across ranks
(round-robin), then processed as dynamic per-thread tasks inside a rank
(``pipeline.unify``).  Also home to the measurement-directory expansion
the ``python -m repro.core.aggregate`` CLI uses.
"""
from __future__ import annotations

import dataclasses
import glob
import os
from typing import List, Sequence, Tuple


@dataclasses.dataclass
class Acquisition:
    """Phase-1 contract: per-rank work lists (round-robin by input
    order, the paper's static distribution before dynamic tasking)."""
    rank_paths: List[List[str]]

    @property
    def n_profiles(self) -> int:
        return sum(len(r) for r in self.rank_paths)


def acquire(profile_paths: Sequence[str], n_ranks: int) -> Acquisition:
    ranks: List[List[str]] = [[] for _ in range(max(1, n_ranks))]
    for i, p in enumerate(profile_paths):
        ranks[i % len(ranks)].append(p)
    return Acquisition(ranks)


def expand_inputs(inputs: Sequence[str]
                  ) -> Tuple[List[str], List[str]]:
    """CLI input acquisition: expand measurement directories into their
    ``*.rpro`` profiles and ``*.rtrc`` traces; pass files through.
    Returns ``(profile_paths, trace_paths)``, each in sorted order."""
    profiles: List[str] = []
    traces: List[str] = []
    for src in inputs:
        if os.path.isdir(src):
            profiles += sorted(glob.glob(os.path.join(src, "*.rpro")))
            traces += sorted(glob.glob(os.path.join(src, "*.rtrc")))
        elif src.endswith(".rtrc"):
            traces.append(src)
        else:
            profiles.append(src)
    return profiles, traces
