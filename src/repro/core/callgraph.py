"""Approximate GPU calling-context-tree reconstruction (paper §6.3, Fig. 5).

Given flat per-function sample counts and a static call graph, reconstruct
an approximate calling context tree:

1. build the static call graph; initialize call-edge weights with exact
   call-instruction counts or call-instruction sample counts;
2. for sample-based graphs: if a function has samples but no incoming edge
   has non-zero weight, assign each incoming edge weight one; propagate
   through callers until every sampled function is reachable;
3. collapse strongly-connected components (Tarjan) into SCC nodes: external
   calls into the SCC link to the SCC node, intra-SCC edges are removed;
4. split the call graph into a tree Gprof-style: apportion each function's
   samples among its call sites by the ratio of each site's call weight to
   the total.

The algorithm is measurement-source agnostic — HPCToolkit applies it to
CUDA device functions; we apply it to HLO computations (fusion/call/while
edges) and to any explicitly-provided graph (tests use the paper's Fig. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class CallGraph:
    nodes: List[str]
    edges: Dict[Tuple[str, str], float]          # (caller, callee) -> weight
    samples: Dict[str, float]                    # node -> flat sample count

    def preds(self, n: str) -> List[Tuple[str, float]]:
        return [(a, w) for (a, b), w in self.edges.items() if b == n]

    def succs(self, n: str) -> List[Tuple[str, float]]:
        return [(b, w) for (a, b), w in self.edges.items() if a == n]


@dataclasses.dataclass
class CCTOut:
    """Reconstructed tree node."""
    name: str                 # function or "SCC{...}"
    cost: float
    children: List["CCTOut"]
    members: Tuple[str, ...] = ()   # for SCC nodes

    def total(self) -> float:
        out = 0.0
        stack = [self]
        while stack:
            n = stack.pop()
            out += n.cost
            stack.extend(n.children)
        return out

    def find(self, name: str) -> Optional["CCTOut"]:
        stack = [self]
        while stack:
            n = stack.pop()
            if n.name == name:
                return n
            stack.extend(n.children)
        return None


def _tarjan_scc(nodes: Sequence[str],
                edges: Dict[Tuple[str, str], float]) -> List[List[str]]:
    """Iterative Tarjan SCC (recursion-free for deep graphs)."""
    succ: Dict[str, List[str]] = {n: [] for n in nodes}
    for (a, b) in edges:
        if a in succ and b in succ:
            succ[a].append(b)
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Dict[str, bool] = {}
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work = [(root, iter(succ[root]))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack[root] = True
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack[w] = True
                    work.append((w, iter(succ[w])))
                    advanced = True
                    break
                elif on_stack.get(w):
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp.append(w)
                    if w == v:
                        break
                sccs.append(comp)
    return sccs


def _propagate_sample_edges(g: CallGraph) -> CallGraph:
    """Step 2: ensure every sampled function has a non-zero inbound path."""
    edges = dict(g.edges)
    changed = True
    rounds = 0
    while changed and rounds <= len(g.nodes) + 1:
        changed = False
        rounds += 1
        # a node "needs support" if it has samples or outgoing weight but
        # no inbound weight (and has at least one potential caller)
        for n in g.nodes:
            has_act = g.samples.get(n, 0) > 0 or any(
                w > 0 for (a, _), w in edges.items() if a == n)
            if not has_act:
                continue
            preds = [(a, b) for (a, b) in edges if b == n]
            if not preds:
                continue
            if all(edges[e] == 0 for e in preds):
                for e in preds:
                    edges[e] = 1.0
                changed = True
    return CallGraph(g.nodes, edges, g.samples)


def reconstruct(g: CallGraph, roots: Optional[Sequence[str]] = None,
                sample_based: bool = True, max_depth: int = 64) -> CCTOut:
    """Run steps 1-4; returns a synthetic root whose children are the
    reconstruction roots (functions with no callers)."""
    if sample_based:
        g = _propagate_sample_edges(g)

    # --- step 3: SCC collapse ---------------------------------------------
    sccs = _tarjan_scc(g.nodes, {e: w for e, w in g.edges.items() if w > 0})
    rep: Dict[str, str] = {}
    members: Dict[str, Tuple[str, ...]] = {}
    for comp in sccs:
        if len(comp) == 1:
            n = comp[0]
            # self-loop -> still an SCC node per the paper's Fig. 5
            if g.edges.get((n, n), 0) > 0:
                name = f"SCC{{{n}}}"
                rep[n] = name
                members[name] = (n,)
            else:
                rep[n] = n
        else:
            name = "SCC{" + ",".join(sorted(comp)) + "}"
            for n in comp:
                rep[n] = name
            members[name] = tuple(sorted(comp))

    cnodes: List[str] = sorted({rep[n] for n in g.nodes})
    cedges: Dict[Tuple[str, str], float] = {}
    csamples: Dict[str, float] = {}
    for n, s in g.samples.items():
        csamples[rep[n]] = csamples.get(rep[n], 0.0) + s
    for (a, b), w in g.edges.items():
        ra, rb = rep[a], rep[b]
        if ra == rb:
            continue  # intra-SCC edge removed
        cedges[(ra, rb)] = cedges.get((ra, rb), 0.0) + w

    # --- step 4: split into a tree with Gprof apportioning ------------------
    if roots is None:
        has_pred = {b for (a, b), w in cedges.items() if w > 0}
        roots = [n for n in cnodes if n not in has_pred] or cnodes[:1]
    roots = [rep.get(r, r) for r in roots]

    # precompute inbound totals and outbound adjacency once
    total_in: Dict[str, float] = {}
    succs: Dict[str, List[Tuple[str, float]]] = {}
    for (a, b), w in cedges.items():
        if w > 0:
            total_in[b] = total_in.get(b, 0.0) + w
            succs.setdefault(a, []).append((b, w))

    def build(start: str) -> CCTOut:
        """Iterative DFS (deep scan chains overflow Python recursion)."""
        root = CCTOut(start, csamples.get(start, 0.0), [],
                      members.get(start, ()))
        stack = [(root, 1.0, 0, frozenset({start}))]
        while stack:
            node, fraction, depth, seen = stack.pop()
            if depth >= max_depth:
                continue
            for b, w in succs.get(node.name, []):
                if b in seen:
                    continue
                frac = fraction * (w / total_in[b])
                child = CCTOut(b, csamples.get(b, 0.0) * frac, [],
                               members.get(b, ()))
                node.children.append(child)
                stack.append((child, frac, depth + 1, seen | {b}))
        return root

    root = CCTOut("<gpu root>", 0.0, [])
    for r in roots:
        root.children.append(build(r))
    return root
