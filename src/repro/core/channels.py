"""Wait-free single-producer/single-consumer queues and bidirectional
channels (paper §4.1).

The paper coordinates application threads, a GPU monitor thread, and tracing
threads exclusively through *bidirectional channels*, each a pair of
wait-free SPSC queues — deliberately avoiding multi-producer queues (the
OpenCL/Level-Zero discussion in §4.1 exists precisely to preserve the
single-producer invariant).

Wait-freedom here: ``try_push`` and ``try_pop`` complete in a bounded number
of steps regardless of what the peer thread does — there are no locks, no
CAS retry loops, and no blocking.  The producer writes only ``_tail`` and
the slot it owns; the consumer writes only ``_head`` and clears the slot it
owns.  In CPython the GIL guarantees that the int stores publish with the
required ordering (slot write happens-before tail increment in program
order, and bytecode boundaries act as full fences); in C this would be a
release store on tail / acquire load on head, exactly as in [34].
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import numpy as np

_EMPTY = object()


class SpscQueue:
    """Bounded wait-free SPSC ring queue."""

    __slots__ = ("_slots", "_capacity", "_head", "_tail",
                 "push_failures", "pushes", "pops")

    def __init__(self, capacity: int = 4096):
        assert capacity > 0
        self._slots: List[Any] = [None] * capacity
        self._capacity = capacity
        self._head = 0  # written only by the consumer
        self._tail = 0  # written only by the producer
        self.push_failures = 0
        self.pushes = 0
        self.pops = 0

    def try_push(self, item: Any) -> bool:
        """Producer-only.  Returns False when full (never blocks)."""
        tail = self._tail
        if tail - self._head >= self._capacity:
            self.push_failures += 1
            return False
        self._slots[tail % self._capacity] = item  # write slot ...
        self._tail = tail + 1                      # ... then publish
        self.pushes += 1
        return True

    def try_pop(self) -> Any:
        """Consumer-only.  Returns ``EMPTY`` when no item is ready."""
        head = self._head
        if head >= self._tail:
            return _EMPTY
        slot = head % self._capacity
        item = self._slots[slot]
        self._slots[slot] = None                   # release reference ...
        self._head = head + 1                      # ... then consume
        self.pops += 1
        return item

    def try_push_many(self, items: Sequence[Any]) -> int:
        """Producer-only batch push.  Returns how many items were accepted
        (0 when full; may be fewer than ``len(items)``).

        All accepted slots are written first and ``_tail`` is published
        once for the whole batch, so the wait-free SPSC invariant is
        unchanged while the per-item call overhead is paid once per batch.
        The consumer may concurrently advance ``_head``; the availability
        snapshot taken here is then a lower bound, which is safe.
        """
        if not items:
            return 0
        tail = self._tail
        avail = self._capacity - (tail - self._head)
        n = len(items) if avail >= len(items) else max(avail, 0)
        if n <= 0:
            self.push_failures += 1
            return 0
        slots, cap = self._slots, self._capacity
        for k in range(n):
            slots[(tail + k) % cap] = items[k]   # write slots ...
        self._tail = tail + n                    # ... then publish once
        self.pushes += n
        if n < len(items):
            self.push_failures += 1
        return n

    def try_pop_many(self, limit: Optional[int] = None) -> List[Any]:
        """Consumer-only batch pop.  Returns up to ``limit`` ready items
        (empty list when none).  ``_head`` is published once per batch."""
        head = self._head
        n = self._tail - head
        if limit is not None and n > limit:
            n = limit
        if n <= 0:
            return []
        slots, cap = self._slots, self._capacity
        out = [None] * n
        for k in range(n):
            i = (head + k) % cap
            out[k] = slots[i]
            slots[i] = None                      # release references ...
        self._head = head + n                    # ... then consume once
        self.pops += n
        return out

    def drain(self, limit: Optional[int] = None) -> Iterator[Any]:
        """Consumer-only: pop until empty (or ``limit`` items)."""
        count = itertools.count() if limit is None else iter(range(limit))
        for _ in count:
            item = self.try_pop()
            if item is _EMPTY:
                return
            yield item

    def __len__(self) -> int:  # approximate (racy but monotonic-safe)
        return max(0, self._tail - self._head)

    @property
    def empty(self) -> bool:
        return self._head >= self._tail


EMPTY = _EMPTY


class BidirectionalChannel:
    """A pair of SPSC queues between exactly two threads (paper Fig. 2).

    ``forward`` carries operation tuples (I, P, C_A) from an application
    thread to the monitor thread; ``backward`` is the *activity channel*
    carrying (A, P) pairs back.
    """

    def __init__(self, capacity: int = 4096):
        self.forward = SpscQueue(capacity)   # app -> monitor ("operation")
        self.backward = SpscQueue(capacity)  # monitor -> app ("activity")

    # convenience aliases matching the paper's terminology
    @property
    def operation(self) -> SpscQueue:
        return self.forward

    @property
    def activity(self) -> SpscQueue:
        return self.backward


class ChannelSet:
    """Registry of per-thread channels owned by the monitor thread.

    Registration itself is the only locked operation (it happens once per
    thread, off the hot path); all steady-state communication is wait-free.
    """

    def __init__(self, capacity: int = 4096):
        self._lock = threading.Lock()
        self._channels: dict = {}
        self._capacity = capacity

    def channel_for(self, thread_id) -> BidirectionalChannel:
        ch = self._channels.get(thread_id)
        if ch is None:
            with self._lock:
                ch = self._channels.get(thread_id)
                if ch is None:
                    ch = BidirectionalChannel(self._capacity)
                    self._channels[thread_id] = ch
        return ch

    def items(self):
        # dict iteration is safe w.r.t. concurrent inserts under the GIL;
        # take a snapshot to be explicit.
        return list(self._channels.items())


class RecordRing:
    """Per-thread wait-free record ring for the dispatch hot path.

    One application thread is the only producer; the monitor thread is
    the only consumer.  Compared to ``SpscQueue`` the ring is tuned for
    the profiler's record traffic:

    - the producer appends one payload tuple per record with a **single
      release-store of the write cursor** (slot write, then
      ``_tail = tail + 1``; under the GIL the int store publishes with
      the required ordering, in C it would be a release store);
    - timed records additionally carry a ``(t_start, t_end, ctx)``
      triple in a numpy-backed **trace lane** alongside the slot, so
      the consumer can lift a whole drain batch of trace events with
      one vectorized gather instead of re-packing Python tuples;
    - the consumer reads in **epoch-stamped batches**
      (``read_batch``): one cursor snapshot, one gather, one
      ``_head`` publish per batch — per-thread FIFO order preserved.

    ``try_append*`` never blocks: a full ring returns False and counts
    ``full_waits`` (the producer decides whether to retry; the profiler
    yields the GIL so the consumer can drain).
    """

    __slots__ = ("_slots", "_lane", "_capacity", "_head", "_tail",
                 "appends", "reads", "epoch", "full_waits")

    LANE_COLS = 3          # (t_start, t_end, ctx) int64 columns

    def __init__(self, capacity: int = 1 << 15):
        assert capacity > 0
        self._slots: List[Any] = [None] * capacity
        self._lane = np.zeros((capacity, self.LANE_COLS), np.int64)
        self._capacity = capacity
        self._head = 0          # written only by the consumer
        self._tail = 0          # written only by the producer
        self.appends = 0
        self.reads = 0
        self.epoch = 0          # one per consumed batch
        self.full_waits = 0

    # -- producer side ------------------------------------------------------
    def try_append(self, payload: Any) -> bool:
        """Append an untimed record (no trace-lane row).  Returns False
        when full (never blocks)."""
        tail = self._tail
        if tail - self._head >= self._capacity:
            self.full_waits += 1
            return False
        self._slots[tail % self._capacity] = payload   # write slot ...
        self._tail = tail + 1                          # ... publish once
        self.appends += 1
        return True

    def try_append_timed(self, payload: Any, t_start: int, t_end: int,
                         ctx: int) -> bool:
        """Append a record with a trace-lane row riding along (the
        batched-trace path: the consumer gathers lane rows per drain)."""
        tail = self._tail
        if tail - self._head >= self._capacity:
            self.full_waits += 1
            return False
        i = tail % self._capacity
        lane = self._lane
        lane[i, 0] = t_start
        lane[i, 1] = t_end
        lane[i, 2] = ctx
        self._slots[i] = payload                       # write slot ...
        self._tail = tail + 1                          # ... publish once
        self.appends += 1
        return True

    # -- consumer side ------------------------------------------------------
    def read_batch(self, limit: int = 1024
                   ) -> Optional[Tuple[List[Any], "np.ndarray", int]]:
        """Consume up to ``limit`` records: returns
        ``(payloads, lane_rows, epoch)`` or None when empty.
        ``lane_rows`` is an owned (n, 3) int64 copy aligned with
        ``payloads`` (rows of untimed records are stale and must be
        selected by payload tag).  ``_head`` is published once."""
        head = self._head
        n = self._tail - head
        if n > limit:
            n = limit
        if n <= 0:
            return None
        cap = self._capacity
        idx = np.arange(head, head + n) % cap
        lane_rows = self._lane[idx]                    # gather (a copy)
        slots = self._slots
        ii = idx.tolist()
        payloads = [slots[i] for i in ii]
        for i in ii:
            slots[i] = None                            # release refs ...
        self._head = head + n                          # ... publish once
        self.reads += n
        self.epoch += 1
        return payloads, lane_rows, self.epoch

    def __len__(self) -> int:  # approximate (racy but monotonic-safe)
        return max(0, self._tail - self._head)

    @property
    def empty(self) -> bool:
        return self._head >= self._tail


class RingSet:
    """Registry of per-thread record rings, drained by the monitor.

    Registration is the only locked operation (once per thread, off the
    hot path).  ``items()`` yields rings in registration order — a
    deterministic per-process drain order (attribution order within a
    thread is the ring's FIFO order either way)."""

    def __init__(self, capacity: int = 1 << 15):
        self._lock = threading.Lock()
        self._rings: dict = {}
        self._capacity = capacity

    def ring_for(self, thread_id) -> RecordRing:
        r = self._rings.get(thread_id)
        if r is None:
            with self._lock:
                r = self._rings.get(thread_id)
                if r is None:
                    r = RecordRing(self._capacity)
                    self._rings[thread_id] = r
        return r

    def items(self):
        return list(self._rings.items())
