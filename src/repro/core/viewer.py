"""Text-mode hpcviewer (paper §7): profile views (top-down / bottom-up /
flat), thread-centric plots (as columns), and the trace Statistic tab.

The GUI renders a database; we render the same content as aligned text so
tests and examples can assert on it.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.aggregate import Database
from repro.core.trace import TraceData


def _fmt(v: float) -> str:
    if v == 0:
        return "."
    if abs(v) >= 1e6 or 0 < abs(v) < 1e-2:
        return f"{v:.3e}"
    return f"{v:,.2f}"


def top_down(db: Database, metric: str, *, stat: str = "sum",
             max_depth: int = 8, min_frac: float = 0.01,
             max_children: int = 8) -> str:
    """Costs in full calling context (inclusive metrics)."""
    mid = db.metric_id(metric)
    col = db.stats[stat][:, mid]
    total = col[0] if col[0] else max(col.max(), 1e-30)
    kids: Dict[int, List[int]] = {}
    for gid, par in enumerate(db.parents):
        if par >= 0:
            kids.setdefault(int(par), []).append(gid)
    lines = [f"TOP-DOWN  metric={metric} [{stat}]  total={_fmt(total)}"]

    def rec(gid: int, depth: int):
        if depth > max_depth:
            return
        cs = sorted(kids.get(gid, []), key=lambda c: -col[c])
        shown = 0
        for c in cs:
            if col[c] / total < min_frac or shown >= max_children:
                break
            shown += 1
            lines.append("  " * depth
                         + f"{col[c] / total * 100:5.1f}% {_fmt(col[c]):>12} "
                         + db.frames[c].pretty())
            rec(c, depth + 1)

    rec(0, 0)
    return "\n".join(lines)


def _exclusive(db: Database, col: np.ndarray) -> np.ndarray:
    """Inclusive -> exclusive: subtract children sums."""
    ex = col.copy()
    for gid, par in enumerate(db.parents):
        if par >= 0:
            ex[par] -= col[gid]
    return np.maximum(ex, 0.0)


def flat(db: Database, metric: str, *, stat: str = "sum",
         top: int = 15) -> str:
    """Aggregate costs by frame, independent of calling context."""
    mid = db.metric_id(metric)
    ex = _exclusive(db, db.stats[stat][:, mid])
    agg: Dict[str, float] = {}
    for gid, f in enumerate(db.frames):
        agg[f.pretty()] = agg.get(f.pretty(), 0.0) + ex[gid]
    rows = sorted(agg.items(), key=lambda kv: -kv[1])[:top]
    total = sum(agg.values()) or 1.0
    lines = [f"FLAT  metric={metric} [{stat}]"]
    for name, v in rows:
        if v <= 0:
            continue
        lines.append(f"{v / total * 100:5.1f}% {_fmt(v):>12}  {name}")
    return "\n".join(lines)


def bottom_up(db: Database, metric: str, *, stat: str = "sum",
              top: int = 10, caller_depth: int = 3) -> str:
    """Apportion each frame's exclusive cost to its callers."""
    mid = db.metric_id(metric)
    ex = _exclusive(db, db.stats[stat][:, mid])
    by_frame: Dict[str, Dict[Tuple[str, ...], float]] = {}
    for gid in range(1, len(db.frames)):
        v = ex[gid]
        if v <= 0:
            continue
        name = db.frames[gid].pretty()
        chain = []
        p = int(db.parents[gid])
        while p > 0 and len(chain) < caller_depth:
            chain.append(db.frames[p].pretty())
            p = int(db.parents[p])
        by_frame.setdefault(name, {})
        key = tuple(chain)
        by_frame[name][key] = by_frame[name].get(key, 0.0) + v
    totals = sorted(((sum(c.values()), n) for n, c in by_frame.items()),
                    reverse=True)[:top]
    lines = [f"BOTTOM-UP  metric={metric} [{stat}]"]
    for v, name in totals:
        lines.append(f"{_fmt(v):>12}  {name}")
        for chain, cv in sorted(by_frame[name].items(),
                                key=lambda kv: -kv[1])[:4]:
            lines.append("              <- " + " <- ".join(chain) if chain
                         else "              <- (root)")
    return "\n".join(lines)


def counter_table(db: Database, *, stat: str = "sum", top: int = 10,
                  by: str = "gpu_kernel/time_ns") -> str:
    """Per-kernel hardware-counter table (paper §6; repro.counters): one
    row per GPU-kernel placeholder context, raw counter columns plus the
    derived occupancy / efficiency columns of ``core.derived``."""
    from repro.core.derived import (ACHIEVED_OCCUPANCY, BYTES_PER_FLOP,
                                    FLOP_EFFICIENCY, REPLAY_PASS_COUNT,
                                    database_columns)
    cols = database_columns(db, stat)
    if "gpu_counter/elapsed_ns" not in cols:
        return "COUNTERS  (no gpu_counter kind in this database)"
    rows = [g for g, f in enumerate(db.frames)
            if f.kind == "placeholder" and f.name.startswith("kernel:")
            and cols["gpu_kernel/invocations"][g] > 0]
    rows.sort(key=lambda g: -cols[by][g])
    rows = rows[:top]
    derived = {
        "occupancy": ACHIEVED_OCCUPANCY.evaluate(cols),
        "flop_eff": FLOP_EFFICIENCY.evaluate(cols),
        "bytes/flop": BYTES_PER_FLOP.evaluate(cols),
        "passes": REPLAY_PASS_COUNT.evaluate(cols),
    }
    header = ["kernel", "invocs", "time_ns", "flops", "hbm_bytes",
              "occupancy", "flop_eff", "bytes/flop", "passes"]
    table = [[db.frames[g].pretty(),
              _fmt(cols["gpu_kernel/invocations"][g]),
              _fmt(cols["gpu_kernel/time_ns"][g]),
              _fmt(cols["gpu_counter/flops"][g]),
              _fmt(cols["gpu_counter/hbm_bytes"][g]),
              f"{derived['occupancy'][g]:.3f}",
              f"{derived['flop_eff'][g]:.3e}",
              f"{derived['bytes/flop'][g]:.3f}",
              f"{derived['passes'][g]:.1f}"] for g in rows]
    widths = [max(len(header[i]), *(len(r[i]) for r in table)) if table
              else len(header[i]) for i in range(len(header))]
    lines = [f"COUNTERS  [{stat}]  ({len(rows)} kernel context(s))",
             "  ".join(h.ljust(widths[i]) for i, h in enumerate(header))]
    for r in table:
        lines.append("  ".join(v.ljust(widths[i]) for i, v in enumerate(r)))
    return "\n".join(lines)


def top_hot_loops(db: Database, *, stat: str = "sum", top: int = 15) -> str:
    """Kernel-interior hot-spot table (paper §7 PC sampling inside GPU
    binaries; repro.core.kstruct): kernel -> loop -> source line with
    the stall-class breakdown.

    Interior contexts are found *structurally*: a GPU_FUNC frame whose
    parent is a GPU_OP frame is a kstruct kernel root (the HLO structure
    path never hangs children under GPU_OP), so no new frame kind — and
    no file-format change — is needed."""
    from repro.core.cct import GPU_FUNC, GPU_LOOP, GPU_OP
    try:
        cols = {m: db.stats[stat][:, db.metric_id(f"gpu_inst/{m}")]
                for m in ("samples", "stall_compute", "stall_memory",
                          "stall_collective")}
    except (KeyError, ValueError):
        return "HOT LOOPS  (no gpu_inst kind in this database)"
    kids: Dict[int, List[int]] = {}
    for gid, par in enumerate(db.parents):
        if par >= 0:
            kids.setdefault(int(par), []).append(gid)
    roots = [g for g, f in enumerate(db.frames)
             if f.kind == GPU_FUNC and db.parents[g] >= 0
             and db.frames[int(db.parents[g])].kind == GPU_OP]
    rows: Dict[tuple, List[float]] = {}
    for r in roots:
        kernel = db.frames[r].name
        stack = [(c, "-") for c in kids.get(r, [])]
        while stack:
            g, loop = stack.pop()
            f = db.frames[g]
            if f.kind == GPU_LOOP:
                loop = f.name
            if f.kind == GPU_OP:
                key = (kernel, loop, f"{f.module}:{f.line}", f.name)
                acc = rows.setdefault(key, [0.0, 0.0, 0.0, 0.0])
                acc[0] += cols["samples"][g]
                acc[1] += cols["stall_compute"][g]
                acc[2] += cols["stall_memory"][g]
                acc[3] += cols["stall_collective"][g]
            stack.extend((c, loop) for c in kids.get(g, []))
    ordered = sorted(rows.items(), key=lambda kv: (-kv[1][0], kv[0]))[:top]
    total = sum(v[0] for v in rows.values()) or 1.0
    header = ["kernel", "loop", "line", "op", "samples", "%",
              "compute", "memory", "collective"]
    table = [[k[0], k[1], k[2], k[3], _fmt(v[0]),
              f"{v[0] / total * 100:.1f}",
              _fmt(v[1]), _fmt(v[2]), _fmt(v[3])]
             for k, v in ordered]
    widths = [max(len(header[i]), *(len(r[i]) for r in table)) if table
              else len(header[i]) for i in range(len(header))]
    lines = [f"HOT LOOPS  [{stat}]  ({len(roots)} kernel context(s), "
             f"{len(rows)} interior line(s))",
             "  ".join(h.ljust(widths[i]) for i, h in enumerate(header))]
    for r in table:
        lines.append("  ".join(v.ljust(widths[i]) for i, v in enumerate(r)))
    return "\n".join(lines)


def thread_plot(db: Database, cms_reader, ctx: int, metric: str,
                ) -> Tuple[np.ndarray, np.ndarray]:
    """(profile ids, values) for one CCT node across profiles — the
    thread-centric view (plot of a metric for a selected node)."""
    return cms_reader.metric_values(ctx, db.metric_id(metric))


def trace_statistic(traces: Sequence[TraceData], db: Database,
                    depth: int = 2, top: int = 10) -> List[Tuple[str, float]]:
    """The trace-view Statistic tab: fraction of total trace area occupied
    by each routine at the given call-stack depth."""
    area: Dict[str, float] = {}
    total = 0.0
    for tr in traces:
        for s, e, c in zip(tr.starts, tr.ends, tr.ctx):
            dur = float(e - s)
            total += dur
            # walk up to requested depth
            gid = int(c)
            chain = []
            while gid > 0 and gid < len(db.frames):
                chain.append(gid)
                gid = int(db.parents[gid])
            pick = chain[-depth] if len(chain) >= depth else chain[0] \
                if chain else 0
            name = db.frames[pick].pretty()
            area[name] = area.get(name, 0.0) + dur
    rows = sorted(area.items(), key=lambda kv: -kv[1])[:top]
    return [(n, v / total if total else 0.0) for n, v in rows]
