"""Streaming aggregation — the ``hpcprof`` / ``hpcprof-mpi`` analogue
(paper §6.1).

Pipeline phases, exactly as the paper stages them:

1. **Input acquisition** — profile files are listed and distributed evenly
   across ranks (round-robin), then processed as dynamic per-thread tasks.
2. **Call-path unification** — each rank unifies its profiles' CCTs into a
   rank-local tree; rank trees merge up a reduction tree of arity ``t``
   (the per-rank thread count) to the root, yielding the global calling
   context tree and a local->global id mapping per profile.
3. **Calling-context expansion** — flat GPU-op frames are expanded against
   hpcstruct-analogue structure files (lines / loops / inlined scopes).
   (Profiles measured with runtime expansion skip this, see profiler.py.)
4. **Statistic generation** — per profile, metric values are scatter-added
   into a sparse (ctx, metric) COO set and propagated up the tree with a
   vectorized level-order sweep (one grouped ``np.add.at`` per tree level,
   deepest first); workers share *nothing* — per-profile partial
   accumulators are folded once at the end, in profile order, so the
   result is deterministic and lock-free (the paper's communication-free
   workers after exscan).  Per-profile values stream into the PMS/CMS
   writers.
5. **Trace + final outputs** — trace files are rewritten in terms of global
   ctx ids (vectorized gather + bulk ``TraceWriter.append_many``) and
   merged into one seekable ``trace.db`` (repro.traceview); tree, stats,
   and sparse cubes land in the database directory.

"Ranks" are worker threads here (single-host container): the reduction
tree, exscan offset computation, and nnz-balanced work splitting are the
same algorithms hpcprof-mpi runs over MPI; docs/aggregation.md discusses
the honesty of this mapping, the GIL caveats, and the bit-exactness
contract (the vectorized path reproduces the reference implementation's
floating-point addition order, so databases are byte-identical).

**Canonical-database contract** (ISSUE 4): the bytes of every output —
tree, stats, CMS/PMS cubes, trace.db — are a pure function of the
*profile set*, independent of ``n_ranks`` / ``n_threads`` / input path
order.  Context ids are renumbered into canonical BFS order (children
sorted by frame key) after unification, and profile ids are assigned in
canonical identity order.  This is what makes sharded aggregation
composable: ``repro.core.merge`` folds independently-built databases
into bytes identical to a one-shot ``aggregate()`` over the union
(docs/aggregation.md §incremental merge).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cct import Frame, GPU_OP, PLACEHOLDER, tree_depths
from repro.core.profmt import (FRAME_KIND_IDX, ProfileData, read_profile)
from repro.core.sparse import ProfileValues, write_cms, write_pms
from repro.core.structure import HloModule
from repro.core.trace import TraceWriter, read_trace

STATS = ("sum", "min", "mean", "max", "std", "cov")

_GPU_OP_KIND = FRAME_KIND_IDX[GPU_OP]


# --------------------------------------------------------------------------
# Global tree under construction
# --------------------------------------------------------------------------
class GlobalTree:
    """Global CCT built by merging per-profile trees.

    Frames are interned into an integer id table (strings interned once,
    then a frame is a (kind, name id, module id, line) key), and children
    are resolved through a dict keyed by the packed integer
    ``(parent << 32) | frame_id`` — per-node tuple/Frame hashing is off the
    hot path entirely; ``merge_paths`` computes each profile's frame ids
    with array-level gathers over the profile's string table.
    """

    def __init__(self):
        self.frames: List[Frame] = [Frame("root", "<program root>")]
        self.parents: List[int] = [-1]
        self._children: Dict[int, int] = {}      # (parent<<32)|fid -> gid
        self._strings: Dict[str, int] = {}       # string intern table
        self._key_fids: Dict[Tuple[int, int, int, int], int] = {}
        self._frame_of_fid: List[Frame] = []     # fid -> canonical Frame
        self._frame_cache: Dict[Frame, int] = {}  # fast path for child()

    # -- interning ----------------------------------------------------------
    def _intern_string(self, s: str) -> int:
        i = self._strings.get(s)
        if i is None:
            i = len(self._strings)
            self._strings[s] = i
        return i

    def _fid_for_key(self, key: Tuple[int, int, int, int],
                     frame: Frame) -> int:
        fid = self._key_fids.get(key)
        if fid is None:
            fid = len(self._frame_of_fid)
            self._key_fids[key] = fid
            self._frame_of_fid.append(frame)
        return fid

    def intern_frame(self, frame: Frame) -> int:
        fid = self._frame_cache.get(frame)
        if fid is None:
            kind = FRAME_KIND_IDX.get(frame.kind)
            if kind is None:   # kinds outside the profile format's table
                kind = -2 - self._intern_string(frame.kind)
            key = (kind, self._intern_string(frame.name),
                   self._intern_string(frame.module), int(frame.line))
            fid = self._fid_for_key(key, frame)
            self._frame_cache[frame] = fid
        return fid

    # -- tree construction ---------------------------------------------------
    def _child_fid(self, parent: int, fid: int) -> int:
        key = (parent << 32) | fid
        gid = self._children.get(key)
        if gid is None:
            gid = len(self.frames)
            self.frames.append(self._frame_of_fid[fid])
            self.parents.append(parent)
            self._children[key] = gid
        return gid

    def child(self, parent: int, frame: Frame) -> int:
        return self._child_fid(parent, self.intern_frame(frame))

    def _profile_fids(self, prof: ProfileData) -> np.ndarray:
        """Per-node global frame ids, resolved with one dict lookup per
        *unique* frame (array-level dedup) instead of one per node."""
        if prof.frame_kinds is None:
            return np.fromiter((self.intern_frame(f) for f in prof.frames),
                               np.int64, len(prof.frames))
        gsid = np.fromiter((self._intern_string(s) for s in prof.strings),
                           np.int64, len(prof.strings)) \
            if prof.strings else np.zeros(0, np.int64)
        rows = np.stack([prof.frame_kinds,
                         gsid[prof.frame_name_sids],
                         gsid[prof.frame_mod_sids],
                         prof.frame_lines], axis=1)
        uniq, first, inv = np.unique(rows, axis=0, return_index=True,
                                     return_inverse=True)
        fids_u = np.empty(len(uniq), np.int64)
        for j in range(len(uniq)):
            r = uniq[j]
            fids_u[j] = self._fid_for_key(
                (int(r[0]), int(r[1]), int(r[2]), int(r[3])),
                prof.frames[int(first[j])])
        return fids_u[inv.ravel()]

    def merge_paths(self, prof: ProfileData,
                    expand=None) -> np.ndarray:
        """Insert one profile's tree; returns local node id -> global id."""
        n = len(prof.node_ids)
        local_to_global = np.zeros(int(prof.node_ids.max()) + 1 if n else 1,
                                   np.int64)
        fids = self._profile_fids(prof).tolist()
        node_ids = prof.node_ids.tolist()
        parents = prof.parents.tolist()
        is_gpu = (prof.frame_kinds == _GPU_OP_KIND).tolist() \
            if (expand is not None and prof.frame_kinds is not None) else None
        l2g = local_to_global.tolist()
        children = self._children
        frames_out, parents_out = self.frames, self.parents
        frame_of_fid = self._frame_of_fid
        # profiles store nodes in creation order: parents precede children
        for i in range(n):
            par = parents[i]
            if par < 0:
                l2g[node_ids[i]] = 0
                continue
            gpar = l2g[par]
            if expand is not None and (
                    is_gpu[i] if is_gpu is not None
                    else prof.frames[i].kind == GPU_OP):
                for f in expand(prof.frames[i], prof):
                    gpar = self.child(gpar, f)
                l2g[node_ids[i]] = gpar
                continue
            key = (gpar << 32) | fids[i]
            gid = children.get(key)
            if gid is None:
                gid = len(frames_out)
                frames_out.append(frame_of_fid[fids[i]])
                parents_out.append(gpar)
                children[key] = gid
            l2g[node_ids[i]] = gid
        local_to_global[:] = l2g
        return local_to_global

    def merge_tree(self, other: "GlobalTree") -> np.ndarray:
        """Merge another tree into this one (reduction-tree step)."""
        mapping = np.zeros(len(other.frames), np.int64)
        m = mapping.tolist()
        other_parents = other.parents
        for gid in range(1, len(other.frames)):
            m[gid] = self.child(m[other_parents[gid]], other.frames[gid])
        mapping[:] = m
        return mapping

    def topo_order(self) -> np.ndarray:
        return np.arange(len(self.frames))  # creation order is topological

    def depths(self) -> np.ndarray:
        """Per-node depth (root = 0), see ``cct.tree_depths``."""
        return tree_depths(self.parents)


# --------------------------------------------------------------------------
# Canonicalization: the database-bytes-are-a-pure-function contract
# --------------------------------------------------------------------------
def canonical_order(frames: List[Frame], parents) -> np.ndarray:
    """Old context id -> canonical id.

    Canonical numbering is a BFS of the tree with each node's children
    visited in sorted frame-key order ``(kind, name, module, line)`` —
    a pure function of the tree's *shape*, independent of the insertion
    order that built it.  Properties the pipeline relies on:

    - topological: a parent's canonical id precedes all its children's
      (so the reverse-id / level-order inclusive sweeps stay valid);
    - the relative order of any two children of one parent is decided by
      frame-key comparison alone, so it is identical in every tree that
      contains both — per-profile inclusive values come out bitwise
      identical whether a profile is aggregated inside a shard or inside
      the full union (the heart of the ``merge_databases`` byte-identity
      contract, docs/aggregation.md).
    """
    n = len(frames)
    parents = np.asarray(parents, np.int64)
    key_rank = {k: i for i, k in enumerate(sorted(
        {(f.kind, f.name, f.module, f.line) for f in frames}))}
    frank = np.fromiter(
        (key_rank[(f.kind, f.name, f.module, f.line)] for f in frames),
        np.int64, n)
    depth = tree_depths(parents)
    new_id = np.zeros(n, np.int64)
    done = 1                       # root keeps id 0
    for lvl in range(1, int(depth.max()) + 1 if n > 1 else 1):
        idx = np.nonzero(depth == lvl)[0]
        if len(idx) == 0:
            break
        order = np.lexsort((frank[idx], new_id[parents[idx]]))
        new_id[idx[order]] = np.arange(done, done + len(idx))
        done += len(idx)
    return new_id


def apply_order(frames: List[Frame], parents, new_id: np.ndarray
                ) -> Tuple[List[Frame], np.ndarray]:
    """Permute a (frames, parents) tree by an old->new id map."""
    parents = np.asarray(parents, np.int64)
    frames_c: List[Frame] = list(frames)
    for old, new in enumerate(new_id.tolist()):
        frames_c[new] = frames[old]
    parents_c = np.full(len(frames), -1, np.int64)
    has_par = parents >= 0
    parents_c[new_id[has_par]] = new_id[parents[has_par]]
    return frames_c, parents_c


def _ident_int(identity: dict, *keys) -> int:
    for k in keys:
        v = identity.get(k)
        if v is not None:
            try:
                return int(v)
            except (TypeError, ValueError):
                return 0
    return 0


def profile_sort_key(identity: dict, ctx: np.ndarray, met: np.ndarray,
                     val: np.ndarray) -> tuple:
    """Canonical profile order: host, rank, CPU threads before GPU
    streams, thread/stream index (the trace.db line order), then the full
    identity JSON, then a digest of the value triplets as a content
    tie-break — a pure function of the profile, never of input order."""
    digest = hashlib.sha256(
        np.ascontiguousarray(ctx.astype("<u4")).tobytes()
        + np.ascontiguousarray(met.astype("<u4")).tobytes()
        + np.ascontiguousarray(val.astype("<f8")).tobytes()).hexdigest()
    return (str(identity.get("host", "")), _ident_int(identity, "rank"),
            0 if identity.get("type", "cpu") == "cpu" else 1,
            _ident_int(identity, "thread", "stream"),
            json.dumps(identity, sort_keys=True), digest)


# --------------------------------------------------------------------------
# Expansion (phase 3)
# --------------------------------------------------------------------------
def make_expander(structures: Dict[str, HloModule]):
    """Returns expand(frame, prof) -> [Frame, ...] using structure files."""
    cache: Dict[Tuple[str, int], tuple] = {}

    def expand(frame: Frame, prof: ProfileData):
        mod = structures.get(frame.module)
        if mod is None:
            return (frame,)
        key = (frame.module, frame.line)   # line == op index for GPU_OP
        frames = cache.get(key)
        if frames is None:
            ops = mod.all_ops()
            if frame.line < len(ops):
                frames = tuple(mod.op_context(ops[frame.line]))
            else:
                frames = (frame,)
            cache[key] = frames
        return frames

    return expand


# --------------------------------------------------------------------------
# Database
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Database:
    out_dir: str
    frames: List[Frame]
    parents: np.ndarray
    metrics: List[str]
    profile_ids: Dict[int, dict]            # profile id -> identity
    stats: Dict[str, np.ndarray]            # stat -> (n_ctx, n_metrics)
    inclusive: bool = True
    # CSR children index, built lazily on first children_of() call
    _child_order: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False)
    _child_parents: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False)
    _depths: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False)

    @classmethod
    def load(cls, out_dir: str) -> "Database":
        with open(os.path.join(out_dir, "meta.json")) as f:
            meta = json.load(f)
        frames = [Frame(*f) for f in meta["frames"]]
        data = np.load(os.path.join(out_dir, "stats.npz"))
        stats = {k: data[k] for k in data.files}
        return cls(out_dir, frames, np.asarray(meta["parents"]),
                   meta["metrics"],
                   {int(k): v for k, v in meta["profiles"].items()}, stats)

    def metric_id(self, name: str) -> int:
        return self.metrics.index(name)

    def children_of(self, gid: int) -> List[int]:
        """Children of a context, via a precomputed CSR index (a stable
        argsort of the parent array) instead of an O(n) scan per call."""
        if self._child_order is None:
            parents = np.asarray(self.parents, np.int64)
            order = np.argsort(parents, kind="stable")
            # publish _child_parents first: a concurrent caller passing the
            # None-check above must find both arrays populated
            self._child_parents = parents[order]
            self._child_order = order
        lo, hi = np.searchsorted(self._child_parents, [gid, gid + 1])
        return [int(i) for i in self._child_order[lo:hi]]

    def depths(self) -> np.ndarray:
        """Per-context depth (root = 0), cached — the traceview raster and
        interval stats project contexts through this."""
        if self._depths is None:
            self._depths = tree_depths(self.parents)
        return self._depths

    def trace_db_path(self) -> str:
        return os.path.join(self.out_dir, "trace.db")

    def cms_path(self) -> str:
        return os.path.join(self.out_dir, "metrics.cms")

    def pms_path(self) -> str:
        return os.path.join(self.out_dir, "metrics.pms")


# --------------------------------------------------------------------------
# Phase 4 kernels: sparse per-profile stats + level-order propagation
# --------------------------------------------------------------------------
def _group_sum_ordered(keys: np.ndarray, vals: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Sum ``vals`` grouped by ``keys``, accumulating within each group in
    the array order of equal keys (stable sort + one unbuffered
    ``np.add.at``) — the FP addition order therefore matches a sequential
    scatter loop over the same data."""
    order = np.argsort(keys, kind="stable")
    ks, vs = keys[order], vals[order]
    uk, counts = np.unique(ks, return_counts=True)
    gidx = np.repeat(np.arange(len(uk)), counts)
    out = np.zeros(len(uk))
    np.add.at(out, gidx, vs)
    return uk, out


def _profile_inclusive_sparse(prof: ProfileData, gmap: np.ndarray,
                              parents: np.ndarray, depth: np.ndarray,
                              n_metrics: int
                              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One profile's inclusive (ctx, metric, value) triplets against the
    global tree, fully sparse.

    Exclusive values are scatter-added into COO keyed by
    ``ctx * n_metrics + metric``; inclusive propagation is a level-order
    sweep from the deepest tree level to the root — per level one grouped
    ``np.add.at`` folds the (already-inclusive) child entries into their
    parents.  Children are folded in decreasing global-id order after the
    parent's own exclusive value, which reproduces, bit for bit, the FP
    addition order of the classic dense reverse-id sweep (see
    docs/aggregation.md and tests/test_aggregate_equiv.py).
    """
    n_values = len(prof.values)
    if n_values == 0 or n_metrics == 0:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros(0, np.float64)
    ranges = prof.ranges
    starts, counts = ranges[:, 1], ranges[:, 2]
    if (len(ranges) and starts[0] == 0
            and starts[-1] + counts[-1] == n_values
            and np.array_equal(starts[1:], starts[:-1] + counts[:-1])):
        node_of_value = np.repeat(gmap[ranges[:, 0]], counts)
    else:   # non-contiguous layout: rare, keep the per-range fill
        node_of_value = np.zeros(n_values, np.int64)
        for nid, start, count in ranges:
            node_of_value[start:start + count] = gmap[int(nid)]
    keys = node_of_value * n_metrics + prof.value_mids.astype(np.int64)
    uk, val = _group_sum_ordered(keys, prof.values)
    ctx = uk // n_metrics
    met = uk % n_metrics

    dd = depth[ctx]
    maxd = int(dd.max()) if len(dd) else 0
    for lvl in range(maxd, 0, -1):
        sel = dd == lvl
        if not sel.any():
            continue
        s_ctx, s_met, s_val = ctx[sel], met[sel], val[sel]
        # children fold into a parent in decreasing id order (stable), the
        # order the dense reverse-id sweep adds them in
        o = np.argsort(-s_ctx, kind="stable")
        up_keys = parents[s_ctx[o]] * n_metrics + s_met[o]
        plv = dd == lvl - 1
        # parent's own (exclusive) entry first, then its children
        cat_keys = np.concatenate([ctx[plv] * n_metrics + met[plv], up_keys])
        cat_vals = np.concatenate([val[plv], s_val[o]])
        uk2, nv = _group_sum_ordered(cat_keys, cat_vals)
        keep = ~plv
        ctx = np.concatenate([ctx[keep], uk2 // n_metrics])
        met = np.concatenate([met[keep], uk2 % n_metrics])
        val = np.concatenate([val[keep], nv])
        dd = depth[ctx]

    nz = val != 0.0          # match np.nonzero() on the dense matrix
    ctx, met, val = ctx[nz], met[nz], val[nz]
    o = np.argsort(ctx * n_metrics + met, kind="stable")  # row-major order
    return ctx[o], met[o], val[o]


# --------------------------------------------------------------------------
# Database writing (shared with repro.core.merge)
# --------------------------------------------------------------------------
def _write_database(out_dir: str, frames: List[Frame], parents: np.ndarray,
                    metrics: List[str],
                    profiles: List[Tuple[dict, np.ndarray, np.ndarray,
                                         np.ndarray]],
                    *, n_workers: int, t0: float,
                    timing_base: Optional[dict] = None) -> Database:
    """Fold per-profile inclusive triplets into the on-disk database.

    ``profiles`` is a list of ``(identity, ctx, metric, value)`` sparse
    triplets against canonical context ids, in *any* order: profiles are
    sorted into canonical order here (``profile_sort_key``), so stats
    accumulation, the CMS/PMS cubes, and ``meta.json`` come out
    byte-identical for any arrival order — the single writer behind both
    ``aggregate()`` and ``merge_databases()``.
    """
    os.makedirs(out_dir, exist_ok=True)
    n_ctx = len(frames)
    n_metrics = len(metrics)
    prepped = []
    for ident, ctx, met, val in profiles:
        ctx = np.asarray(ctx, np.int64)
        met = np.asarray(met, np.int64)
        val = np.asarray(val, np.float64)
        o = np.lexsort((met, ctx))          # row-major, defensive re-sort
        ctx, met, val = ctx[o], met[o], val[o]
        prepped.append((profile_sort_key(ident, ctx, met, val),
                        ident, ctx, met, val))
    prepped.sort(key=lambda it: it[0])

    identities: Dict[int, dict] = {}
    pvals: List[ProfileValues] = []
    acc_sum = np.zeros((n_ctx, n_metrics))
    acc_min = np.full((n_ctx, n_metrics), np.inf)
    acc_max = np.full((n_ctx, n_metrics), -np.inf)
    acc_sumsq = np.zeros((n_ctx, n_metrics))
    acc_count = np.zeros((n_ctx, n_metrics))
    for pidx, (_, ident, ctx, met, val) in enumerate(prepped):
        identities[pidx] = ident
        pvals.append(ProfileValues(pidx, ctx.astype(np.uint32),
                                   met.astype(np.uint32), val))
        idx = (ctx, met)
        acc_sum[idx] += val           # (ctx, metric) pairs unique per profile
        np.minimum.at(acc_min, idx, val)
        np.maximum.at(acc_max, idx, val)
        acc_sumsq[idx] += val ** 2
        acc_count[idx] += 1

    count = np.maximum(acc_count, 1)
    mean = acc_sum / count
    var = np.maximum(acc_sumsq / count - mean ** 2, 0.0)
    std = np.sqrt(var)
    stats = {
        "sum": acc_sum,
        "min": np.where(np.isfinite(acc_min), acc_min, 0.0),
        "mean": mean,
        "max": np.where(np.isfinite(acc_max), acc_max, 0.0),
        "std": std,
        "cov": np.where(mean != 0, std / np.maximum(np.abs(mean), 1e-30),
                        0.0),
        "count": acc_count,
    }

    cms_info = write_cms(os.path.join(out_dir, "metrics.cms"), pvals,
                         n_workers=n_workers)
    pms_info = write_pms(os.path.join(out_dir, "metrics.pms"), pvals,
                         n_workers=n_workers)

    meta = {
        "frames": [[f.kind, f.name, f.module, f.line] for f in frames],
        "parents": [int(p) for p in parents],
        "metrics": metrics,
        "profiles": {str(i): ident for i, ident in identities.items()},
        "cms": cms_info, "pms": pms_info,
        "timing": {**(timing_base or {}),
                   "total_s": time.monotonic() - t0},
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f)
    np.savez(os.path.join(out_dir, "stats.npz"), **stats)
    return Database(out_dir, frames, np.asarray(parents), metrics,
                    identities, stats)


# --------------------------------------------------------------------------
# The aggregation driver
# --------------------------------------------------------------------------
def aggregate(profile_paths: Sequence[str], out_dir: str, *,
              n_ranks: int = 4, n_threads: int = 4,
              structures: Optional[Dict[str, HloModule]] = None,
              trace_paths: Sequence[str] = (),
              trace_db: bool = True,
              base_db: "Optional[str | Database]" = None,
              timing: Optional[dict] = None) -> Database:
    """One-shot aggregation of ``profile_paths`` into ``out_dir``.

    With ``base_db`` (a database directory or ``Database``), runs in
    incremental mode: the new profiles extend the base database and the
    output is byte-identical to a one-shot run over the union — see
    ``_aggregate_incremental`` and ``repro.core.merge``."""
    if base_db is not None:
        return _aggregate_incremental(
            profile_paths, out_dir, base_db, n_ranks=n_ranks,
            n_threads=n_threads, structures=structures,
            trace_paths=trace_paths, trace_db=trace_db, timing=timing)
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.monotonic()
    expand = make_expander(structures) if structures else None

    # phase 1: acquisition + round-robin distribution
    ranks: List[List[str]] = [[] for _ in range(n_ranks)]
    for i, p in enumerate(profile_paths):
        ranks[i % n_ranks].append(p)

    # phase 2: per-rank unification (threads = dynamic tasks inside a rank)
    def unify_rank(paths: List[str]):
        tree = GlobalTree()
        profs: List[Tuple[str, ProfileData, np.ndarray]] = []
        def load(path):
            return path, read_profile(path)
        with ThreadPoolExecutor(max(1, n_threads)) as ex:
            loaded = list(ex.map(load, paths))
        for path, prof in loaded:
            mapping = tree.merge_paths(prof, expand)
            profs.append((path, prof, mapping))
        return tree, profs

    with ThreadPoolExecutor(max(1, n_ranks)) as ex:
        rank_results = list(ex.map(unify_rank, ranks))

    # reduction tree (arity = n_threads) to the root rank
    trees = [r[0] for r in rank_results]
    mappings: List[Optional[np.ndarray]] = [None] * len(trees)
    root = trees[0]
    # k-ary reduction: fold each tree into root, tracked per rank
    for i in range(1, len(trees)):
        mappings[i] = root.merge_tree(trees[i])
    t_unify = time.monotonic() - t0

    # canonical context renumbering: database ids are a pure function of
    # the profile set, independent of n_ranks / path order (merge contract)
    new_id = canonical_order(root.frames, root.parents)
    frames_c, parents_c = apply_order(root.frames, root.parents, new_id)

    # broadcast: convert each profile's local->rank mapping to ->canonical
    all_profiles: List[Tuple[str, ProfileData, np.ndarray]] = []
    for r, (tree, profs) in enumerate(rank_results):
        conv = mappings[r]
        for path, prof, mapping in profs:
            gmap = mapping if conv is None else conv[mapping]
            all_profiles.append((path, prof, new_id[gmap]))

    # phase 4: statistic generation (parallel over profiles).  Workers are
    # communication-free: each returns its profile's sparse triplets; the
    # partial accumulators are folded in _write_database, once, in
    # canonical profile order — no shared state, no lock, deterministic.
    metrics = all_profiles[0][1].metrics if all_profiles else []
    n_metrics = len(metrics)
    parents = parents_c
    depth = tree_depths(parents_c)

    def gen_stats(args):
        path, prof, gmap = args
        ctx, met, val = _profile_inclusive_sparse(prof, gmap, parents,
                                                  depth, n_metrics)
        return (prof.identity, ctx, met, val)

    with ThreadPoolExecutor(max(1, n_ranks * n_threads)) as ex:
        profile_items = list(ex.map(gen_stats, all_profiles))
    t_stats = time.monotonic() - t0 - t_unify

    # phase 5: trace conversion (vectorized gather through gmap)
    path_to_gmap = {path: gmap for path, prof, gmap in all_profiles}
    converted_traces: List[str] = []
    for tpath in trace_paths:
        td = read_trace(tpath)
        ppath = tpath.replace(".rtrc", ".rpro")
        gmap = path_to_gmap.get(ppath)
        identity = td.identity
        if gmap is None:
            # no matching profile: ctx ids pass through unmapped (e.g. the
            # profiler's GPU-stream traces, which record app-thread node
            # ids — see ROADMAP).  Mark the line so downstream composition
            # (repro.core.merge) copies it verbatim instead of remapping
            # ids that were never database ctx ids.
            identity = {**identity, "ctx_unmapped": True}
        out = TraceWriter(os.path.join(out_dir, os.path.basename(tpath)),
                          identity)
        if gmap is None:
            gids = td.ctx
        else:
            valid = (td.ctx >= 0) & (td.ctx < len(gmap))
            if not valid.all():
                warnings.warn(
                    f"{tpath}: {int((~valid).sum())} trace event(s) "
                    "reference ctx ids outside the profile's id map; "
                    "attributing them to the root context", RuntimeWarning)
            gids = np.where(valid,
                            gmap[np.clip(td.ctx, 0, len(gmap) - 1)], 0)
        out.append_many(td.starts, td.ends, gids)
        out.close()
        if out.path in converted_traces:
            warnings.warn(
                f"{tpath}: basename collides with another trace path; "
                "the earlier converted trace was overwritten",
                RuntimeWarning)
        else:
            converted_traces.append(out.path)
    if converted_traces and trace_db:
        # post-mortem merge into the seekable trace.db (traceview, §4.4):
        # the converted traces already carry global ctx ids, so the merged
        # database is directly renderable against this Database
        from repro.traceview.tracedb import build_db
        build_db(converted_traces, os.path.join(out_dir, "trace.db"))

    db = _write_database(out_dir, frames_c, parents_c, metrics,
                         profile_items, n_workers=n_ranks * n_threads,
                         t0=t0, timing_base={"unify_s": t_unify,
                                             "stats_s": t_stats})
    if timing is not None:
        with open(os.path.join(out_dir, "meta.json")) as f:
            timing.update(json.load(f)["timing"])
    return db


def _aggregate_incremental(profile_paths: Sequence[str], out_dir: str,
                           base_db: str, *, n_ranks: int, n_threads: int,
                           structures, trace_paths: Sequence[str],
                           trace_db: bool, timing: Optional[dict]
                           ) -> Database:
    """``aggregate(..., base_db=...)``: extend an existing database with
    new profiles.  The new profiles are aggregated into a scratch
    database, then folded with the base through ``merge_databases`` — the
    result is byte-identical to a one-shot ``aggregate()`` over the union
    of the base's profiles and the new ones (the canonical contract).
    ``out_dir`` may equal ``base_db`` (in-place epoch extension)."""
    import shutil
    import tempfile
    from repro.core.merge import merge_databases

    base_dir = base_db.out_dir if isinstance(base_db, Database) else base_db
    t0 = time.monotonic()
    scratch = tempfile.mkdtemp(prefix="repro_increment_")
    try:
        aggregate(profile_paths, scratch, n_ranks=n_ranks,
                  n_threads=n_threads, structures=structures,
                  trace_paths=trace_paths, trace_db=trace_db)
        db = merge_databases([base_dir, scratch], out_dir,
                             n_workers=n_ranks * n_threads,
                             trace_db=trace_db)
    finally:
        shutil.rmtree(scratch, ignore_errors=True)
    if timing is not None:
        with open(os.path.join(out_dir, "meta.json")) as f:
            timing.update(json.load(f)["timing"])
        timing["incremental_s"] = time.monotonic() - t0
    return db
