"""Streaming aggregation — the ``hpcprof`` / ``hpcprof-mpi`` analogue
(paper §6.1): the public façade over the staged pipeline.

The five paper phases each live in their own module under
``repro.core.pipeline`` (acquire -> unify -> expand -> stats ->
traceconv, behind dataclass stage contracts), the database
reader/writer in ``pipeline.database``, and the pluggable serial /
thread / process shard driver in ``pipeline.driver`` —
``docs/pipeline.md`` documents the architecture, ``docs/aggregation.md``
the canonical-database contract every stage upholds: database bytes are
a pure function of the profile set, which is what makes shard
aggregation composable (``repro.core.merge``), the parallel driver
byte-identical to serial by construction, and retention policies
(``repro.core.retention``) exact.

This module re-exports every name the pre-decomposition monolith
offered, so existing imports keep working unchanged.

CLI::

    python -m repro.core.aggregate MEASURE_DIR -o DB [--workers N]
        [--driver serial|thread|process] [--base DB] [--retain SPEC]
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Sequence

# Re-exported public surface (the façade contract: no import breaks).
from repro.core.pipeline.acquire import Acquisition, acquire  # noqa: F401
from repro.core.pipeline.contracts import (ProfileEntry,  # noqa: F401
                                           ShardResult, UnifiedProfile,
                                           Unification)
from repro.core.pipeline.database import (STATS, Database,  # noqa: F401
                                          ancestor_closure,
                                          profile_sort_key, write_database)
from repro.core.pipeline.database import write_database as _write_database  # noqa: F401,E501
from repro.core.pipeline.driver import (DRIVERS, ENV_DRIVER,  # noqa: F401
                                        ENV_WORKERS, resolve_driver)
from repro.core.pipeline.expand import make_expander  # noqa: F401
from repro.core.pipeline.stats import (_group_sum_ordered,  # noqa: F401
                                       _profile_inclusive_sparse,
                                       generate_stats)
from repro.core.pipeline.traceconv import convert_traces  # noqa: F401
from repro.core.pipeline.unify import (GlobalTree,  # noqa: F401
                                       apply_order, canonical_order, unify)
from repro.core.structure import HloModule


def aggregate(profile_paths: Sequence[str], out_dir: str, *,
              n_ranks: int = 4, n_threads: int = 4,
              structures: Optional[Dict[str, HloModule]] = None,
              trace_paths: Sequence[str] = (),
              trace_db: bool = True,
              trace_pyramid: bool = False,
              base_db: "Optional[str | Database]" = None,
              timing: Optional[dict] = None,
              workers: Optional[int] = None,
              driver: Optional[str] = None,
              retention=None) -> Database:
    """Aggregate ``profile_paths`` into the database at ``out_dir``.

    - ``workers`` / ``driver`` select the shard driver
      (``pipeline.driver``): ``workers=4`` runs four shard aggregations
      on a ``ProcessPoolExecutor`` and folds them through
      ``merge_databases`` — byte-identical to the serial one-shot by
      construction, faster once shard work dominates the fold.
      Defaults honour ``$REPRO_AGG_DRIVER`` / ``$REPRO_AGG_WORKERS``.
    - ``base_db`` (a database directory or ``Database``) switches to
      incremental mode: the new profiles extend the base and the output
      is byte-identical to a one-shot run over the union — see
      ``_aggregate_incremental`` and ``repro.core.merge``.
    - ``retention`` (a ``repro.core.retention.RetentionPolicy``) is
      applied at merge time: epochs beyond the window are retired,
      duplicates compacted, and the result is byte-identical to
      re-aggregating the surviving profile set.
    - ``trace_pyramid=True`` also builds the ``trace.pyr`` tile pyramid
      next to ``trace.db`` during phase 5 (repro.traceview.pyramid) —
      the opt-in alternative to the lazy ``ensure_pyramid`` cache.
    """
    if base_db is not None:
        db = _aggregate_incremental(
            profile_paths, out_dir, base_db, n_ranks=n_ranks,
            n_threads=n_threads, structures=structures,
            trace_paths=trace_paths, trace_db=trace_db, timing=timing,
            workers=workers, driver=driver, retention=retention)
    elif retention is not None and not retention.is_noop:
        db = _aggregate_retained(
            profile_paths, out_dir, retention, n_ranks=n_ranks,
            n_threads=n_threads, structures=structures,
            trace_paths=trace_paths, trace_db=trace_db, timing=timing,
            workers=workers, driver=driver)
    else:
        from repro.core.pipeline import driver as _driver
        return _driver.run(profile_paths, out_dir, n_ranks=n_ranks,
                           n_threads=n_threads, structures=structures,
                           trace_paths=trace_paths, trace_db=trace_db,
                           trace_pyramid=trace_pyramid, timing=timing,
                           workers=workers, driver=driver)
    # merged paths (incremental/retained) rebuild trace.db during the
    # fold; refresh the pyramid from the final bytes
    if trace_pyramid and os.path.exists(db.trace_db_path()):
        from repro.traceview.pyramid import ensure_pyramid
        ensure_pyramid(db).close()
    return db


def _aggregate_incremental(profile_paths: Sequence[str], out_dir: str,
                           base_db, *, n_ranks: int, n_threads: int,
                           structures, trace_paths: Sequence[str],
                           trace_db: bool, timing: Optional[dict],
                           workers=None, driver=None,
                           retention=None) -> Database:
    """``aggregate(..., base_db=...)``: extend an existing database with
    new profiles.  The new profiles are aggregated into a scratch
    database, then folded with the base through ``merge_databases`` — the
    result is byte-identical to a one-shot ``aggregate()`` over the union
    of the base's profiles and the new ones (the canonical contract).
    ``out_dir`` may equal ``base_db`` (in-place epoch extension); a
    ``retention`` policy retires old epochs in the same fold."""
    import json
    import shutil
    import tempfile
    from repro.core.merge import merge_databases

    base_dir = base_db.out_dir if isinstance(base_db, Database) else base_db
    t0 = time.monotonic()
    scratch = tempfile.mkdtemp(prefix="repro_increment_")
    try:
        aggregate(profile_paths, scratch, n_ranks=n_ranks,
                  n_threads=n_threads, structures=structures,
                  trace_paths=trace_paths, trace_db=trace_db,
                  workers=workers, driver=driver)
        db = merge_databases([base_dir, scratch], out_dir,
                             n_workers=n_ranks * n_threads,
                             trace_db=trace_db, retention=retention)
    finally:
        shutil.rmtree(scratch, ignore_errors=True)
    if timing is not None:
        with open(os.path.join(out_dir, "meta.json")) as f:
            timing.update(json.load(f)["timing"])
        timing["incremental_s"] = time.monotonic() - t0
    return db


def _aggregate_retained(profile_paths: Sequence[str], out_dir: str,
                        retention, *, n_ranks: int, n_threads: int,
                        structures, trace_paths: Sequence[str],
                        trace_db: bool, timing: Optional[dict],
                        workers, driver) -> Database:
    """One-shot aggregation with a retention policy: aggregate to a
    scratch database (under the selected driver), then apply the policy
    in a single self-merge — the same fold the incremental path uses.
    Like every merged directory, the output indexes traces solely via
    ``trace.db`` (no per-trace ``.rtrc`` intermediates)."""
    import shutil
    import tempfile
    from repro.core.merge import merge_databases

    scratch = tempfile.mkdtemp(prefix="repro_retain_")
    try:
        aggregate(profile_paths, scratch, n_ranks=n_ranks,
                  n_threads=n_threads, structures=structures,
                  trace_paths=trace_paths, trace_db=trace_db,
                  timing=timing, workers=workers, driver=driver)
        return merge_databases([scratch], out_dir,
                               n_workers=n_ranks * n_threads,
                               trace_db=trace_db, retention=retention)
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    import sys
    from repro.core.pipeline.cli import main
    sys.exit(main())
