"""Streaming aggregation — the ``hpcprof`` / ``hpcprof-mpi`` analogue
(paper §6.1).

Pipeline phases, exactly as the paper stages them:

1. **Input acquisition** — profile files are listed and distributed evenly
   across ranks (round-robin), then processed as dynamic per-thread tasks.
2. **Call-path unification** — each rank unifies its profiles' CCTs into a
   rank-local tree; rank trees merge up a reduction tree of arity ``t``
   (the per-rank thread count) to the root, yielding the global calling
   context tree and a local->global id mapping per profile.
3. **Calling-context expansion** — flat GPU-op frames are expanded against
   hpcstruct-analogue structure files (lines / loops / inlined scopes).
   (Profiles measured with runtime expansion skip this, see profiler.py.)
4. **Statistic generation** — per profile, metric values are propagated up
   the tree (inclusive metrics, vectorized scatter-add over a topological
   order) and fed into per-(ctx, metric) accumulators that yield
   sum/min/mean/max/stddev/CoV across profiles; per-profile values stream
   straight into the PMS/CMS writers.
5. **Trace + final outputs** — trace files are rewritten in terms of global
   ctx ids; tree, stats, and sparse cubes land in the database directory.

"Ranks" are worker threads here (single-host container): the reduction
tree, exscan offset computation, and nnz-balanced work splitting are the
same algorithms hpcprof-mpi runs over MPI; DESIGN.md §8 discusses the
honesty of this mapping and the benchmark reports both wall-clock and
work/critical-path scaling.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cct import Frame, GPU_OP, PLACEHOLDER
from repro.core.profmt import ProfileData, read_profile
from repro.core.sparse import ProfileValues, write_cms, write_pms
from repro.core.structure import HloModule
from repro.core.trace import TraceWriter, read_trace

STATS = ("sum", "min", "mean", "max", "std", "cov")


# --------------------------------------------------------------------------
# Global tree under construction
# --------------------------------------------------------------------------
class GlobalTree:
    def __init__(self):
        self.frames: List[Frame] = [Frame("root", "<program root>")]
        self.parents: List[int] = [-1]
        self._index: Dict[Tuple[int, Frame], int] = {}

    def child(self, parent: int, frame: Frame) -> int:
        key = (parent, frame)
        gid = self._index.get(key)
        if gid is None:
            gid = len(self.frames)
            self.frames.append(frame)
            self.parents.append(parent)
            self._index[key] = gid
        return gid

    def merge_paths(self, prof: ProfileData,
                    expand=None) -> np.ndarray:
        """Insert one profile's tree; returns local node id -> global id."""
        n = len(prof.node_ids)
        local_to_global = np.zeros(int(prof.node_ids.max()) + 1 if n else 1,
                                   np.int64)
        # profiles store nodes in creation order: parents precede children
        for i in range(n):
            nid = int(prof.node_ids[i])
            par = int(prof.parents[i])
            frame = prof.frames[i]
            if par < 0:
                local_to_global[nid] = 0
                continue
            gpar = int(local_to_global[par])
            if expand is not None and frame.kind == GPU_OP:
                for f in expand(frame, prof):
                    gpar = self.child(gpar, f)
                local_to_global[nid] = gpar
            else:
                local_to_global[nid] = self.child(gpar, frame)
        return local_to_global

    def merge_tree(self, other: "GlobalTree") -> np.ndarray:
        """Merge another tree into this one (reduction-tree step)."""
        mapping = np.zeros(len(other.frames), np.int64)
        for gid in range(1, len(other.frames)):
            mapping[gid] = self.child(int(mapping[other.parents[gid]]),
                                      other.frames[gid])
        return mapping

    def topo_order(self) -> np.ndarray:
        return np.arange(len(self.frames))  # creation order is topological


# --------------------------------------------------------------------------
# Expansion (phase 3)
# --------------------------------------------------------------------------
def make_expander(structures: Dict[str, HloModule]):
    """Returns expand(frame, prof) -> [Frame, ...] using structure files."""
    cache: Dict[Tuple[str, int], tuple] = {}

    def expand(frame: Frame, prof: ProfileData):
        mod = structures.get(frame.module)
        if mod is None:
            return (frame,)
        key = (frame.module, frame.line)   # line == op index for GPU_OP
        frames = cache.get(key)
        if frames is None:
            ops = mod.all_ops()
            if frame.line < len(ops):
                frames = tuple(mod.op_context(ops[frame.line]))
            else:
                frames = (frame,)
            cache[key] = frames
        return frames

    return expand


# --------------------------------------------------------------------------
# Database
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Database:
    out_dir: str
    frames: List[Frame]
    parents: np.ndarray
    metrics: List[str]
    profile_ids: Dict[int, dict]            # profile id -> identity
    stats: Dict[str, np.ndarray]            # stat -> (n_ctx, n_metrics)
    inclusive: bool = True

    @classmethod
    def load(cls, out_dir: str) -> "Database":
        with open(os.path.join(out_dir, "meta.json")) as f:
            meta = json.load(f)
        frames = [Frame(*f) for f in meta["frames"]]
        data = np.load(os.path.join(out_dir, "stats.npz"))
        stats = {k: data[k] for k in data.files}
        return cls(out_dir, frames, np.asarray(meta["parents"]),
                   meta["metrics"],
                   {int(k): v for k, v in meta["profiles"].items()}, stats)

    def metric_id(self, name: str) -> int:
        return self.metrics.index(name)

    def children_of(self, gid: int) -> List[int]:
        return [i for i, p in enumerate(self.parents) if p == gid]

    def cms_path(self) -> str:
        return os.path.join(self.out_dir, "metrics.cms")

    def pms_path(self) -> str:
        return os.path.join(self.out_dir, "metrics.pms")


# --------------------------------------------------------------------------
# The aggregation driver
# --------------------------------------------------------------------------
def aggregate(profile_paths: Sequence[str], out_dir: str, *,
              n_ranks: int = 4, n_threads: int = 4,
              structures: Optional[Dict[str, HloModule]] = None,
              trace_paths: Sequence[str] = (),
              timing: Optional[dict] = None) -> Database:
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.monotonic()
    expand = make_expander(structures) if structures else None

    # phase 1: acquisition + round-robin distribution
    ranks: List[List[str]] = [[] for _ in range(n_ranks)]
    for i, p in enumerate(profile_paths):
        ranks[i % n_ranks].append(p)

    # phase 2: per-rank unification (threads = dynamic tasks inside a rank)
    def unify_rank(paths: List[str]):
        tree = GlobalTree()
        profs: List[Tuple[str, ProfileData, np.ndarray]] = []
        def load(path):
            return path, read_profile(path)
        with ThreadPoolExecutor(max(1, n_threads)) as ex:
            loaded = list(ex.map(load, paths))
        for path, prof in loaded:
            mapping = tree.merge_paths(prof, expand)
            profs.append((path, prof, mapping))
        return tree, profs

    with ThreadPoolExecutor(max(1, n_ranks)) as ex:
        rank_results = list(ex.map(unify_rank, ranks))

    # reduction tree (arity = n_threads) to the root rank
    trees = [r[0] for r in rank_results]
    mappings: List[np.ndarray] = [None] * len(trees)  # rank tree -> global
    root = trees[0]
    idmaps = [np.arange(len(root.frames))]
    # k-ary reduction: fold each tree into root, tracked per rank
    mappings[0] = None
    for i in range(1, len(trees)):
        mappings[i] = root.merge_tree(trees[i])
    t_unify = time.monotonic() - t0

    n_ctx = len(root.frames)
    # broadcast: convert each profile's local->rank mapping to ->global
    all_profiles: List[Tuple[str, ProfileData, np.ndarray]] = []
    for r, (tree, profs) in enumerate(rank_results):
        conv = mappings[r]
        for path, prof, mapping in profs:
            gmap = mapping if conv is None else conv[mapping]
            all_profiles.append((path, prof, gmap))

    # phase 4: statistic generation (parallel over profiles)
    metrics = all_profiles[0][1].metrics if all_profiles else []
    n_metrics = len(metrics)
    parents = np.asarray(root.parents)

    acc_lock = __import__("threading").Lock()
    acc = {
        "sum": np.zeros((n_ctx, n_metrics)),
        "min": np.full((n_ctx, n_metrics), np.inf),
        "max": np.full((n_ctx, n_metrics), -np.inf),
        "sumsq": np.zeros((n_ctx, n_metrics)),
        "count": np.zeros((n_ctx, n_metrics)),
    }
    pvals: List[ProfileValues] = []
    identities: Dict[int, dict] = {}

    def gen_stats(args):
        pidx, (path, prof, gmap) = args
        dense = np.zeros((n_ctx, n_metrics))
        node_of_value = np.zeros(len(prof.values), np.int64)
        for nid, start, count in prof.ranges:
            node_of_value[start:start + count] = gmap[int(nid)]
        np.add.at(dense, (node_of_value, prof.value_mids.astype(np.int64)),
                  prof.values)
        # inclusive propagation: children created after parents, so a
        # reverse sweep adds each row into its parent exactly once.
        for gid in range(n_ctx - 1, 0, -1):
            p = parents[gid]
            if p >= 0:
                dense[p] += dense[gid]
        nz_ctx, nz_met = np.nonzero(dense)
        vals = dense[nz_ctx, nz_met]
        with acc_lock:
            acc["sum"][nz_ctx, nz_met] += vals
            np.minimum.at(acc["min"], (nz_ctx, nz_met), vals)
            np.maximum.at(acc["max"], (nz_ctx, nz_met), vals)
            acc["sumsq"][nz_ctx, nz_met] += vals ** 2
            acc["count"][nz_ctx, nz_met] += 1
            pvals.append(ProfileValues(pidx, nz_ctx.astype(np.uint32),
                                       nz_met.astype(np.uint32), vals))
            identities[pidx] = prof.identity
        return None

    with ThreadPoolExecutor(max(1, n_ranks * n_threads)) as ex:
        list(ex.map(gen_stats, enumerate(all_profiles)))
    t_stats = time.monotonic() - t0 - t_unify

    count = np.maximum(acc["count"], 1)
    mean = acc["sum"] / count
    var = np.maximum(acc["sumsq"] / count - mean ** 2, 0.0)
    std = np.sqrt(var)
    stats = {
        "sum": acc["sum"],
        "min": np.where(np.isfinite(acc["min"]), acc["min"], 0.0),
        "mean": mean,
        "max": np.where(np.isfinite(acc["max"]), acc["max"], 0.0),
        "std": std,
        "cov": np.where(mean != 0, std / np.maximum(np.abs(mean), 1e-30),
                        0.0),
        "count": acc["count"],
    }

    # sparse cube outputs
    pvals.sort(key=lambda p: p.profile_id)
    cms_info = write_cms(os.path.join(out_dir, "metrics.cms"), pvals,
                         n_workers=n_ranks * n_threads)
    pms_info = write_pms(os.path.join(out_dir, "metrics.pms"), pvals,
                         n_workers=n_ranks * n_threads)

    # phase 5: trace conversion
    path_to_gmap = {path: gmap for path, prof, gmap in all_profiles}
    for tpath in trace_paths:
        td = read_trace(tpath)
        ppath = tpath.replace(".rtrc", ".rpro")
        gmap = path_to_gmap.get(ppath)
        out = TraceWriter(os.path.join(out_dir, os.path.basename(tpath)),
                          td.identity)
        for s, e, c in zip(td.starts, td.ends, td.ctx):
            gid = int(gmap[int(c)]) if gmap is not None and \
                int(c) < len(gmap) else int(c)
            out.append(int(s), int(e), gid)
        out.close()

    meta = {
        "frames": [[f.kind, f.name, f.module, f.line] for f in root.frames],
        "parents": [int(p) for p in root.parents],
        "metrics": metrics,
        "profiles": {str(i): ident for i, ident in identities.items()},
        "cms": cms_info, "pms": pms_info,
        "timing": {"unify_s": t_unify, "stats_s": t_stats,
                   "total_s": time.monotonic() - t0},
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f)
    np.savez(os.path.join(out_dir, "stats.npz"), **stats)
    if timing is not None:
        timing.update(meta["timing"])
    return Database(out_dir, root.frames, parents, metrics, identities,
                    stats)
