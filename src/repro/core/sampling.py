"""Fine-grained measurement — the PC-sampling analogue (paper §4.2).

NVIDIA GPUs expose hardware PC sampling (instruction address + stall reason
+ count).  TPUs expose no public equivalent, so we adapt (DESIGN.md §2): the
"instruction" is an HLO op inside the compiled module, the sampling weight
is the op's roofline-model time, and the *stall reason* analogue is the
op's dominant bound class:

    stall_compute    — MXU/VPU-bound (flops term dominates)
    stall_memory     — HBM-bound (bytes term dominates)
    stall_collective — ICI-bound (collective term dominates)

The attribution machinery downstream of the sample source (samples ->
activity records -> CCT nodes under the kernel placeholder -> lines/loops
via structure info) is exactly the paper's.  On real TPUs the same
``Sample`` records could be filled from XProf/XPlane device traces instead.

The GT-Pin instrumentation path (§4.2's second mode) is the *exact* op
count: ``instrument=True`` emits one record per op with its true executed
count (1, or trip count inside while bodies) instead of sampled counts.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.structure import HloModule, HloOp

# TPU v5e-class chip constants (also used by roofline.py)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 4.5e10              # ~bytes/s effective per link direction

STALL_CLASSES = ("compute", "memory", "collective")

# budgets at or below this draw as n categorical samples (inverse CDF)
# instead of one multinomial — see draw_samples
_SMALL_DRAW = 32


@dataclasses.dataclass(slots=True)
class Sample:
    op_index: int            # index of the op within the module
    stall: str               # one of STALL_CLASSES
    count: int
    leaf: int = -1           # kernel-interior leaf index (kstruct), or -1


def op_time_model(op: HloOp) -> Dict[str, float]:
    """Roofline time terms for one op (seconds)."""
    tc = op.flops / PEAK_FLOPS
    tm = op.bytes / HBM_BW
    tcoll = 0.0
    if op.is_collective:
        g = max(op.group_size, 1)
        tcoll = op.bytes * 2.0 * (g - 1) / g / ICI_BW
    return {"compute": tc, "memory": tm, "collective": tcoll}


# pseudo-ops that are not executed instructions (never sampled)
_NON_INST = frozenset({"parameter", "constant", "get-tuple-element", "tuple",
                       "bitcast", "after-all", "partition-id", "replica-id"})


def op_weights(module: HloModule) -> "np.ndarray":
    """(n_ops,) expected-time weights + (n_ops,) stall class indices.

    Cached on the module — recomputing per dispatch dominated tool overhead
    (bench_overhead: 4.1x -> ~2x after caching; EXPERIMENTS.md §Perf)."""
    cached = getattr(module, "_op_weights_cache", None)
    if cached is not None:
        return cached
    ops = module.all_ops()
    kstructs = module.kernel_structures() \
        if hasattr(module, "kernel_structures") else {}
    w = np.zeros(len(ops))
    stall = np.zeros(len(ops), np.int32)
    for i, op in enumerate(ops):
        if op.opcode in _NON_INST:
            continue
        t = op_time_model(op)
        ks = kstructs.get(op.index)
        if ks is not None:
            # a bound Pallas kernel parses as an opaque custom-call with
            # flops=0; its recovered interior structure supplies the
            # modeled compute/memory terms instead
            t["compute"] = max(t["compute"], ks.total_flops / PEAK_FLOPS)
            t["memory"] = max(t["memory"], ks.total_bytes / HBM_BW)
        w[i] = max(t.values())
        stall[i] = int(np.argmax([t["compute"], t["memory"],
                                  t["collective"]]))
    module._op_weights_cache = (w, stall)
    return w, stall


def sample_budget(duration_s: float, rate_hz: float,
                  cap: Optional[int] = None) -> int:
    """The per-dispatch sample count for one kernel execution — the
    cheap integer math the dispatch path computes inline before
    deferring the draw itself to the monitor thread (``draw_samples``).
    At least one sample is always budgeted (the never-off contract)."""
    n = max(1, int(duration_s * rate_hz))
    if cap is not None:
        n = max(1, min(n, int(cap)))
    return n


def pc_samples(module: HloModule, duration_s: float,
               rate_hz: float = 1e6, rng: Optional[np.random.Generator] = None,
               cap: Optional[int] = None) -> List[Sample]:
    """Draw PC samples for one kernel execution of ``duration_s``.

    Expected total samples = duration * rate; distributed over ops
    proportionally to modeled op time (multinomial when rng given,
    deterministic expectation rounding otherwise).  ``cap`` bounds the
    samples drawn for this one execution — the serving governor's
    per-dispatch throttle (repro.serving.governor); at least one sample
    is always drawn, so fine-grained attribution never fully stops.

    This is ``sample_budget`` + ``draw_samples``; the profiler's
    deferred path calls the two halves from different threads.
    """
    return draw_samples(module, sample_budget(duration_s, rate_hz, cap),
                        rng)


def draw_samples(module: HloModule, n: int,
                 rng: Optional[np.random.Generator] = None) -> List[Sample]:
    """Distribute exactly-budgeted ``n`` samples over the module's ops
    (the draw core of ``pc_samples``).  Runs on the monitor thread in
    the deferred path: the ``w/total_w`` lookups are cached on the
    module, so consecutive dispatches of the same module amortize to
    the multinomial itself."""
    ops = module.all_ops()
    if not ops:
        return []
    w, stall = op_weights(module)
    # normalized weights cached with the module: the division is O(ops)
    p = getattr(module, "_op_p_cache", None)
    if p is None:
        total_w = w.sum()
        p = w / total_w if total_w > 0 else None
        module._op_p_cache = p
    if p is None:
        return []
    counts = None
    items = None
    if rng is not None:
        if n <= _SMALL_DRAW:
            # n independent categorical draws by inverse CDF — the same
            # distribution as multinomial(n, p) but ~4x cheaper at the
            # small per-dispatch budgets the governor runs (the deferred
            # path pays this per dispatch on the monitor thread).  Pure
            # python (bisect over a cached cdf list): at budget ~1 the
            # numpy searchsorted/bincount/nonzero round-trips dominated
            # the draw.  bisect_right == searchsorted(side="right") on
            # the same float64 values, so the drawn ops are identical.
            cdf_list = getattr(module, "_op_cdf_list_cache", None)
            if cdf_list is None:
                cdf = np.cumsum(p)
                cdf[-1] = 1.0           # guard fp drift: u < 1 always lands
                module._op_cdf_cache = cdf
                cdf_list = cdf.tolist()
                module._op_cdf_list_cache = cdf_list
            cnt: Dict[int, int] = {}
            for u in rng.random(n).tolist():
                i = bisect.bisect_right(cdf_list, u)
                cnt[i] = cnt.get(i, 0) + 1
            items = sorted(cnt.items())
        else:
            counts = rng.multinomial(n, p)
    else:
        counts = np.floor(n * p + 0.5).astype(np.int64)
        if counts.sum() == 0:
            # expectation rounding can floor *every* op to zero when the
            # governor cap forces n=1 and weights are spread thin across
            # many ops (max p < 0.5) — the documented guarantee is that
            # at least one sample is always drawn, attributed to the
            # heaviest op
            counts[int(np.argmax(p))] = 1
    # touch only the ops that drew samples: with the governor capping n
    # far below the op count, the per-dispatch draw cost must be
    # O(samples), not O(module ops)
    if items is None:
        items = [(int(i), int(counts[i])) for i in np.nonzero(counts)[0]]
    kstructs = module.kernel_structures() \
        if hasattr(module, "kernel_structures") else {}
    out: List[Sample] = []
    for i, c in items:
        op = ops[i]
        ks = kstructs.get(op.index)
        if ks is None:
            out.append(Sample(op_index=op.index,
                              stall=STALL_CLASSES[stall[i]], count=c))
            continue
        # two-level draw (§7): the op's samples descend into the bound
        # kernel-interior structure, apportioned over leaves by modeled
        # leaf weight — exactly ``c`` samples total, so the governor's
        # per-dispatch cap survives the descent unchanged
        for leaf, lc in ks.distribute(c, rng):
            out.append(Sample(op_index=op.index,
                              stall=ks.leaves[leaf].stall, count=lc,
                              leaf=leaf))
    return out


_MASK48 = (1 << 48) - 1
_MASK64 = (1 << 64) - 1

# splitmix64 constants (vectorized counter-hash uniforms)
_GOLDEN = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB
_INV53 = 1.0 / (1 << 53)


def _mix64(z: int) -> int:
    """One splitmix64 finalizer round over python ints (64-bit wrap)."""
    z &= _MASK64
    z = ((z ^ (z >> 30)) * _MIX1) & _MASK64
    z = ((z ^ (z >> 27)) * _MIX2) & _MASK64
    return z ^ (z >> 31)


class DispatchStream:
    """One dispatch's deterministic random stream, duck-typed to the
    slice of the Generator API the draw uses (``random``,
    ``multinomial``).

    Small draws — the per-dispatch budgets the governor actually runs —
    come from a counter-mode splitmix64 hash of the dispatch key, a few
    integer ops per value; re-keying the Philox generator costs ~7us in
    numpy state plumbing, which dominated the whole deferred draw.  The
    real keyed Generator is materialized lazily only for draws above
    ``_SMALL_DRAW``, where a kernel ran long enough that the multinomial
    amortizes.  Values are a pure function of (seed, lane, seq, draw
    position) either way — drain-order invariant.

    One mutable instance per KeyedRng, re-keyed per record (monitor
    thread only); never hold one across records."""

    __slots__ = ("_owner", "_key", "_pos", "_lane", "_seq", "_gen")

    def __init__(self, owner: "KeyedRng"):
        self._owner = owner

    def rekey(self, lane: int, seq: int) -> None:
        # _mix64(seed ^ _mix64(k2 + GOLDEN)), both rounds inlined: this
        # runs once per drained activity record
        z = ((((lane & 0xFFFF) << 48) | (seq & _MASK48)) + _GOLDEN) \
            & _MASK64
        z = ((z ^ (z >> 30)) * _MIX1) & _MASK64
        z = ((z ^ (z >> 27)) * _MIX2) & _MASK64
        z = self._owner._seed ^ z ^ (z >> 31)
        z = ((z ^ (z >> 30)) * _MIX1) & _MASK64
        z = ((z ^ (z >> 27)) * _MIX2) & _MASK64
        self._key = z ^ (z >> 31)
        self._pos = 0
        self._lane = lane
        self._seq = seq
        self._gen = None

    def random(self, n: int = 1):
        """n uniforms in [0, 1), consumed from the stream position."""
        pos = self._pos
        self._pos = pos + n
        if n == 1:
            out = np.empty(1)
            out[0] = (_mix64(self._key + (pos + 1) * _GOLDEN)
                      >> 11) * _INV53
            return out
        idx = np.arange(pos + 1, pos + n + 1, dtype=np.uint64)
        z = np.uint64(self._key) + idx * np.uint64(_GOLDEN)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(_MIX1)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(_MIX2)
        z ^= z >> np.uint64(31)
        return (z >> np.uint64(11)).astype(np.float64) * _INV53

    def multinomial(self, n: int, p) -> np.ndarray:
        n = int(n)
        if n <= _SMALL_DRAW:
            cdf = np.cumsum(p)
            cdf[-1] = 1.0
            idx = cdf.searchsorted(self.random(n), side="right")
            return np.bincount(idx, minlength=len(p))
        if self._gen is None:
            self._gen = self._owner.keyed(self._lane, self._seq)
        return self._gen.multinomial(n, p)


class KeyedRng:
    """Deterministic per-dispatch generator streams for the deferred
    PC-sample draw.

    The legacy inline path consumed one shared ``default_rng(seed)`` in
    dispatch order, so the drawn values depended on the order draws
    happened to run — unacceptable once the draw moves off-thread,
    where drain batching would permute it.  ``keyed(lane, seq)``
    instead re-keys a single Philox bit generator to the 128-bit key
    ``(seed, lane << 48 | seq)`` — ``lane`` the dispatching thread's
    stable index, ``seq`` its per-thread dispatch sequence number — so
    every dispatch owns an independent counter-mode stream and the
    draw is a pure function of (seed, lane, seq), invariant under any
    drain order or batch split.

    Re-keying swaps the bit-generator state in place instead of
    constructing ``Generator(Philox(key=...))`` per dispatch (~4x
    cheaper; the states are bit-identical to fresh construction, which
    ``tests/test_dispatch_path.py`` pins).  Not thread-safe: the
    monitor thread is the only caller.
    """

    def __init__(self, seed: int):
        self._seed = int(seed) & _MASK64
        self._bg = np.random.Philox(key=[self._seed, 0])
        self.generator = np.random.Generator(self._bg)
        self._stream = DispatchStream(self)

    def stream(self, lane: int, seq: int) -> DispatchStream:
        """The cheap per-dispatch stream (the deferred path's default);
        see DispatchStream.  Returns the shared instance re-keyed."""
        s = self._stream
        s.rekey(lane, seq)
        return s

    def keyed(self, lane: int, seq: int) -> np.random.Generator:
        state = self._bg.state
        inner = state["state"]
        inner["key"][:] = (self._seed,
                           ((lane & 0xFFFF) << 48) | (seq & _MASK48))
        inner["counter"][:] = 0
        state["buffer_pos"] = 4         # buffer empty: first draw refills
        state["has_uint32"] = 0
        state["uinteger"] = 0
        self._bg.state = state
        return self.generator


def instruction_counts(module: HloModule,
                       trip_counts: Optional[Dict[str, int]] = None,
                       ) -> List[Sample]:
    """GT-Pin-analogue instrumentation: exact per-op executed counts.

    ``trip_counts``: while-op name -> trip count (defaults to 1); counts
    multiply through nested loop bodies, mirroring basic-block count
    propagation in §4.2.
    """
    trip_counts = trip_counts or {}
    # computation -> execution multiplier
    mult: Dict[str, int] = {module.entry: 1}
    callers = module.callers()

    def comp_mult(comp: str, seen=frozenset()) -> int:
        if comp in mult:
            return mult[comp]
        if comp in seen:
            return 1
        sites = callers.get(comp, [])
        if not sites:
            mult[comp] = 1
            return 1
        site = sites[0]
        m = comp_mult(site.comp, seen | {comp})
        if site.opcode == "while":
            m *= trip_counts.get(site.name, 1)
        mult[comp] = m
        return m

    out = []
    for op in module.all_ops():
        m = comp_mult(op.comp)
        out.append(Sample(op_index=op.index, stall="compute", count=m))
    return out
