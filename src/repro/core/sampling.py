"""Fine-grained measurement — the PC-sampling analogue (paper §4.2).

NVIDIA GPUs expose hardware PC sampling (instruction address + stall reason
+ count).  TPUs expose no public equivalent, so we adapt (DESIGN.md §2): the
"instruction" is an HLO op inside the compiled module, the sampling weight
is the op's roofline-model time, and the *stall reason* analogue is the
op's dominant bound class:

    stall_compute    — MXU/VPU-bound (flops term dominates)
    stall_memory     — HBM-bound (bytes term dominates)
    stall_collective — ICI-bound (collective term dominates)

The attribution machinery downstream of the sample source (samples ->
activity records -> CCT nodes under the kernel placeholder -> lines/loops
via structure info) is exactly the paper's.  On real TPUs the same
``Sample`` records could be filled from XProf/XPlane device traces instead.

The GT-Pin instrumentation path (§4.2's second mode) is the *exact* op
count: ``instrument=True`` emits one record per op with its true executed
count (1, or trip count inside while bodies) instead of sampled counts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.structure import HloModule, HloOp

# TPU v5e-class chip constants (also used by roofline.py)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 4.5e10              # ~bytes/s effective per link direction

STALL_CLASSES = ("compute", "memory", "collective")


@dataclasses.dataclass
class Sample:
    op_index: int            # index of the op within the module
    stall: str               # one of STALL_CLASSES
    count: int
    leaf: int = -1           # kernel-interior leaf index (kstruct), or -1


def op_time_model(op: HloOp) -> Dict[str, float]:
    """Roofline time terms for one op (seconds)."""
    tc = op.flops / PEAK_FLOPS
    tm = op.bytes / HBM_BW
    tcoll = 0.0
    if op.is_collective:
        g = max(op.group_size, 1)
        tcoll = op.bytes * 2.0 * (g - 1) / g / ICI_BW
    return {"compute": tc, "memory": tm, "collective": tcoll}


# pseudo-ops that are not executed instructions (never sampled)
_NON_INST = frozenset({"parameter", "constant", "get-tuple-element", "tuple",
                       "bitcast", "after-all", "partition-id", "replica-id"})


def op_weights(module: HloModule) -> "np.ndarray":
    """(n_ops,) expected-time weights + (n_ops,) stall class indices.

    Cached on the module — recomputing per dispatch dominated tool overhead
    (bench_overhead: 4.1x -> ~2x after caching; EXPERIMENTS.md §Perf)."""
    cached = getattr(module, "_op_weights_cache", None)
    if cached is not None:
        return cached
    ops = module.all_ops()
    kstructs = module.kernel_structures() \
        if hasattr(module, "kernel_structures") else {}
    w = np.zeros(len(ops))
    stall = np.zeros(len(ops), np.int32)
    for i, op in enumerate(ops):
        if op.opcode in _NON_INST:
            continue
        t = op_time_model(op)
        ks = kstructs.get(op.index)
        if ks is not None:
            # a bound Pallas kernel parses as an opaque custom-call with
            # flops=0; its recovered interior structure supplies the
            # modeled compute/memory terms instead
            t["compute"] = max(t["compute"], ks.total_flops / PEAK_FLOPS)
            t["memory"] = max(t["memory"], ks.total_bytes / HBM_BW)
        w[i] = max(t.values())
        stall[i] = int(np.argmax([t["compute"], t["memory"],
                                  t["collective"]]))
    module._op_weights_cache = (w, stall)
    return w, stall


def pc_samples(module: HloModule, duration_s: float,
               rate_hz: float = 1e6, rng: Optional[np.random.Generator] = None,
               cap: Optional[int] = None) -> List[Sample]:
    """Draw PC samples for one kernel execution of ``duration_s``.

    Expected total samples = duration * rate; distributed over ops
    proportionally to modeled op time (multinomial when rng given,
    deterministic expectation rounding otherwise).  ``cap`` bounds the
    samples drawn for this one execution — the serving governor's
    per-dispatch throttle (repro.serving.governor); at least one sample
    is always drawn, so fine-grained attribution never fully stops.
    """
    ops = module.all_ops()
    if not ops:
        return []
    w, stall = op_weights(module)
    # normalized weights cached with the module: the division is O(ops)
    # and this runs on the dispatch path
    p = getattr(module, "_op_p_cache", None)
    if p is None:
        total_w = w.sum()
        p = w / total_w if total_w > 0 else None
        module._op_p_cache = p
    if p is None:
        return []
    n = max(1, int(duration_s * rate_hz))
    if cap is not None:
        n = max(1, min(n, int(cap)))
    if rng is not None:
        counts = rng.multinomial(n, p)
    else:
        counts = np.floor(n * p + 0.5).astype(np.int64)
        if counts.sum() == 0:
            # expectation rounding can floor *every* op to zero when the
            # governor cap forces n=1 and weights are spread thin across
            # many ops (max p < 0.5) — the documented guarantee is that
            # at least one sample is always drawn, attributed to the
            # heaviest op
            counts[int(np.argmax(p))] = 1
    # touch only the ops that drew samples: with the governor capping n
    # far below the op count, the dispatch-path cost must be O(samples),
    # not O(module ops)
    kstructs = module.kernel_structures() \
        if hasattr(module, "kernel_structures") else {}
    out: List[Sample] = []
    for i in np.nonzero(counts)[0]:
        op = ops[i]
        c = int(counts[i])
        ks = kstructs.get(op.index)
        if ks is None:
            out.append(Sample(op_index=op.index,
                              stall=STALL_CLASSES[stall[i]], count=c))
            continue
        # two-level draw (§7): the op's samples descend into the bound
        # kernel-interior structure, apportioned over leaves by modeled
        # leaf weight — exactly ``c`` samples total, so the governor's
        # per-dispatch cap survives the descent unchanged
        for leaf, lc in ks.distribute(c, rng):
            out.append(Sample(op_index=op.index,
                              stall=ks.leaves[leaf].stall, count=lc,
                              leaf=leaf))
    return out


def instruction_counts(module: HloModule,
                       trip_counts: Optional[Dict[str, int]] = None,
                       ) -> List[Sample]:
    """GT-Pin-analogue instrumentation: exact per-op executed counts.

    ``trip_counts``: while-op name -> trip count (defaults to 1); counts
    multiply through nested loop bodies, mirroring basic-block count
    propagation in §4.2.
    """
    trip_counts = trip_counts or {}
    # computation -> execution multiplier
    mult: Dict[str, int] = {module.entry: 1}
    callers = module.callers()

    def comp_mult(comp: str, seen=frozenset()) -> int:
        if comp in mult:
            return mult[comp]
        if comp in seen:
            return 1
        sites = callers.get(comp, [])
        if not sites:
            mult[comp] = 1
            return 1
        site = sites[0]
        m = comp_mult(site.comp, seen | {comp})
        if site.opcode == "while":
            m *= trip_counts.get(site.name, 1)
        mult[comp] = m
        return m

    out = []
    for op in module.all_ops():
        m = comp_mult(op.comp)
        out.append(Sample(op_index=op.index, stall="compute", count=m))
    return out
