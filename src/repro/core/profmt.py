"""On-disk sparse profile format (paper §4.6, Fig. 3b).

Each profile file has the sections the paper describes:

- **Load Modules** — libraries / compiled HLO modules seen in execution;
- **CCT**          — tree structure: per node (id, parent, frame);
- **Metrics**      — index + name (+ properties) of every metric;
- **Metric Values** and **CCT Metric Values** — only non-zero values: a node
  with index range [I, I+N) owns positions I..I+N-1 of Metric Values.

plus a string table and a small identity header (the (node, rank, thread,
stream) tuple of §7).  Everything little-endian, numpy-readable so the
aggregator can stream values without materializing objects.
"""
from __future__ import annotations

import dataclasses
import io
import json
import struct
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cct import CCT, CCTNode, Frame
from repro.core.metrics import MetricRegistry

MAGIC = b"RPRO"
VERSION = 2

_FRAME_KINDS = ("root", "host", "placeholder", "gpu_op", "gpu_func",
                "gpu_loop")
_KIND_IDX = {k: i for i, k in enumerate(_FRAME_KINDS)}

# public aliases: the aggregator's batched frame interning keys frames by
# (kind idx, name, module, line) and needs the same kind numbering
FRAME_KINDS = _FRAME_KINDS
FRAME_KIND_IDX = _KIND_IDX


class _StringTable:
    def __init__(self):
        self._idx: Dict[str, int] = {}
        self.strings: List[str] = []

    def intern(self, s: str) -> int:
        i = self._idx.get(s)
        if i is None:
            i = len(self.strings)
            self._idx[s] = i
            self.strings.append(s)
        return i


def write_profile(path: str, cct: CCT, registry: MetricRegistry,
                  identity: Dict[str, object],
                  load_modules: Optional[List[str]] = None) -> Dict[str, int]:
    """Writes one profile.  Returns section byte sizes (for §8.2 size
    accounting)."""
    strings = _StringTable()
    nodes = cct.nodes()

    # --- CCT section ------------------------------------------------------
    cct_rows = np.zeros((len(nodes), 5), np.int64)
    for i, n in enumerate(nodes):
        cct_rows[i] = (
            n.node_id,
            n.parent.node_id if n.parent is not None else -1,
            _KIND_IDX[n.frame.kind],
            (strings.intern(n.frame.name) << 32)
            | strings.intern(n.frame.module),
            n.frame.line,
        )

    # --- sparse metric values (Fig. 3b) ------------------------------------
    mids: List[int] = []
    vals: List[float] = []
    node_ranges: List[Tuple[int, int, int]] = []   # (node_id, start, count)
    for n in nodes:
        if n.metrics.empty:
            continue
        start = len(mids)
        for gid, v in n.metrics.nonzero_items(registry):
            mids.append(gid)
            vals.append(v)
        count = len(mids) - start
        if count:
            node_ranges.append((n.node_id, start, count))

    header = {
        "identity": identity,
        "n_nodes": len(nodes),
        "n_values": len(vals),
        "metrics": registry.metric_names,
        "load_modules": load_modules or [],
    }

    sizes: Dict[str, int] = {}
    with open(path, "wb") as f:
        f.write(MAGIC + struct.pack("<I", VERSION))
        hdr = json.dumps(header).encode()
        f.write(struct.pack("<I", len(hdr)))
        f.write(hdr)
        sizes["header"] = len(hdr) + 12

        def section(name: str, arr: np.ndarray):
            data = arr.tobytes()
            f.write(struct.pack("<I", len(data)))
            f.write(data)
            sizes[name] = len(data) + 4

        section("cct", cct_rows)
        section("mids", np.asarray(mids, np.uint32))
        section("vals", np.asarray(vals, np.float64))
        section("ranges", np.asarray(node_ranges, np.int64).reshape(-1, 3))
        blob = json.dumps(strings.strings).encode()
        f.write(struct.pack("<I", len(blob)))
        f.write(blob)
        sizes["strings"] = len(blob) + 4
    return sizes


@dataclasses.dataclass
class ProfileData:
    identity: Dict[str, object]
    metrics: List[str]
    load_modules: List[str]
    node_ids: np.ndarray        # (N,)
    parents: np.ndarray         # (N,)
    frames: List[Frame]         # per node
    value_mids: np.ndarray      # (V,) uint32 global metric ids
    values: np.ndarray          # (V,) float64
    ranges: np.ndarray          # (R, 3) node_id, start, count
    # raw frame keys, parallel to ``frames`` — lets the aggregator intern
    # frames with array-level gathers over the profile string table instead
    # of hashing Frame objects per node (None on hand-built ProfileData)
    frame_kinds: Optional[np.ndarray] = None    # (N,) kind index
    frame_name_sids: Optional[np.ndarray] = None  # (N,) local string id
    frame_mod_sids: Optional[np.ndarray] = None   # (N,) local string id
    frame_lines: Optional[np.ndarray] = None    # (N,)
    strings: Optional[List[str]] = None         # local string table

    def node_values(self, node_id: int) -> Dict[int, float]:
        row = self.ranges[self.ranges[:, 0] == node_id]
        if len(row) == 0:
            return {}
        _, start, count = row[0]
        return {int(m): float(v)
                for m, v in zip(self.value_mids[start:start + count],
                                self.values[start:start + count])}

    def dense_matrix(self, n_metrics: int) -> np.ndarray:
        """(n_nodes, n_metrics) dense expansion — for the §8.2 comparison."""
        out = np.zeros((len(self.node_ids), n_metrics), np.float64)
        idx_of = {int(n): i for i, n in enumerate(self.node_ids)}
        for nid, start, count in self.ranges:
            i = idx_of[int(nid)]
            out[i, self.value_mids[start:start + count]] = \
                self.values[start:start + count]
        return out


def read_profile(path: str) -> ProfileData:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"bad magic in {path}"
        (version,) = struct.unpack("<I", f.read(4))
        assert version == VERSION
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))

        def section(dtype, cols=None):
            (n,) = struct.unpack("<I", f.read(4))
            arr = np.frombuffer(f.read(n), dtype)
            return arr.reshape(-1, cols) if cols else arr

        cct_rows = section(np.int64, 5)
        mids = section(np.uint32)
        vals = section(np.float64)
        ranges = section(np.int64, 3)
        (slen,) = struct.unpack("<I", f.read(4))
        strings = json.loads(f.read(slen))

    packed = cct_rows[:, 3]
    name_sids = (packed >> 32).astype(np.int64)
    mod_sids = (packed & 0xFFFFFFFF).astype(np.int64)
    frames = [Frame(_FRAME_KINDS[k], strings[n], strings[m], ln)
              for k, n, m, ln in zip(cct_rows[:, 2].tolist(),
                                     name_sids.tolist(), mod_sids.tolist(),
                                     cct_rows[:, 4].tolist())]
    return ProfileData(
        identity=header["identity"],
        metrics=header["metrics"],
        load_modules=header["load_modules"],
        node_ids=cct_rows[:, 0].copy(),
        parents=cct_rows[:, 1].copy(),
        frames=frames,
        value_mids=mids.copy(),
        values=vals.copy(),
        ranges=ranges.copy(),
        frame_kinds=cct_rows[:, 2].copy(),
        frame_name_sids=name_sids,
        frame_mod_sids=mod_sids,
        frame_lines=cct_rows[:, 4].copy(),
        strings=strings,
    )


def dense_profile_nbytes(n_nodes: int, n_metrics: int) -> int:
    """Size the original dense format would need (§8.2 comparison)."""
    return n_nodes * n_metrics * 8
