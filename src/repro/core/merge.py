"""Incremental & sharded database merge (continuous profiling).

The paper's ``hpcprof-mpi`` (§6.1) aggregates a whole measurement
directory in one shot; its exascale follow-up ("Preparing for Performance
Analysis at Exascale", Anderson et al.) gets to scale with a sparse
format plus *composable* parallel reduction.  This module is that
composition step: ``merge_databases`` folds N independently-built
databases (shards of a measurement directory, or successive epochs of a
long-running job) into one database whose bytes are **identical** to a
one-shot ``aggregate()`` over the union of their profiles.

Why that byte-identity is possible (the canonical contract,
docs/aggregation.md):

- context ids are canonical (BFS, children in frame-key order), so the
  union tree renumbers the same no matter how profiles were sharded, and
  the *relative* order of any node's children — the floating-point fold
  order of the inclusive sweep — is the same in a shard tree as in the
  union tree.  Per-profile inclusive values therefore come out bitwise
  identical in both, differing only by the ctx renumbering this module
  applies;
- profile ids are canonical (identity order + content digest), so the
  cross-profile accumulator fold and the CMS/PMS plane order do not
  depend on which shard a profile arrived in;
- ``trace.db`` lines merge by canonical identity order and re-merge
  idempotently (repro.traceview.tracedb), so shard trace databases
  re-fold after the same ctx remapping.

The merge therefore never re-propagates metrics: it re-reads each
shard's per-profile inclusive values from the PMS cube (``read_pms``),
grafts the shard trees into one union tree (``GlobalTree.merge_tree``
replayed from the serialized arrays), remaps ctx ids through the
composed ``shard -> union -> canonical`` map, and hands everything to
the same ``write_database`` writer ``aggregate()`` uses.

Inputs need not live on disk: the parallel shard driver
(``repro.core.pipeline.driver``) hands in-memory ``ShardResult``
objects (phases 1-4 over a shard, no intermediate database), and the
identical fold runs — that is what makes ``aggregate(..., workers=N)``
byte-identical to serial by construction and faster in wall-clock
(benchmarks/bench_pipeline.py measures it; bench_merge measures the
on-disk variant).

**Retention** (``repro.core.retention``): a ``RetentionPolicy`` filters
the unioned profile multiset before the write — retiring epochs,
deduplicating, capping profile count — and the tree is rebuilt from the
survivors' recorded context coverage, so the retained database is
byte-identical to re-aggregating the surviving profiles from scratch.

CLI::

    python -m repro.core.merge SHARD_DB... -o OUT_DB [--retain SPEC]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.cct import Frame
from repro.core.pipeline.contracts import ShardResult
from repro.core.pipeline.database import (Database, ancestor_closure,
                                          load_coverage, write_database)
from repro.core.pipeline.unify import (GlobalTree, apply_order,
                                       canonical_order)
from repro.core.retention import RetentionPolicy, RetentionReport, \
    apply_retention, parse_retention
from repro.core.sparse import ProfileValues, read_pms
from repro.core.trace import TraceData
from repro.ft import inject

# Labeled crash points on the commit path (ISSUE 6): the fleet crash
# matrix kills the merging process at each of these and asserts the
# intact-or-previous guarantee plus journal replay (docs/fleet.md).
FP_COMMIT_PRE_SWAP = "merge.commit.pre_swap"
FP_COMMIT_MID_SWAP = "merge.commit.mid_swap"
FP_COMMIT_POST_SWAP = "merge.commit.post_swap"
inject.register_points(FP_COMMIT_PRE_SWAP, FP_COMMIT_MID_SWAP,
                       FP_COMMIT_POST_SWAP)

PRE_MERGE_SUFFIX = ".pre-merge"
STAGING_PREFIX = ".merge_staging_"


# --------------------------------------------------------------------------
# Shard loading
# --------------------------------------------------------------------------
class LoadedShard:
    """One input database, fully materialized (arrays are copies, so an
    in-place merge may replace the files afterwards)."""

    def __init__(self, out_dir: str, *, load_traces: bool = True):
        self.out_dir = out_dir
        db = Database.load(out_dir)
        self.frames: List[Frame] = db.frames
        self.parents = np.asarray(db.parents, np.int64)
        self.metrics: List[str] = list(db.metrics)
        self.identities: Dict[int, dict] = db.profile_ids
        pms = db.pms_path()
        self.pvals: List[ProfileValues] = \
            read_pms(pms) if os.path.exists(pms) else []
        if set(int(p.profile_id) for p in self.pvals) != \
                set(self.identities):
            raise ValueError(
                f"{out_dir}: PMS profile planes do not match meta.json "
                "profiles; refusing to merge a torn database")
        # per-profile ctx coverage; databases written before coverage was
        # recorded fall back to the ancestor closure of the nonzero ctxs
        self.coverage: Dict[int, np.ndarray] = load_coverage(out_dir) or {
            int(pv.profile_id): ancestor_closure(
                pv.ctx.astype(np.int64), self.parents)
            for pv in self.pvals}
        self.trace_lines: List[TraceData] = []
        tpath = db.trace_db_path()
        if load_traces and os.path.exists(tpath):
            from repro.traceview.tracedb import TraceDB
            self.trace_lines = [
                TraceData(td.identity, np.array(td.starts),
                          np.array(td.ends), np.array(td.ctx))
                for td in TraceDB(tpath).line_views()]


ShardInput = Union[str, ShardResult, LoadedShard]


# --------------------------------------------------------------------------
# The merge driver
# --------------------------------------------------------------------------
def merge_databases(in_dirs: Sequence[ShardInput], out_dir: str, *,
                    n_workers: int = 4,
                    trace_db: bool = True,
                    retention: Optional[RetentionPolicy] = None,
                    retention_report: Optional[RetentionReport] = None,
                    remaps_out: Optional[list] = None,
                    extra_files: Optional[Dict[str, bytes]] = None
                    ) -> Database:
    """Fold N databases into one, byte-identical to a one-shot
    ``aggregate()`` over the union of their profiles.

    The fold is associative and input-order-invariant (canonicalization
    happens after the union), so any sharding of a measurement directory
    — and any merge tree over the shards — lands on the same bytes
    (property-tested in tests/test_merge_properties.py).  Profiles are
    concatenated as a multiset; identities are not deduplicated (unless
    a ``retention`` policy asks for it).

    Inputs are database directories or in-memory ``ShardResult`` objects
    (the parallel shard driver's contract).  With ``retention``, the
    unioned profile multiset is filtered and the tree restricted to the
    survivors' coverage before writing — byte-identical to re-aggregating
    the survivors (``repro.core.retention``); a ``retention_report``
    instance, when given, is filled in place.  ``remaps_out``, when a
    list, receives one ``shard ctx id -> output ctx id`` array per input
    (unsupported together with ``retention``).

    The output is staged in a sibling temp dir and committed with a
    directory swap, so ``out_dir`` may be one of ``in_dirs`` (in-place
    epoch extension — every input is fully materialized before anything
    is written) and a crash mid-merge never leaves a half-written mix of
    old and new files: the worst case is the old database parked at
    ``out_dir + ".pre-merge"`` (cleaned up on the next merge, or by
    ``recover_interrupted_swap``).  A merged directory indexes traces
    solely via ``trace.db`` — the per-trace ``.rtrc`` intermediates a
    one-shot ``aggregate()`` leaves are not reproduced (and any stale
    ones in a replaced ``out_dir`` go away with it).

    ``extra_files`` (name -> bytes) are written into the staged output
    *before* the swap, so they commit atomically with the database —
    this is how the fleet daemon's ingest journal rides the fold
    (``repro.fleet.journal``): there is no crash schedule that applies
    shards without journaling them, or vice versa.
    """
    if not in_dirs:
        raise ValueError("merge_databases: need at least one input "
                         "database")
    if retention is not None and remaps_out is not None:
        raise ValueError("merge_databases: remaps_out is not supported "
                         "together with retention (retired contexts have "
                         "no output id)")
    t0 = time.monotonic()
    shards = [sh if isinstance(sh, (ShardResult, LoadedShard))
              else LoadedShard(sh, load_traces=trace_db)
              for sh in in_dirs]

    metrics: List[str] = []
    for sh in shards:
        if not sh.identities:
            continue            # empty databases carry no metric columns
        if not metrics:
            metrics = sh.metrics
        elif sh.metrics != metrics:
            raise ValueError(
                f"{sh.out_dir}: metric columns {sh.metrics[:3]}... differ "
                f"from {metrics[:3]}...; databases must be measured with "
                "identical metric registries to merge")

    # union tree: graft every shard tree (shard inputs duck-type the
    # frames/parents pair merge_tree consumes — the same reduction step
    # hpcprof's rank fold uses, replayed from the serialized arrays),
    # then canonicalize — the result is a pure function of the union
    # node set, not of shard order
    union = GlobalTree()
    mappings = [union.merge_tree(sh) for sh in shards]
    new_id = canonical_order(union.frames, union.parents)
    frames_c, parents_c = apply_order(union.frames, union.parents, new_id)
    remaps = [new_id[m] for m in mappings]

    # per-profile values: remap ctx (and coverage) through shard ->
    # canonical-union ids.  write_database re-sorts rows and re-sorts
    # profiles canonically, so shard order is irrelevant from here on.
    entries: List[Tuple[dict, np.ndarray, np.ndarray, np.ndarray,
                        np.ndarray]] = []
    for sh, remap in zip(shards, remaps):
        for pv in sh.pvals:
            pid = int(pv.profile_id)
            cover = sh.coverage.get(pid)
            if cover is None:
                cover = ancestor_closure(pv.ctx.astype(np.int64),
                                         np.asarray(sh.parents, np.int64))
            entries.append(
                (sh.identities[pid], remap[pv.ctx.astype(np.int64)],
                 pv.metric.astype(np.int64), pv.values,
                 np.sort(remap[np.asarray(cover, np.int64)])))

    # trace.db: remap each shard's lines and re-merge (idempotent path)
    trace_lines: List[TraceData] = []
    for sh, remap in zip(shards, remaps):
        for td in sh.trace_lines:
            if td.identity.get("ctx_unmapped"):
                # aggregate() flagged this line as carrying raw
                # (non-database) ctx ids; copy it verbatim — exactly what
                # a one-shot aggregation over the union would emit
                trace_lines.append(td)
                continue
            valid = (td.ctx >= 0) & (td.ctx < len(remap))
            if not bool(valid.all()):
                warnings.warn(
                    f"{sh.out_dir}/trace.db: {int((~valid).sum())} event(s)"
                    " reference ctx ids outside the shard tree; attributing"
                    " them to the root context", RuntimeWarning)
            ctx = np.where(valid, remap[np.clip(td.ctx, 0, len(remap) - 1)],
                           0)
            trace_lines.append(TraceData(td.identity, td.starts, td.ends,
                                         ctx))

    if retention is not None and not retention.is_noop:
        entries, trace_lines, report = \
            apply_retention(entries, trace_lines, retention)
        if retention_report is not None:
            retention_report.__dict__.update(report.__dict__)
        frames_c, parents_c, entries, trace_lines = _restrict_tree(
            frames_c, parents_c, entries, trace_lines)

    # stage the complete output in a sibling temp dir, then commit with a
    # directory swap (two renames).  This is what makes in-place epoch
    # extension safe — a crash never leaves out_dir as a half-written mix
    # of old and new files — and it sweeps away anything stale a replaced
    # out_dir held (old trace.db, converted .rtrc with dead ctx ids).
    import shutil
    import tempfile
    out_abs = os.path.abspath(out_dir)
    parent = os.path.dirname(out_abs) or "."
    os.makedirs(parent, exist_ok=True)
    work_dir = tempfile.mkdtemp(prefix=STAGING_PREFIX, dir=parent)

    db = write_database(work_dir, frames_c, parents_c, metrics,
                        entries, n_workers=max(1, n_workers), t0=t0,
                        timing_base={"merged_dbs": len(shards)})
    if trace_lines and trace_db:
        from repro.traceview.tracedb import build_db
        build_db(trace_lines, os.path.join(work_dir, "trace.db"))
    for name, data in (extra_files or {}).items():
        with open(os.path.join(work_dir, name), "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())

    inject.fault_point(FP_COMMIT_PRE_SWAP)
    backup = out_abs + PRE_MERGE_SUFFIX
    if os.path.lexists(backup):       # leftover of a crashed prior merge
        shutil.rmtree(backup, ignore_errors=True)
    if os.path.lexists(out_abs):
        # only ever replace a database directory (or an empty one) — a
        # typo'd -o must not vaporize unrelated files
        if not os.path.isdir(out_abs) or (
                os.listdir(out_abs)
                and not os.path.exists(os.path.join(out_abs, "meta.json"))):
            shutil.rmtree(work_dir, ignore_errors=True)
            raise ValueError(
                f"{out_dir}: exists and is not a database directory "
                "(no meta.json); refusing to replace it")
        os.rename(out_abs, backup)
        inject.fault_point(FP_COMMIT_MID_SWAP)
        os.rename(work_dir, out_abs)
        inject.fault_point(FP_COMMIT_POST_SWAP)
        shutil.rmtree(backup, ignore_errors=True)
    else:
        os.rename(work_dir, out_abs)
        inject.fault_point(FP_COMMIT_POST_SWAP)
    if remaps_out is not None:
        remaps_out.extend(remaps)
    return Database(out_dir, db.frames, db.parents, db.metrics,
                    db.profile_ids, db.stats)


def recover_interrupted_swap(out_dir: str) -> Optional[str]:
    """Repair the directory state a merge killed mid-commit leaves
    behind — the restart half of the intact-or-previous guarantee.

    Returns what was done (``"restored"`` — the previous database was
    parked at ``<out>.pre-merge`` with nothing at ``out_dir``, so it is
    renamed back; ``"cleaned"`` — the swap completed but the backup's
    removal didn't, so the stale backup is dropped) or ``None`` when the
    state is already consistent.  Always sweeps dead staging
    directories.  The fleet daemon runs this before every poll
    (``repro.fleet.daemon``)."""
    import shutil
    out_abs = os.path.abspath(out_dir)
    parent = os.path.dirname(out_abs) or "."
    if os.path.isdir(parent):
        for fn in os.listdir(parent):
            if fn.startswith(STAGING_PREFIX):
                shutil.rmtree(os.path.join(parent, fn),
                              ignore_errors=True)
    backup = out_abs + PRE_MERGE_SUFFIX
    if not os.path.lexists(backup):
        return None
    if not os.path.lexists(out_abs):
        os.rename(backup, out_abs)      # crash between the two renames
        return "restored"
    shutil.rmtree(backup, ignore_errors=True)   # crash before cleanup
    return "cleaned"


def _restrict_tree(frames: List[Frame], parents: np.ndarray, entries: list,
                   trace_lines: List[TraceData]):
    """Drop every context no surviving profile covers (and no surviving
    mapped trace line references), then renumber canonically.

    Coverage sets are parent-closed by construction (every profile path
    node maps; expansion intermediates are ancestors of mapped nodes),
    so the kept set is ancestor-closed and the compressed numbering of
    an already-canonical tree stays canonical — the restricted tree is
    exactly what re-aggregating the survivors builds (``canonical_order``
    is re-run as cheap insurance).
    """
    n = len(frames)
    referenced = [np.zeros(0, np.int64)]
    for e in entries:
        referenced.append(e[4])
    for td in trace_lines:
        if not td.identity.get("ctx_unmapped"):
            referenced.append(np.asarray(td.ctx, np.int64))
    keep_ids = ancestor_closure(np.concatenate(referenced),
                                np.asarray(parents, np.int64))
    sub = np.full(n, -1, np.int64)
    sub[keep_ids] = np.arange(len(keep_ids))
    frames_r = [frames[int(i)] for i in keep_ids]
    parents_r = np.where(np.asarray(parents, np.int64)[keep_ids] >= 0,
                         sub[np.asarray(parents, np.int64)[keep_ids]], -1)
    new2 = canonical_order(frames_r, parents_r)
    frames_r, parents_r = apply_order(frames_r, parents_r, new2)
    conv = new2[sub]          # old id -> restricted canonical id (kept only)
    entries = [(ident, conv[ctx], met, val, np.sort(conv[cover]))
               for ident, ctx, met, val, cover in entries]
    out_lines = []
    for td in trace_lines:
        if td.identity.get("ctx_unmapped"):
            out_lines.append(td)
        else:
            out_lines.append(TraceData(td.identity, td.starts, td.ends,
                                       conv[np.asarray(td.ctx, np.int64)]))
    return frames_r, parents_r, entries, out_lines


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def summarize(db: Database, in_dirs: Sequence[str]) -> str:
    """Deterministic post-merge report (golden-tested): counts only, no
    timings or absolute paths."""
    nnz = sum(len(pv.values) for pv in read_pms(db.pms_path()))
    lines = [
        f"MERGE  {len(in_dirs)} database(s) -> "
        f"{os.path.basename(os.path.normpath(db.out_dir))}",
        f"  inputs:   "
        + " ".join(sorted(os.path.basename(os.path.normpath(d))
                          for d in in_dirs)),
        f"  profiles: {len(db.profile_ids)}",
        f"  contexts: {len(db.frames)}",
        f"  metrics:  {len(db.metrics)}",
        f"  nnz:      {nnz}",
    ]
    tpath = db.trace_db_path()
    if os.path.exists(tpath):
        from repro.traceview.tracedb import TraceDB
        tdb = TraceDB(tpath)
        lines.append(f"  trace.db: {len(tdb)} line(s), "
                     f"{tdb.n_events} event(s)")
    else:
        lines.append("  trace.db: (none)")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.merge",
        description="Merge databases produced by aggregate() into one, "
                    "byte-identical to a one-shot aggregation over the "
                    "union of their profiles.")
    ap.add_argument("inputs", nargs="+", help="input database directories")
    ap.add_argument("-o", "--out", required=True,
                    help="output database directory")
    ap.add_argument("--workers", type=int, default=4,
                    help="writer worker threads (default 4)")
    ap.add_argument("--retain", default=None, metavar="SPEC",
                    help="retention policy, e.g. 'last=2,max=64,dedup' "
                         "(repro.core.retention)")
    ap.add_argument("--no-trace-db", action="store_true",
                    help="skip merging the shards' trace.db files (any "
                         "pre-existing OUT/trace.db is removed — its ctx "
                         "ids would be stale against the merged tree)")
    args = ap.parse_args(argv)
    retention = parse_retention(args.retain) if args.retain else None
    report = RetentionReport() if retention else None
    db = merge_databases(args.inputs, args.out, n_workers=args.workers,
                         trace_db=not args.no_trace_db,
                         retention=retention, retention_report=report)
    print(summarize(db, args.inputs))
    if report is not None:
        print(report.summary())
    return 0


if __name__ == "__main__":
    sys.exit(main())
