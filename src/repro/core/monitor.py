"""Measurement runtime (paper §4.1, Fig. 2): application threads, one GPU
monitor thread, and N tracing threads coordinated via wait-free SPSC
channels.

Message flow (the OpenCL/Level-Zero variant of §4.1, since on this stack the
completion "callback" runs on the application thread):

  app thread:   dispatch I  -> unwind stack, insert placeholder P
                            -> OP record (I, P, C_A) on its operation channel
                completion  -> ACTIVITY record (A, P, C_A) on the same
                               operation channel
  monitor:      drains every thread's operation channel; matches activities
                to operations; enqueues (A, P) on the owning thread's
                activity channel C_A; if tracing, routes (A, P) to the
                per-stream trace channel
  tracing thrd: polls its set of trace channels, appends to trace files
  app thread:   drains C_A (at the next dispatch or flush) and attributes
                A's metrics below P — heterogeneous calling context.

The monitor thread being the only producer into C_A (and the only consumer
of each C_O) is what keeps every queue single-producer/single-consumer —
the design point §4.1 makes explicitly.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.channels import BidirectionalChannel, ChannelSet, EMPTY, \
    SpscQueue
from repro.core.cct import CCTNode

OP = 0
ACTIVITY = 1
SHUTDOWN = 2


@dataclasses.dataclass
class GpuOperation:
    """Invocation record I."""
    corr_id: int
    kind: str                 # kernel | copy | sync
    name: str
    stream: int
    placeholder: CCTNode
    module_id: Optional[int] = None


@dataclasses.dataclass
class GpuActivity:
    """Measurement record A."""
    corr_id: int
    kind: str
    name: str
    stream: int
    t_start: int
    t_end: int
    bytes: int = 0
    samples: Optional[list] = None      # fine-grained records (§4.2)
    module_id: Optional[int] = None
    meta: Optional[dict] = None

    @property
    def duration(self) -> int:
        return self.t_end - self.t_start


class MonitorThread:
    """The GPU monitor thread of Fig. 2."""

    def __init__(self, channels: ChannelSet, tracing: bool = False,
                 n_tracing_threads: int = 1, poll_s: float = 1e-4):
        self._channels = channels
        self._tracing = tracing
        self._poll_s = poll_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run,
                                        name="repro-gpu-monitor",
                                        daemon=True)
        self._pending_ops: Dict[int, tuple] = {}   # corr_id -> (op, C_A)
        # True while a popped batch is being routed: quiesce() must not
        # declare the system drained based on empty queues alone, because
        # up to 1024 records can be in flight inside _drain_once
        self._routing = False
        # per-stream trace channels; monitor is the single producer
        self._trace_channels: Dict[int, SpscQueue] = {}
        self._trace_threads: List[TracingThread] = []
        self._n_tracing = max(1, n_tracing_threads)
        self.stats = {"ops": 0, "activities": 0, "routed": 0,
                      "counter_records": 0}
        self.trace_sink: Optional[Callable] = None   # (stream, A, P) -> None

    # -- lifecycle ----------------------------------------------------------
    def start(self):
        if self._tracing:
            for i in range(self._n_tracing):
                t = TracingThread(i, poll_s=self._poll_s)
                self._trace_threads.append(t)
                t.start()
        self._thread.start()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=10)
        for t in self._trace_threads:
            t.stop()

    def quiesce(self, timeout: float = 5.0):
        """Wait until all channels drain (used by flush)."""
        def queues_empty():
            if not all(ch.operation.empty for _, ch in
                       self._channels.items()):
                return False
            return not self._tracing or all(
                q.empty for q in self._trace_channels.values())

        def flags_clear():
            return not self._routing and \
                not any(t.busy for t in self._trace_threads)

        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            # queues / flags / queues / flags.  The flags are raised before
            # each batch pop, so flags reading False rules out a batch
            # popped from queues a preceding scan saw empty; the second
            # queue scan catches records a routing round moved *into* a
            # trace queue between the first scan and the flag read, and the
            # final flag read catches a tracer that popped that handoff
            # right before the second scan and is still appending it.
            if queues_empty() and flags_clear() \
                    and queues_empty() and flags_clear():
                return True
            time.sleep(self._poll_s)
        return False

    # -- the monitor loop ----------------------------------------------------
    def _run(self):
        while not self._stop.is_set():
            busy = self._drain_once()
            if not busy:
                time.sleep(self._poll_s)
        # final drain on shutdown
        for _ in range(16):
            if not self._drain_once():
                break

    def _drain_once(self) -> bool:
        """One polling round.  Records are popped and re-routed in batches
        (``try_pop_many`` / ``try_push_many``) so the per-item Python call
        overhead is paid once per batch; per-channel FIFO order is
        preserved because each batch keeps arrival order."""
        busy = False
        for tid, ch in self._channels.items():
            # flag raised *before* the pop: an observer sees either the
            # flag or a still-non-empty queue, never a silent in-flight gap
            self._routing = True
            recs = ch.operation.try_pop_many(1024)
            if not recs:
                self._routing = False
                continue
            busy = True
            routed: Dict[Any, List[tuple]] = {}   # owner channel -> batch
            traced: Dict[int, List[tuple]] = {}   # stream -> batch
            for rec in recs:
                tag = rec[0]
                if tag == OP:
                    _, op = rec
                    self._pending_ops[op.corr_id] = (op, ch)
                    self.stats["ops"] += 1
                elif tag == ACTIVITY:
                    _, act = rec
                    self.stats["activities"] += 1
                    if act.meta is not None and "counters" in act.meta:
                        self.stats["counter_records"] += 1
                    entry = self._pending_ops.pop(act.corr_id, None)
                    if entry is None:
                        continue
                    op, owner_ch = entry
                    routed.setdefault(owner_ch, []).append(
                        (act, op.placeholder))
                    if self._tracing:
                        traced.setdefault(act.stream, []).append(
                            (act, op.placeholder))
            # route (A, P) batches back to the owning application threads
            for owner_ch, batch in routed.items():
                self._push_all(owner_ch.activity, batch)
                self.stats["routed"] += len(batch)
            for stream, batch in traced.items():
                self._push_all(self._trace_queue(stream), batch)
            self._routing = False
        return busy

    def _push_all(self, q: SpscQueue, batch: List[tuple]):
        pos = q.try_push_many(batch)
        while pos < len(batch):
            time.sleep(self._poll_s)  # backpressure, consumer drains
            pos += q.try_push_many(batch[pos:])

    def _trace_queue(self, stream: int) -> SpscQueue:
        q = self._trace_channels.get(stream)
        if q is None:
            q = SpscQueue(1 << 16)
            self._trace_channels[stream] = q
            tt = self._trace_threads[stream % len(self._trace_threads)]
            tt.add_channel(stream, q, self.trace_sink)
        return q


class TracingThread(threading.Thread):
    """Records one or more GPU streams of activities (paper §4.1).

    The number of tracing threads is user-adjustable to balance tracing
    efficiency against tool resource usage.
    """

    def __init__(self, idx: int, poll_s: float = 1e-4):
        super().__init__(name=f"repro-tracer-{idx}", daemon=True)
        self._poll_s = poll_s
        self._stop_evt = threading.Event()
        self._channels: Dict[int, tuple] = {}
        self._pending: List[tuple] = []
        self.records: Dict[int, list] = {}
        # raised before each batch pop (see MonitorThread.quiesce)
        self.busy = False

    def add_channel(self, stream: int, q: SpscQueue, sink):
        # single assignment from the monitor thread; dict insert is atomic
        self._channels[stream] = (q, sink)

    def run(self):
        while not self._stop_evt.is_set():
            busy = self._poll()
            if not busy:
                time.sleep(self._poll_s)
        self._poll()

    def _poll(self) -> bool:
        progressed = False
        for stream, (q, sink) in list(self._channels.items()):
            self.busy = True    # raised before the pop, cleared after append
            batch = q.try_pop_many(1024)
            if not batch:
                self.busy = False
                continue
            progressed = True
            recs = self.records.setdefault(stream, [])
            for act, placeholder in batch:
                # 4th column: the dispatching app thread (rides
                # GpuActivity.meta from Profiler.dispatch) — write()
                # stamps it into the stream trace so aggregation can
                # convert the node id through that thread's gmap
                tid = (act.meta or {}).get("dispatch_tid", -1)
                recs.append((act.t_start, act.t_end, placeholder.node_id,
                             tid))
                if sink is not None:
                    sink(stream, act, placeholder)
            self.busy = False
        return progressed

    def stop(self):
        self._stop_evt.set()
        self.join(timeout=10)
