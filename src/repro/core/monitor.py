"""Measurement runtime (paper §4.1, Fig. 2): application threads, one GPU
monitor thread, and N tracing threads coordinated via wait-free,
per-thread record rings.

Message flow (the OpenCL/Level-Zero variant of §4.1, since on this stack
the completion "callback" runs on the application thread):

  app thread:   dispatch I  -> unwind stack, insert placeholder P
                            -> OP record (I, P) on its record ring
                completion  -> ACTIVITY record (A, P) + trace-lane row
                               on the same ring (one cursor publish each)
  monitor:      drains every thread's ring in epoch-stamped batches
                (``RecordRing.read_batch``); hands each batch to the
                profiler's record handler, which performs the deferred
                PC-sample draw, hardware-counter read, and metric
                attribution into the thread's *shadow* CCT; completed
                (A, P) pairs route onward to the per-stream trace
                channels; trace-lane rows become one buffered trace
                chunk per drain
  tracing thrd: polls its set of trace channels, appends to trace files
  app thread:   never sees the records again — the shadow CCTs graft
                into the per-thread trees at flush, when the owning
                threads are quiescent (profiler.py).

The ring's single producer (its app thread) and single consumer (the
monitor) keep every queue SPSC — the design point §4.1 makes
explicitly — and the monitor being the only caller of the record
handler is what lets the deferred draw, counter rotation, and shadow
attribution all run lock-free on one thread.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.channels import RingSet, SpscQueue
from repro.core.cct import CCTNode

OP = 0
ACTIVITY = 1
SHUTDOWN = 2


@dataclasses.dataclass(slots=True)
class GpuOperation:
    """Invocation record I."""
    corr_id: int
    kind: str                 # kernel | copy | sync
    name: str
    stream: int
    placeholder: CCTNode
    module_id: Optional[int] = None


@dataclasses.dataclass(slots=True)
class GpuActivity:
    """Measurement record A."""
    corr_id: int
    kind: str
    name: str
    stream: int
    t_start: int
    t_end: int
    bytes: int = 0
    samples: Optional[list] = None      # fine-grained records (§4.2)
    module_id: Optional[int] = None
    meta: Optional[dict] = None

    @property
    def duration(self) -> int:
        return self.t_end - self.t_start


# the record handler: (thread_id, payloads, lane_rows) ->
# (completed [(GpuActivity, placeholder)], stat increments)
RecordHandler = Callable[[int, List[Any], Any], tuple]


class MonitorThread:
    """The GPU monitor thread of Fig. 2."""

    def __init__(self, rings: RingSet, handler: RecordHandler,
                 tracing: bool = False, n_tracing_threads: int = 1,
                 poll_s: float = 1e-4, batch: int = 1024):
        self._rings = rings
        self._handler = handler
        self._tracing = tracing
        self._poll_s = poll_s
        self._batch = batch
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run,
                                        name="repro-gpu-monitor",
                                        daemon=True)
        # True while a popped batch is being processed: quiesce() must
        # not declare the system drained based on empty rings alone,
        # because up to ``batch`` records can be in flight here
        self._routing = False
        # per-stream trace channels; monitor is the single producer
        self._trace_channels: Dict[int, SpscQueue] = {}
        self._trace_threads: List[TracingThread] = []
        self._n_tracing = max(1, n_tracing_threads)
        self.stats = {"ops": 0, "activities": 0, "routed": 0,
                      "counter_records": 0, "drains": 0}
        # (stream, [(A, P), ...]) -> None, one call per drained batch
        self.trace_sink: Optional[Callable] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self):
        if self._tracing:
            for i in range(self._n_tracing):
                t = TracingThread(i, poll_s=self._poll_s)
                self._trace_threads.append(t)
                t.start()
        self._thread.start()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=10)
        for t in self._trace_threads:
            t.stop()

    def quiesce(self, timeout: float = 5.0):
        """Wait until all rings and trace channels drain (used by flush)."""
        def queues_empty():
            if not all(ring.empty for _, ring in self._rings.items()):
                return False
            return not self._tracing or all(
                q.empty for q in self._trace_channels.values())

        def flags_clear():
            return not self._routing and \
                not any(t.busy for t in self._trace_threads)

        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            # queues / flags / queues / flags.  The flags are raised before
            # each batch pop, so flags reading False rules out a batch
            # popped from rings a preceding scan saw empty; the second
            # queue scan catches records a routing round moved *into* a
            # trace queue between the first scan and the flag read, and the
            # final flag read catches a tracer that popped that handoff
            # right before the second scan and is still appending it.
            if queues_empty() and flags_clear() \
                    and queues_empty() and flags_clear():
                return True
            time.sleep(self._poll_s)
        return False

    # -- the monitor loop ----------------------------------------------------
    def _run(self):
        while not self._stop.is_set():
            busy = self._drain_once()
            if not busy:
                time.sleep(self._poll_s)
        # final drain on shutdown
        for _ in range(16):
            if not self._drain_once():
                break

    def _drain_once(self) -> bool:
        """One polling round: one epoch-stamped batch read per ring,
        handed wholesale to the record handler (deferred draw +
        attribution), completed activities routed to the per-stream
        trace channels.  Per-thread FIFO order is the ring's order; the
        cross-thread drain order is registration order, and nothing
        downstream depends on it (the handler attributes into
        per-thread shadow trees, and trace merges sort by timestamp)."""
        busy = False
        stats = self.stats
        for tid, ring in self._rings.items():
            # flag raised *before* the read: an observer sees either the
            # flag or a still-non-empty ring, never a silent in-flight gap
            self._routing = True
            got = ring.read_batch(self._batch)
            if got is None:
                self._routing = False
                continue
            busy = True
            payloads, lane, _epoch = got
            acts, hstats = self._handler(tid, payloads, lane)
            for k, v in hstats.items():
                stats[k] = stats.get(k, 0) + v
            stats["drains"] += 1
            if acts:
                stats["routed"] += len(acts)
                if self._tracing:
                    traced: Dict[int, List[tuple]] = {}
                    for pair in acts:
                        traced.setdefault(pair[0].stream, []).append(pair)
                    for stream, batch in traced.items():
                        self._push_all(self._trace_queue(stream), batch)
            self._routing = False
        return busy

    def _push_all(self, q: SpscQueue, batch: List[tuple]):
        pos = q.try_push_many(batch)
        while pos < len(batch):
            time.sleep(self._poll_s)  # backpressure, consumer drains
            pos += q.try_push_many(batch[pos:])

    def _trace_queue(self, stream: int) -> SpscQueue:
        q = self._trace_channels.get(stream)
        if q is None:
            q = SpscQueue(1 << 16)
            self._trace_channels[stream] = q
            tt = self._trace_threads[stream % len(self._trace_threads)]
            tt.add_channel(stream, q, self.trace_sink)
        return q


class TracingThread(threading.Thread):
    """Records one or more GPU streams of activities (paper §4.1).

    The number of tracing threads is user-adjustable to balance tracing
    efficiency against tool resource usage.
    """

    def __init__(self, idx: int, poll_s: float = 1e-4):
        super().__init__(name=f"repro-tracer-{idx}", daemon=True)
        self._poll_s = poll_s
        self._stop_evt = threading.Event()
        self._channels: Dict[int, tuple] = {}
        self._pending: List[tuple] = []
        self.records: Dict[int, list] = {}
        # raised before each batch pop (see MonitorThread.quiesce)
        self.busy = False

    def add_channel(self, stream: int, q: SpscQueue, sink):
        # single assignment from the monitor thread; dict insert is atomic
        self._channels[stream] = (q, sink)

    def run(self):
        while not self._stop_evt.is_set():
            busy = self._poll()
            if not busy:
                time.sleep(self._poll_s)
        self._poll()

    def _poll(self) -> bool:
        progressed = False
        for stream, (q, sink) in list(self._channels.items()):
            self.busy = True    # raised before the pop, cleared after append
            batch = q.try_pop_many(1024)
            if not batch:
                self.busy = False
                continue
            progressed = True
            recs = self.records.setdefault(stream, [])
            for act, placeholder in batch:
                # 4th column: the dispatching app thread (rides
                # GpuActivity.meta from the record handler) — write()
                # stamps it into the stream trace so aggregation can
                # convert the node id through that thread's gmap
                tid = (act.meta or {}).get("dispatch_tid", -1)
                recs.append((act.t_start, act.t_end, placeholder.node_id,
                             tid))
            if sink is not None:
                sink(stream, batch)   # one call (and one lock) per batch
            self.busy = False
        return progressed

    def stop(self):
        self._stop_evt.set()
        self.join(timeout=10)
