"""Trace files (paper §3, §4.1, §4.4): per CPU-thread / GPU-stream sequences
of (t_start, t_end, cct_node) events.

Per §4.4: CUPTI usually orders activities within a stream but the order is
undefined for OpenCL (and even Power9+CUPTI produced overlaps), so rather
than ordering online, the writer just *notes* out-of-order appends and the
post-mortem reader sorts when the flag is set.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import List, Tuple

import numpy as np

_REC = struct.Struct("<QQI")
MAGIC = b"RTRC"


class TraceWriter:
    def __init__(self, path: str, identity: dict):
        self.path = path
        self.identity = identity
        self._records: List[Tuple[int, int, int]] = []
        self._last_start = -1
        self.out_of_order = False

    def append(self, t_start: int, t_end: int, ctx_id: int) -> None:
        if t_start < self._last_start:
            self.out_of_order = True  # noted; sorted post-mortem (§4.4)
        self._last_start = t_start
        self._records.append((t_start, t_end, ctx_id))

    def close(self) -> None:
        import json
        with open(self.path, "wb") as f:
            hdr = json.dumps({"identity": self.identity,
                              "out_of_order": self.out_of_order}).encode()
            f.write(MAGIC + struct.pack("<I", len(hdr)) + hdr)
            arr = np.asarray(self._records, np.uint64).reshape(-1, 3)
            f.write(arr.tobytes())


@dataclasses.dataclass
class TraceData:
    identity: dict
    starts: np.ndarray
    ends: np.ndarray
    ctx: np.ndarray


def read_trace(path: str) -> TraceData:
    import json
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        (n,) = struct.unpack("<I", f.read(4))
        hdr = json.loads(f.read(n))
        arr = np.frombuffer(f.read(), np.uint64).reshape(-1, 3)
    starts, ends, ctx = arr[:, 0], arr[:, 1], arr[:, 2].astype(np.int64)
    if hdr.get("out_of_order"):
        order = np.argsort(starts, kind="stable")  # post-mortem sort (§4.4)
        starts, ends, ctx = starts[order], ends[order], ctx[order]
    return TraceData(hdr["identity"], starts.astype(np.int64),
                     ends.astype(np.int64), ctx)
