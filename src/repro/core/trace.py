"""Trace files (paper §3, §4.1, §4.4): per CPU-thread / GPU-stream sequences
of (t_start, t_end, cct_node) events.

Per §4.4: CUPTI usually orders activities within a stream but the order is
undefined for OpenCL (and even Power9+CUPTI produced overlaps), so rather
than ordering online, the writer just *notes* out-of-order appends and the
post-mortem reader sorts when the flag is set.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import List, Tuple

import numpy as np

_REC = struct.Struct("<QQI")
MAGIC = b"RTRC"

# GPU-stream traces written by ``Profiler.write()`` record, per event,
# the *dispatching app thread* alongside the CCT node: the thread index
# rides the high ctx bits and the identity's ``dispatch_profiles`` maps
# thread index -> profile basename.  Phase 5 of aggregation
# (``repro.core.pipeline.traceconv``) converts each event through its
# dispatcher's gmap — the fix for the former ``ctx_unmapped`` flagging
# of profiler GPU-stream traces.
DISPATCH_CTX_SHIFT = 32
DISPATCH_CTX_MASK = (1 << DISPATCH_CTX_SHIFT) - 1


def pack_dispatch_ctx(thread_idx, node_id):
    """Encode (dispatcher thread index, CCT node id) into one ctx value
    (array-friendly: accepts numpy arrays)."""
    import numpy as _np
    return ((_np.asarray(thread_idx, _np.uint64) << DISPATCH_CTX_SHIFT)
            | _np.asarray(node_id, _np.uint64))


class TraceWriter:
    def __init__(self, path: str, identity: dict):
        self.path = path
        self.identity = identity
        self._records: List[Tuple[int, int, int]] = []
        self._chunks: List[np.ndarray] = []
        # invariant: the start of the last event written through EITHER
        # append API — append after append_many must compare against the
        # chunk's last start (tests/test_traceview.py interleaves both)
        self._last_start = -1
        self.out_of_order = False

    def append(self, t_start: int, t_end: int, ctx_id: int) -> None:
        if t_start < self._last_start:
            self.out_of_order = True  # noted; sorted post-mortem (§4.4)
        self._last_start = t_start
        self._records.append((t_start, t_end, ctx_id))

    def append_many(self, starts, ends, ctx_ids) -> None:
        """Bulk append: one vectorized out-of-order check and one array
        copy instead of a Python call per event.  Produces byte-identical
        files to the equivalent sequence of ``append`` calls."""
        starts = np.asarray(starts)
        n = len(starts)
        if n == 0:
            return
        if self._records:   # preserve interleaving with scalar appends
            self._chunks.append(
                np.asarray(self._records, np.uint64).reshape(-1, 3))
            self._records = []
        s64 = starts.astype(np.int64)
        if int(s64[0]) < self._last_start or bool((s64[1:] < s64[:-1]).any()):
            self.out_of_order = True
        self._last_start = int(s64[-1])
        chunk = np.empty((n, 3), np.uint64)
        chunk[:, 0] = starts
        chunk[:, 1] = np.asarray(ends)
        chunk[:, 2] = np.asarray(ctx_ids)
        self._chunks.append(chunk)

    def append_chunk(self, chunk: "np.ndarray") -> None:
        """Adopt a prebuilt ``(n, 3)`` event chunk without re-packing —
        the buffered-trace path: the monitor thread gathers one chunk
        per ring drain (``RecordRing.read_batch`` trace-lane rows) and
        the writer takes it wholesale, one call per drain batch.  Chunk
        boundaries never reach the file (``close`` concatenates), so
        any batch split produces byte-identical output to per-event
        ``append`` calls in the same order."""
        chunk = np.asarray(chunk)
        if chunk.ndim != 2 or chunk.shape[1] != 3:
            raise ValueError("append_chunk wants an (n, 3) event array")
        if not len(chunk):
            return
        if chunk.dtype == np.int64:
            chunk = chunk.view(np.uint64)       # same bits, no copy
        elif chunk.dtype != np.uint64:
            chunk = chunk.astype(np.uint64)
        if self._records:   # preserve interleaving with scalar appends
            self._chunks.append(
                np.asarray(self._records, np.uint64).reshape(-1, 3))
            self._records = []
        s64 = chunk[:, 0].astype(np.int64)
        if int(s64[0]) < self._last_start or bool((s64[1:] < s64[:-1]).any()):
            self.out_of_order = True
        self._last_start = int(s64[-1])
        self._chunks.append(chunk)

    def close(self) -> None:
        import json
        with open(self.path, "wb") as f:
            hdr = json.dumps({"identity": self.identity,
                              "out_of_order": self.out_of_order}).encode()
            f.write(MAGIC + struct.pack("<I", len(hdr)) + hdr)
            parts = list(self._chunks)
            if self._records:
                parts.append(
                    np.asarray(self._records, np.uint64).reshape(-1, 3))
            if parts:
                arr = np.concatenate(parts)
            else:
                arr = np.zeros((0, 3), np.uint64)
            f.write(arr.tobytes())


@dataclasses.dataclass
class TraceData:
    identity: dict
    starts: np.ndarray
    ends: np.ndarray
    ctx: np.ndarray


def sorted_by_start(td: TraceData) -> TraceData:
    """Events stable-sorted by start time, as int64 arrays — the §4.4
    post-mortem sort, shared by the trace.db merge and the traceview
    interval stats.  Returns a new TraceData; arrays are views of the
    input when already sorted."""
    starts = np.asarray(td.starts, np.int64)
    ends = np.asarray(td.ends, np.int64)
    ctx = np.asarray(td.ctx, np.int64)
    if len(starts) > 1 and bool((starts[1:] < starts[:-1]).any()):
        order = np.argsort(starts, kind="stable")
        starts, ends, ctx = starts[order], ends[order], ctx[order]
    return TraceData(td.identity, starts, ends, ctx)


def read_trace_header(path: str) -> dict:
    """Read just the JSON header (identity + out-of-order flag) without
    touching the event data — what shard planning and dispatch
    resolution need from a trace file."""
    import json
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: not a trace file (bad magic)")
        (n,) = struct.unpack("<I", f.read(4))
        return json.loads(f.read(n))


def read_trace(path: str) -> TraceData:
    import json
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        (n,) = struct.unpack("<I", f.read(4))
        hdr = json.loads(f.read(n))
        arr = np.frombuffer(f.read(), np.uint64).reshape(-1, 3)
    starts, ends, ctx = arr[:, 0], arr[:, 1], arr[:, 2].astype(np.int64)
    if hdr.get("out_of_order"):
        order = np.argsort(starts, kind="stable")  # post-mortem sort (§4.4)
        starts, ends, ctx = starts[order], ends[order], ctx[order]
    return TraceData(hdr["identity"], starts.astype(np.int64),
                     ends.astype(np.int64), ctx)
