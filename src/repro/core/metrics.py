"""Metric kinds and the sparse per-node metric representation (paper §4.6).

HPCToolkit measures well over 100 metrics, most zero at most CCT nodes, so
``hpcrun`` partitions metrics into *kinds* (GPU kernel info kind, GPU
instruction-stall kind, CPU time kind, ...).  Each CCT node carries a list
of only the kinds it actually has, each kind a dense array of its member
metrics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class MetricKind:
    name: str
    metrics: Tuple[str, ...]      # member metric names, in kind-local order
    kind_id: int = -1


class MetricRegistry:
    """Assigns global metric ids; kinds are contiguous id ranges."""

    def __init__(self):
        self.kinds: List[MetricKind] = []
        self._kind_by_name: Dict[str, MetricKind] = {}
        self._global_ids: Dict[Tuple[str, str], int] = {}
        self.metric_names: List[str] = []

    def register_kind(self, name: str, metrics: Tuple[str, ...]) -> MetricKind:
        if name in self._kind_by_name:
            k = self._kind_by_name[name]
            assert k.metrics == tuple(metrics), f"kind {name} redefined"
            return k
        kind = MetricKind(name, tuple(metrics), kind_id=len(self.kinds))
        self.kinds.append(kind)
        self._kind_by_name[name] = kind
        for m in metrics:
            self._global_ids[(name, m)] = len(self.metric_names)
            self.metric_names.append(f"{name}/{m}")
        return kind

    def kind(self, name: str) -> MetricKind:
        return self._kind_by_name[name]

    def global_id(self, kind: str, metric: str) -> int:
        return self._global_ids[(kind, metric)]

    @property
    def n_metrics(self) -> int:
        return len(self.metric_names)


# Kernel-granularity hardware-counter kind (paper §6 "supplement
# fine-grained measurements with hardware performance counters").  The
# member layout is owned here so every profile agrees on the columns; the
# counter *taxonomy* (domains, units, multiplex capacities) lives in
# repro.counters.taxonomy and validates itself against this tuple.
GPU_COUNTER_KIND = "gpu_counter"
GPU_COUNTER_METRICS = (
    # compute domain
    "flops", "mxu_flops", "transcendental_ops",
    # memory domain
    "hbm_read_bytes", "hbm_write_bytes", "hbm_bytes",
    # collective domain
    "ici_wire_bytes", "collective_invocations",
    # scheduler domain
    "inst_executed", "active_ns",
    # tool domain (always collected, never multiplexed)
    "elapsed_ns", "replay_passes",
)

# The default registry mirrors the paper's examples (§4.5, §4.6, §7.1).
DEFAULT_KINDS = (
    ("cpu", ("time_ns", "samples")),
    # raw GPU-operation metrics: op count / time; copies carry bytes
    ("gpu_kernel", ("invocations", "time_ns", "registers_sum",
                    "static_smem_sum", "occupancy_sum")),
    ("gpu_copy", ("invocations", "time_ns", "bytes")),
    ("gpu_sync", ("invocations", "time_ns")),
    # fine-grained (PC-sampling analogue) metrics per GPU "instruction"
    ("gpu_inst", ("samples", "stall_compute", "stall_memory",
                  "stall_collective", "flops", "bytes")),
    # kernel-granularity hardware counters (repro.counters)
    (GPU_COUNTER_KIND, GPU_COUNTER_METRICS),
)


def default_registry() -> MetricRegistry:
    reg = MetricRegistry()
    for name, metrics in DEFAULT_KINDS:
        reg.register_kind(name, metrics)
    return reg


class NodeMetrics:
    """Sparse metric store for one CCT node: a metric-kind list."""

    __slots__ = ("_kinds",)

    def __init__(self):
        self._kinds: Dict[int, np.ndarray] = {}

    def add(self, kind: MetricKind, metric: str, value: float) -> None:
        arr = self._kinds.get(kind.kind_id)
        if arr is None:
            arr = np.zeros(len(kind.metrics), np.float64)
            self._kinds[kind.kind_id] = arr
        arr[kind.metrics.index(metric)] += value

    def add_vec(self, kind: MetricKind, values: np.ndarray) -> None:
        arr = self._kinds.get(kind.kind_id)
        if arr is None:
            self._kinds[kind.kind_id] = np.asarray(values, np.float64).copy()
        else:
            arr += values

    def merge_from(self, other: "NodeMetrics") -> None:
        """Fold another node's metrics into this one, kind by kind —
        the shadow-CCT graft (profiler flush) merging monitor-side
        attribution into the application thread's tree."""
        for kid, arr in other._kinds.items():
            mine = self._kinds.get(kid)
            if mine is None:
                self._kinds[kid] = arr.copy()
            else:
                mine += arr

    def get(self, kind: MetricKind, metric: str) -> float:
        arr = self._kinds.get(kind.kind_id)
        if arr is None:
            return 0.0
        return float(arr[kind.metrics.index(metric)])

    def kinds(self) -> Dict[int, np.ndarray]:
        return self._kinds

    @property
    def empty(self) -> bool:
        return not self._kinds

    def nonzero_items(self, registry: MetricRegistry):
        """Yields (global_metric_id, value) for non-zero metrics."""
        for kid, arr in sorted(self._kinds.items()):
            kind = registry.kinds[kid]
            base = registry.global_id(kind.name, kind.metrics[0])
            for i, v in enumerate(arr):
                if v != 0.0:
                    yield base + i, float(v)
