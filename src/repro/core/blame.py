"""GPU-idleness blame analysis (paper §7.2, §8.5 — the Nyx case study).

Identify intervals where *all* GPU streams are idle while at least one CPU
thread is active; partition the idle time equally across the active CPU
contexts.  CPU routines with high blame are optimization candidates (the
paper removes a cuCtxSynchronize and a JIT-compile stall this way).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.trace import TraceData


def idle_segments(cpu_traces: Sequence[TraceData],
                  gpu_traces: Sequence[TraceData]):
    """Yield (t0, t1, active cpu ctx set) for every elementary segment
    where zero GPU streams are active and >= 1 CPU thread is.

    Sweep-line over all interval boundaries; ``blame_gpu_idleness`` folds
    the segments, and ``traceview.stats.blame_over_time`` bins them — one
    sweep, one set of boundary semantics.
    """
    events: List[Tuple[int, int, int, int]] = []  # (t, kind, delta, ctx)
    GPU, CPU = 0, 1
    for tr in gpu_traces:
        for s, e in zip(tr.starts, tr.ends):
            events.append((int(s), GPU, +1, -1))
            events.append((int(e), GPU, -1, -1))
    for tr in cpu_traces:
        for s, e, c in zip(tr.starts, tr.ends, tr.ctx):
            events.append((int(s), CPU, +1, int(c)))
            events.append((int(e), CPU, -1, int(c)))
    if not events:
        return
    events.sort()
    gpu_active = 0
    cpu_active: Dict[int, int] = {}
    t_prev = events[0][0]
    for t, kind, delta, ctx in events:
        if t > t_prev and gpu_active == 0 and cpu_active:
            yield t_prev, t, set(cpu_active)
        t_prev = t
        if kind == GPU:
            gpu_active += delta
        else:
            n = cpu_active.get(ctx, 0) + delta
            if n <= 0:
                cpu_active.pop(ctx, None)
            else:
                cpu_active[ctx] = n


def blame_gpu_idleness(cpu_traces: Sequence[TraceData],
                       gpu_traces: Sequence[TraceData],
                       ) -> Tuple[Dict[int, float], float]:
    """Returns ({cpu ctx id: blamed idle ns}, total idle ns).

    Each all-streams-idle segment's length is split evenly among the CPU
    contexts active during it (normalized blame, §7.2).
    """
    blame: Dict[int, float] = {}
    total_idle = 0.0
    for t0, t1, active in idle_segments(cpu_traces, gpu_traces):
        seg = t1 - t0
        total_idle += seg
        share = seg / len(active)
        for c in active:
            blame[c] = blame.get(c, 0.0) + share
    return blame, total_idle


def blame_report(blame: Dict[int, float], total_idle: float, db,
                 top: int = 10) -> List[Tuple[str, float]]:
    """Ranked (context name, normalized blame) list, §7.2 style."""
    rows = []
    for ctx, ns in blame.items():
        name = (db.frames[ctx].pretty() if ctx < len(db.frames)
                else f"ctx{ctx}")
        rows.append((name, ns / total_idle if total_idle else 0.0))
    rows.sort(key=lambda r: -r[1])
    return rows[:top]
