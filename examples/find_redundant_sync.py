"""Reproduce the PeleC case study (paper §8.4.1): find redundant GPU
synchronizations with the derived metric  diff = sync_count - kernel_count.

    PYTHONPATH=src python examples/find_redundant_sync.py

The serving loop deliberately issues two device syncs per decode step with
no kernel between them (the paper's FillPatchIterator pattern: a sync in a
destructor that guards no computation).  The derived metric pinpoints the
calling contexts where syncs exceed kernel launches; in PeleC, fixing three
such contexts cut sync invocations 38% and sped the app 1.05x.
"""
import os
import tempfile

import numpy as np

from repro.configs import get_config
from repro.core.aggregate import aggregate
from repro.core.derived import SYNC_DIFF, database_columns
from repro.launch.serve import serve


def main():
    out = tempfile.mkdtemp(prefix="repro_syncdiff_")
    cfg = get_config("qwen2-1.5b").reduced()
    _, paths = serve(cfg, n_requests=2, batch=2, prompt_len=16, gen_len=6,
                     profile_dir=os.path.join(out, "prof"),
                     redundant_sync=True)
    profiles = [v for k, v in paths.items() if "trace" not in k]
    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=1,
                   n_threads=2)

    cols = database_columns(db)
    diff = SYNC_DIFF.evaluate(cols)
    syncs = cols["gpu_sync/invocations"]
    kernels = cols["gpu_kernel/invocations"]

    print("contexts where sync_count > kernel_count "
          "(candidates for removal, cf. paper Fig. 7):\n")
    order = np.argsort(-diff)
    shown = 0
    for gid in order:
        if diff[gid] <= 0 or shown >= 6:
            break
        # inclusive counts: skip pure ancestors, report the deepest frames
        kids_diff = [diff[c] for c, par in enumerate(db.parents)
                     if par == gid]
        if kids_diff and max(kids_diff, default=0) == diff[gid]:
            continue
        print(f"  diff={int(diff[gid]):4d}  syncs={int(syncs[gid]):4d} "
              f"kernels={int(kernels[gid]):4d}  "
              f"{db.frames[gid].pretty()}")
        shown += 1
    assert (diff > 0).any(), "expected to find the injected redundant syncs"
    print("\nfix: drop the guard-nothing sync (paper: -38% sync calls, "
          "1.05x end to end)")


if __name__ == "__main__":
    main()
