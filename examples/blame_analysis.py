"""Reproduce the Nyx case study (paper §8.5): attribute GPU idleness to the
CPU code executing while every GPU stream is idle.

    PYTHONPATH=src python examples/blame_analysis.py

A two-stream serving run is interleaved with deliberate CPU-side stalls
(the paper's culprits: cuCtxSynchronize before an already-synchronizing
copy, and JIT compilation at runtime).  The blame analysis partitions
all-streams-idle time across active CPU contexts and ranks them — the
paper used exactly this view to find and remove both stalls (10.6s ->
9.8s, 1.08x on 640 streams).
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate
from repro.core.blame import blame_gpu_idleness, blame_report
from repro.core.profiler import Profiler
from repro.core.trace import read_trace


def main():
    out = tempfile.mkdtemp(prefix="repro_blame_")
    f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
    x = jnp.ones((256, 256))
    compiled = f.lower(x).compile()

    prof = Profiler(os.path.join(out, "prof"), tracing=True, rng_seed=0)
    mid = prof.register_module("kernel_f", compiled.as_text())
    with prof:
        for i in range(6):
            with prof.dispatch("kernel", "kernel_f", stream=i % 2,
                               module_id=mid):
                jax.block_until_ready(compiled(x))
            if i == 2:
                with prof.cpu_region("runtime_jit_compile"):
                    time.sleep(0.05)      # the paper's JIT-at-runtime stall
            with prof.cpu_region("host_preprocessing"):
                time.sleep(0.01)
    paths = prof.write()

    profiles = [v for k, v in paths.items() if "trace" not in k
                and k.startswith("cpu")]
    cpu_trace_paths = [v for k, v in paths.items()
                       if k.startswith("cpu_trace")]
    # aggregation rewrites trace ctx ids into global calling-context ids
    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=1,
                   n_threads=1, trace_paths=cpu_trace_paths)
    cpu_traces = [read_trace(os.path.join(out, "db", os.path.basename(p)))
                  for p in cpu_trace_paths]
    gpu_traces = [read_trace(v) for k, v in paths.items()
                  if k.startswith("gpu_trace")]
    blame, idle = blame_gpu_idleness(cpu_traces, gpu_traces)
    print(f"total all-streams-idle time: {idle / 1e6:.1f} ms\n")
    print("GPU Idleness Blame (paper §7.2 tab), descending:")
    for name, frac in blame_report(blame, idle, db, top=8):
        print(f"  {frac:6.1%}  {name}")
    print("\npaper outcome: removing the two top culprits -> 1.08x "
          "end-to-end on 640 streams")


if __name__ == "__main__":
    main()
