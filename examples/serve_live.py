"""Always-on serving profiler (ISSUE 7): the full production loop on a
real (reduced) model — per-request windows, the overhead-budgeted
governor, and live telemetry export through a fleet daemon.

    PYTHONPATH=src python examples/serve_live.py [--arch qwen2-1.5b]

CI runs this as the serving smoke: the script *asserts* that the
profiler's steady-state dispatch-path overhead (measured by its own
accounting, after the governor settles) stayed under the budget, that
the governor actually throttled, that every request came back out of
the aggregated database with per-phase attribution, and that the
telemetry epochs folded into the fleet database exactly once.

Budget calibration: the dispatch path has a fixed per-dispatch cost the
fidelity ladder cannot remove, and a *reduced config on CPU* runs
decode steps in ~0.3ms — so the floor overhead fraction sits near 1x
here, where production GPU kernels (10-100x longer) would see a few
percent.  The default budget (2.5) gates the steady state with
headroom: it catches dispatch-path cost regressions, and the governed
steady state must also beat the unthrottled settle-phase fraction.
"""
import argparse
import os
import tempfile

from repro.configs import get_config
from repro.core.aggregate import aggregate
from repro.fleet.client import DirectoryTransport, ShardProducer
from repro.fleet.daemon import FleetDaemon
from repro.launch.serve import serve
from repro.serving import GovernorConfig, ServingProfiler, read_telemetry
from repro.traceview.stats import (request_attribution,
                                   request_latency_percentiles)
from repro.traceview.tracedb import TraceDB


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=6)
    ap.add_argument("--budget", type=float, default=2.5,
                    help="steady-state overhead gate (tool ns / app ns); "
                         "see the calibration note in the module docstring")
    args = ap.parse_args(argv)

    out = tempfile.mkdtemp(prefix="repro_serve_live_")
    # the fleet side: a daemon spool + a producer the profiler exports
    # telemetry through (and polls for backpressure)
    daemon = FleetDaemon(os.path.join(out, "fleet_db"),
                         os.path.join(out, "spool"))
    producer = ShardProducer(os.path.join(out, "outbox"),
                             DirectoryTransport(daemon.incoming_dir),
                             daemon_spool_soft=32)
    sp = ServingProfiler(os.path.join(out, "prof"),
                         governor=GovernorConfig(budget=0.30, interval=4),
                         producer=producer, export_every_s=0.0,
                         sample_rate_hz=1e6)

    cfg = get_config(args.arch).reduced()
    with sp:
        # settle pass: the governor starts at full fidelity and walks
        # down; the gated steady-state window opens after it
        serve(cfg, n_requests=args.requests, batch=args.batch,
              prompt_len=args.prompt_len, gen_len=args.gen_len,
              serving=sp, rid_prefix="settle-")
        c0 = dict(sp.profiler.overhead_counters())
        settle_frac = c0["tool_ns"] / max(c0["app_ns"], 1)
        toks, _ = serve(cfg, n_requests=args.requests, batch=args.batch,
                        prompt_len=args.prompt_len, gen_len=args.gen_len,
                        serving=sp)
        c1 = sp.profiler.overhead_counters()
        steady_frac = (c1["tool_ns"] - c0["tool_ns"]) \
            / max(c1["app_ns"] - c0["app_ns"], 1)
        sp.profiler.flush()
        paths = sp.write()
        status = sp.status()
        governor = sp.governor.state()
    print(f"served {toks.shape[0]} requests x {toks.shape[1]} tokens "
          "(x2 passes)")
    print("live status:", {k: round(v, 4) for k, v in
                           sorted(status.items())})
    print(f"governor: level {governor['level']} ({governor['level_name']}),"
          f" {governor['throttle_downs']} down / "
          f"{governor['throttle_ups']} up")
    print(f"overhead: settle {settle_frac:.2f}x -> steady "
          f"{steady_frac:.2f}x (budget {args.budget})")

    # the smoke gates: the governor throttled, and the steady state it
    # reached is inside the calibrated budget and below the settle phase
    assert governor["throttle_downs"] > 0, "governor never throttled"
    assert steady_frac <= args.budget, \
        f"steady overhead {steady_frac:.2f} over budget {args.budget}"
    assert steady_frac < max(settle_frac, 1.0), \
        f"governor did not reduce overhead ({settle_frac:.2f} -> " \
        f"{steady_frac:.2f})"

    # per-request attribution out of the aggregated database (the
    # settle pass rode distinct "settle-" ids, so the measured pass
    # reads back clean)
    profs = [v for k, v in sorted(paths.items()) if "trace" not in k]
    traces = [v for k, v in sorted(paths.items()) if "trace" in k]
    db = aggregate(profs, os.path.join(out, "db"), n_ranks=1, n_threads=1,
                   trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    rows = [r for r in request_attribution(lines, db)
            if not r[0].startswith("settle-")]
    n_batches = (args.requests + args.batch - 1) // args.batch
    assert len(rows) == n_batches, (len(rows), n_batches)
    print("\nper-request GPU attribution:")
    for rid, total, phases in rows:
        split = ", ".join(f"{p} {ns / 1e6:.2f}ms"
                          for p, ns in sorted(phases.items()))
        print(f"  {rid:<10} {total / 1e6:8.2f}ms  ({split})")
    pct = request_latency_percentiles(lines, db)
    for phase, qs in sorted(pct.items()):
        print(f"  {phase} latency p50={qs[50.0]:.2f}ms "
              f"p99={qs[99.0]:.2f}ms")

    # telemetry epochs fold into the fleet database exactly once
    daemon.poll_once()
    series = read_telemetry(daemon.database())
    assert len(series) == int(status["epochs_exported"]), \
        (len(series), status["epochs_exported"])
    print(f"\ntelemetry: {len(series)} epochs in the fleet database, "
          f"last tok_s={series[-1]['tok_s']:.1f}")
    print(f"artifacts under {out}")


if __name__ == "__main__":
    main()
