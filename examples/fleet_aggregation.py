"""Crash-tolerant fleet aggregation with exactly-once shard ingest
(ISSUE 6; docs/fleet.md).

    PYTHONPATH=src python examples/fleet_aggregation.py

Three producer hosts each build a shard database, package it into a
checksummed envelope, and deliver it to a ``FleetDaemon`` spool; the
daemon folds the shards into one fleet database.  The demo then breaks
things on purpose:

1. a **torn delivery** (truncated envelope) — quarantined with a
   ``.reason`` file, never a crash;
2. a **duplicate redelivery** of every shard — the journal makes it a
   no-op (exactly-once);
3. a **crash in the middle of a fold** (``repro.ft.inject``) followed
   by a restart — the replay converges on the byte-exact one-shot
   ``aggregate()`` over all shards.

jax-free: profiles are written directly with the profmt/trace writers,
so this runs in milliseconds.
"""
import os
import shutil
import tempfile

import numpy as np

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST
from repro.core.metrics import default_registry
from repro.core.profmt import write_profile
from repro.core.trace import TraceWriter
from repro.fleet import (DirectoryTransport, FleetDaemon, Journal,
                        ShardProducer)
from repro.ft import InjectedCrash, injected


def measure_host(d, rank_base, n_profiles=3, seed=None):
    """One host's measurement: profiles + traces with fleet-unique
    ranks (as a real multi-host job would have)."""
    os.makedirs(d, exist_ok=True)
    rng = np.random.default_rng(seed if seed is not None else rank_base)
    reg = default_registry()
    cpu = reg.kind("cpu")
    paths, traces = [], []
    for p in range(n_profiles):
        rank = rank_base + p
        cct, nodes = CCT(), []
        for _ in range(int(rng.integers(15, 30))):
            frames = [Frame(HOST, f"fn{rng.integers(8)}",
                            f"file{rng.integers(3)}.py",
                            int(rng.integers(30)))
                      for _ in range(1 + int(rng.integers(3)))]
            node = cct.insert_path(frames)
            node.metrics.add(cpu, "time_ns", float(rng.integers(1, 9000)))
            nodes.append(node)
        path = os.path.join(d, f"r{rank}.rpro")
        write_profile(path, cct, reg, {"rank": rank, "type": "cpu"}, [])
        paths.append(path)
        tw = TraceWriter(path.replace(".rpro", ".rtrc"), {"rank": rank})
        t = 0
        for node in nodes[:6]:
            tw.append(t, t + 10, node.node_id)
            t += 10
        tw.close()
        traces.append(path.replace(".rpro", ".rtrc"))
    return paths, traces


def db_bytes(d):
    return {fn: open(os.path.join(d, fn), "rb").read()
            for fn in ("stats.npz", "metrics.cms", "metrics.pms",
                       "trace.db")}


def main():
    work = tempfile.mkdtemp(prefix="fleet_demo_")
    db = os.path.join(work, "fleet")
    spool = os.path.join(work, "spool")

    # --- three producer hosts, one shard database each -----------------
    shard_dbs, all_paths, all_traces = [], [], []
    for host in range(3):
        paths, traces = measure_host(
            os.path.join(work, f"host{host}"), rank_base=10 * host)
        out = os.path.join(work, f"shard{host}")
        aggregate(paths, out, trace_paths=traces)
        shard_dbs.append(out)
        all_paths += paths
        all_traces += traces

    daemon = FleetDaemon(db, spool, n_workers=1)
    producer = ShardProducer(os.path.join(work, "outbox"),
                             DirectoryTransport(daemon.incoming_dir),
                             producer="demo", sleep=lambda s: None)
    for sd in shard_dbs[:2]:
        producer.stage(sd)
    print("delivered:", producer.deliver().delivered)
    rep = daemon.poll_once()
    print("fold #1:", rep.summary())

    # --- a torn delivery quarantines, never crashes ---------------------
    sid = producer.stage(shard_dbs[2], epoch=1)   # returns the shard id
    env = os.path.join(producer.outbox_dir, sid + ".shard")
    torn = os.path.join(daemon.incoming_dir, "torn.shard")
    with open(env, "rb") as f:
        payload = f.read()
    with open(torn, "wb") as f:
        f.write(payload[:len(payload) - 40])   # truncate: torn delivery
    os.unlink(env)                             # host 2 re-stages later
    rep = daemon.poll_once()
    print("fold #2:", rep.summary())
    qdir = daemon.quarantine_dir
    for fn in sorted(os.listdir(qdir)):
        if fn.endswith(".reason"):
            print("  quarantined:", fn, "->",
                  open(os.path.join(qdir, fn)).read().strip())

    # --- duplicate redelivery is a no-op (exactly-once) -----------------
    for sd in shard_dbs[:2]:
        producer.stage(sd)                     # content-addressed: same ids
    producer.deliver()
    rep = daemon.poll_once()
    assert not rep.applied and len(rep.duplicates) == 2, rep.summary()
    print("fold #3 (redelivery):", rep.summary())

    # --- crash mid-fold, restart, replay --------------------------------
    producer.stage(shard_dbs[2])
    producer.deliver()
    try:
        with injected("daemon.fold.post_commit"):
            daemon.poll_once()
    except InjectedCrash as e:
        print(f"daemon killed at fault point {e.label!r}")
    daemon = FleetDaemon(db, spool, n_workers=1)   # the restart path
    rep = daemon.poll_once()
    print("fold #4 (after restart):", rep.summary())

    # --- the invariant: byte-identical to the one-shot aggregate --------
    want = os.path.join(work, "want")
    aggregate(all_paths, want, trace_paths=all_traces)
    assert db_bytes(db) == db_bytes(want)
    journal = Journal.load(db)
    print(f"byte-identical to one-shot aggregate over "
          f"{len(all_paths)} profiles; journal: "
          f"{len(journal.applied)} shards, generation {journal.generation}")
    shutil.rmtree(work)


if __name__ == "__main__":
    main()
