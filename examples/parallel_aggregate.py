"""Parallel shard-driver aggregation + retention-windowed continuous
profiling (ISSUE 5; docs/pipeline.md).

    PYTHONPATH=src python examples/parallel_aggregate.py

Two production shapes on one measured workload:

1. **Parallel aggregation.**  ``aggregate(..., workers=4)`` partitions
   the profiles into shards, runs the pipeline's phases 1-4 in worker
   processes (no shared GIL), and folds the shard results through
   ``merge_databases`` — byte-identical to the serial one-shot by
   construction, verified below.
2. **Retention-windowed continuous profiling.**  A long-running job
   extends its database in place every epoch
   (``aggregate(..., base_db=...)``) under a ``keep_last_epochs=2``
   retention window: old epochs retire at merge time, and the database
   stays byte-identical to re-aggregating only the surviving epochs —
   bounded storage without recomputation.
"""
import itertools
import os
import tempfile

from repro.core.aggregate import aggregate
from repro.core.merge import summarize
from repro.core.profiler import Profiler
from repro.core.retention import RetentionPolicy

clock_src = itertools.count(0, 250_000)    # deterministic 0.25 ms ticks


def measure_epoch(out, epoch, n_ranks=2, n_steps=5):
    """One epoch's measurement across ranks: CPU threads dispatching
    kernels on two GPU streams (every trace event records its
    dispatching thread, so GPU-stream traces convert exactly)."""
    profiles, traces = [], []
    for rank in range(n_ranks):
        prof = Profiler(os.path.join(out, f"epoch{epoch}_rank{rank}"),
                        tracing=True, rank=rank, unwind=False,
                        clock=lambda: next(clock_src),
                        tag=f"epoch{epoch}")
        with prof:
            for i in range(n_steps):
                with prof.dispatch("kernel", f"step_e{epoch}",
                                   stream=i % 2, duration_ns=2_000_000):
                    pass
                with prof.cpu_region(f"host_epoch{epoch}"):
                    next(clock_src)
            assert prof.flush(timeout=30)
        written = prof.write()
        profiles += [v for k, v in written.items() if "trace" not in k]
        traces += [v for k, v in written.items() if "trace" in k]
    return profiles, traces


def db_fingerprint(d):
    return {fn: open(os.path.join(d, fn), "rb").read()
            for fn in ("stats.npz", "metrics.cms", "metrics.pms",
                       "trace.db")}


def main():
    out = tempfile.mkdtemp(prefix="repro_parallel_")

    # ---- shape 1: 4-worker parallel aggregation ---------------------------
    profiles, traces = measure_epoch(out, epoch=1)
    serial_db = os.path.join(out, "db_serial")
    aggregate(profiles, serial_db, trace_paths=traces, driver="serial")

    parallel_db = os.path.join(out, "db_parallel")
    timing = {}
    db = aggregate(profiles, parallel_db, trace_paths=traces,
                   workers=4, driver="process", timing=timing)
    print(summarize(db, [parallel_db]))
    print(f"\ndriver={timing['driver']} workers={timing['workers']} "
          f"shards={timing['n_shards']} "
          f"(shard wall {timing['shard_wall_s']:.2f}s, "
          f"fold {timing['fold_s']:.2f}s)")

    assert db_fingerprint(parallel_db) == db_fingerprint(serial_db), \
        "process driver diverged from the serial one-shot"
    print("4-worker aggregation is byte-identical to serial: OK")

    # ---- shape 2: continuous profiling with a retention window ------------
    window = RetentionPolicy(keep_last_epochs=2)
    live_db = os.path.join(out, "db_live")
    aggregate(profiles, live_db, trace_paths=traces)
    by_epoch = {1: (profiles, traces)}
    for epoch in (2, 3, 4):
        p, t = measure_epoch(out, epoch)
        by_epoch[epoch] = (p, t)
        # extend in place; epochs beyond the window retire at merge time
        db = aggregate(p, live_db, base_db=live_db, trace_paths=t,
                       retention=window, workers=2)
        tags = sorted({v["tag"] for v in db.profile_ids.values()})
        print(f"\nafter epoch {epoch}: {len(db.profile_ids)} profiles, "
              f"epochs kept: {' '.join(tags)}")

        # the retention contract: byte-identical to re-aggregating ONLY
        # the surviving epochs from their original measurements
        survivors = [e for e in by_epoch if e > epoch - 2]
        sp = [x for e in survivors for x in by_epoch[e][0]]
        st = [x for e in survivors for x in by_epoch[e][1]]
        want = os.path.join(out, f"db_want_{epoch}")
        aggregate(sp, want, trace_paths=st)
        assert db_fingerprint(live_db) == db_fingerprint(want), \
            "retained database diverged from re-aggregated survivors"
    print("\nretention window == re-aggregation of survivors, every "
          "epoch: OK")


if __name__ == "__main__":
    main()
