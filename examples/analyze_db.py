"""hpcviewer-style analysis of an existing database: the three code-centric
views (top-down / bottom-up / flat), the thread-centric plot, and a custom
derived metric — all against a database produced by any other example.

    PYTHONPATH=src python examples/analyze_db.py [db_dir]

Without an argument it first produces a database by profiling a short
multi-thread run.
"""
import os
import sys
import tempfile
import threading

import jax
import jax.numpy as jnp

from repro.core.aggregate import Database, aggregate
from repro.core.derived import DerivedMetric, database_columns
from repro.core.profiler import Profiler
from repro.core.sparse import CMSReader
from repro.core import viewer


def make_db(out: str) -> str:
    f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
    x = jnp.ones((256, 256))
    compiled = f.lower(x).compile()
    prof = Profiler(os.path.join(out, "prof"), tracing=False, rng_seed=0,
                    unwind=False)
    mid = prof.register_module("kern", compiled.as_text())

    def worker(n):
        for _ in range(n):
            with prof.dispatch("kernel", "kern", stream=0, module_id=mid):
                jax.block_until_ready(compiled(x))

    with prof:
        ts = [threading.Thread(target=worker, args=(3 + i,))
              for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    paths = prof.write()
    profiles = [v for k, v in paths.items() if "trace" not in k]
    aggregate(profiles, os.path.join(out, "db"), n_ranks=2, n_threads=2)
    return os.path.join(out, "db")


def main():
    if len(sys.argv) > 1:
        db_dir = sys.argv[1]
    else:
        db_dir = make_db(tempfile.mkdtemp(prefix="repro_analyze_"))
    db = Database.load(db_dir)

    metric = "gpu_inst/samples" if "gpu_inst/samples" in db.metrics \
        else db.metrics[0]
    print(viewer.top_down(db, metric, max_depth=6, max_children=4))
    print()
    print(viewer.bottom_up(db, metric, top=5))
    print()
    print(viewer.flat(db, metric, top=8))

    # thread-centric: one CCT node's metric across all profiles
    cms = CMSReader(db.cms_path())
    mid = db.metric_id("gpu_kernel/invocations")
    best, best_n = 0, 0
    for ctx in cms.contexts():
        pids, _ = cms.metric_values(int(ctx), mid)
        if len(pids) > best_n:
            best, best_n = int(ctx), len(pids)
    pids, vals = viewer.thread_plot(db, cms, best, "gpu_kernel/invocations")
    print(f"\nthread-centric plot of {db.frames[best].pretty()!r}:")
    for p, v in zip(pids, vals):
        ident = db.profile_ids.get(int(p), {})
        print(f"  profile {p} {ident.get('type', '?')}: "
              + "#" * int(v) + f" {v:.0f}")

    # a user-authored derived metric (spreadsheet formula, §7.1)
    imbalance = DerivedMetric(
        "imbalance", "gpu_kernel__time_ns / cpu__time_ns")
    cols = database_columns(db)
    try:
        vals = imbalance.evaluate(cols)
        print(f"\nderived 'gpu/cpu time' at root: {vals[0]:.3f}")
    except KeyError:
        pass


if __name__ == "__main__":
    main()
