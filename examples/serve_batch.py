"""Batched serving under measurement: prefill + decode dispatches with
per-stream traces and a utilization report.

    PYTHONPATH=src python examples/serve_batch.py [--arch qwen2-1.5b]
"""
import argparse
import os
import tempfile

from repro.configs import get_config
from repro.core.aggregate import aggregate
from repro.core.derived import GPU_UTILIZATION, database_columns
from repro.core import viewer
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    out = tempfile.mkdtemp(prefix="repro_serve_")
    cfg = get_config(args.arch).reduced()
    toks, paths = serve(cfg, n_requests=args.requests, batch=args.batch,
                        prompt_len=args.prompt_len, gen_len=args.gen_len,
                        profile_dir=os.path.join(out, "prof"))
    print(f"generated {toks.shape[0]} x {toks.shape[1]} tokens")

    profiles = [v for k, v in paths.items() if "trace" not in k]
    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=1,
                   n_threads=2)
    print()
    print(viewer.top_down(db, "gpu_kernel/time_ns", max_depth=6,
                          max_children=4))
    cols = database_columns(db)
    util = GPU_UTILIZATION.evaluate(cols)
    print(f"\nGPU utilization at root: {util[0]:.1%} "
          "(derived metric, paper §4.5)")
    print(f"artifacts under {out}")


if __name__ == "__main__":
    main()
