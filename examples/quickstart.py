"""Quickstart: measure a JAX program with the HPCToolkit-analogue stack.

    PYTHONPATH=src python examples/quickstart.py

1. jit-compile a small function ("the GPU kernel"),
2. register its compiled HLO as the loaded GPU binary (hpcstruct input),
3. dispatch it a few times under the profiler (hpcrun),
4. aggregate the resulting profiles (hpcprof),
5. print the top-down / flat profile views (hpcviewer).
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate
from repro.core.profiler import Profiler
from repro.core import viewer


def attention_like(x, w):
    s = jnp.einsum("bqd,bkd->bqk", x, x) * x.shape[-1] ** -0.5
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, x) @ w


def main():
    out = tempfile.mkdtemp(prefix="repro_quickstart_")
    x = jnp.ones((4, 128, 64))
    w = jnp.ones((64, 64)) * 0.01
    step = jax.jit(attention_like)
    compiled = step.lower(x, w).compile()

    prof = Profiler(os.path.join(out, "measure"), tracing=True, rng_seed=0)
    module_id = prof.register_module("attention_like", compiled.as_text())
    with prof:
        for i in range(10):
            with prof.dispatch("kernel", "attention_like", stream=0,
                               module_id=module_id):
                jax.block_until_ready(compiled(x, w))
        with prof.dispatch("copy", "weights_h2d", stream=1,
                           nbytes=w.size * 4):
            pass
    paths = prof.write()
    print(f"wrote {len(paths)} profile/trace files under {out}/measure\n")

    profiles = [v for k, v in paths.items()
                if "trace" not in k]
    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=2,
                   n_threads=2)
    print(viewer.top_down(db, "gpu_inst/samples", max_depth=6))
    print()
    print(viewer.flat(db, "gpu_inst/samples", top=8))
    print(f"\ndatabase: {out}/db")


if __name__ == "__main__":
    main()
