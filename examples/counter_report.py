"""Hardware-counter kernel measurement, end to end (paper §6).

    PYTHONPATH=src python examples/counter_report.py

1. jit-compile a small attention-like step ("the GPU kernel"),
2. enable counter collection (repro.counters) in serialized-replay mode
   on rank 0 and single-pass multiplexing on rank 1,
3. dispatch the kernel under both profilers,
4. aggregate the two ranks' profiles — counter values merge with the
   same bitwise-deterministic accumulator fold as every other kind,
5. print the multiplex schedule, the per-kernel counter table with the
   derived occupancy / efficiency columns, and the trace-side top-kernel
   join.
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate
from repro.core import viewer
from repro.counters import ALL_COUNTERS, build_schedule, describe


def attention_like(x, w):
    s = jnp.einsum("bqd,bkd->bqk", x, x) * x.shape[-1] ** -0.5
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, x) @ w


REQUEST = ["flops", "mxu_flops", "hbm_read_bytes", "hbm_write_bytes",
           "hbm_bytes", "active_ns", "inst_executed"]


def main():
    from repro.core.profiler import Profiler

    out = tempfile.mkdtemp(prefix="repro_counters_")
    x = jnp.ones((4, 128, 64))
    w = jnp.ones((64, 64)) * 0.01
    compiled = jax.jit(attention_like).lower(x, w).compile()

    print("counter catalog:")
    print(describe())
    print()
    print(build_schedule(ALL_COUNTERS).describe())
    print()

    profiles = []
    for rank, replay in ((0, True), (1, False)):
        prof = Profiler(os.path.join(out, f"measure_r{rank}"),
                        tracing=True, rank=rank, rng_seed=rank)
        sched = prof.enable_counters(REQUEST, replay=replay)
        mid = prof.register_module("attention_like", compiled.as_text(),
                                   cost=compiled.cost_analysis())
        with prof:
            for i in range(6):
                with prof.dispatch("kernel", "attention_like", stream=0,
                                   module_id=mid):
                    jax.block_until_ready(compiled(x, w))
        paths = prof.write()
        profiles += [v for k, v in paths.items() if "trace" not in k]
        mode = "replay" if replay else "single-pass multiplex"
        print(f"rank {rank} ({mode}): {sched.n_passes} pass(es)/kernel, "
              f"{prof._monitor.stats['counter_records']} counter records")

    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=2,
                   n_threads=2)
    print()
    print(viewer.counter_table(db, top=5))
    print(f"\ndatabase: {out}/db")


if __name__ == "__main__":
    main()
