"""End-to-end driver: train a model with the full substrate (data pipeline,
AdamW, checkpointing, watchdog) under measurement, then analyze.

    PYTHONPATH=src python examples/profile_train.py                # quick
    PYTHONPATH=src python examples/profile_train.py --steps 300 \
        --arch xlstm-125m --full --seq 1024 --batch 8              # ~125M

The quick mode trains the reduced xlstm config for 30 steps on CPU; the
full run is the real 125M-parameter architecture (expect hours on CPU —
sized for a TPU host).  Either way the workflow is identical: every
train_step dispatch is timed, PC-sample-analogue fine-grained metrics are
attributed below it, and the post-mortem analysis prints where time went —
scan loop, attention einsums, optimizer — in full heterogeneous calling
context.
"""
import argparse
import os
import tempfile

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.aggregate import aggregate
from repro.core import viewer
from repro.launch.train import train
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = args.out or tempfile.mkdtemp(prefix="repro_train_")
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    shape = ShapeConfig("custom", args.seq, args.batch, "train")
    opts = T.ModelOptions(q_chunk=min(256, args.seq),
                          kv_chunk=min(256, args.seq),
                          ssm_chunk=min(128, args.seq),
                          loss_chunk=min(256, args.seq))
    print(f"training {cfg.name} ({cfg.n_params() / 1e6:.1f}M params) "
          f"for {args.steps} steps, profiling on")
    _, history, paths = train(
        cfg, shape, n_steps=args.steps,
        ckpt_dir=os.path.join(out, "ckpt"), ckpt_every=max(args.steps // 3,
                                                           1),
        profile_dir=os.path.join(out, "prof"), opts=opts,
        log_every=max(args.steps // 10, 1))
    print(f"loss: {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}")

    profiles = [v for k, v in paths.items() if "trace" not in k]
    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=2,
                   n_threads=2)
    print()
    print(viewer.top_down(db, "gpu_inst/samples", max_depth=7,
                          max_children=4))
    print()
    print(viewer.flat(db, "gpu_inst/samples", top=10))
    print(f"\nartifacts under {out}")


if __name__ == "__main__":
    main()
