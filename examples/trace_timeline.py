"""Time-centric trace analysis across ranks (paper §4.4, §7 —
hpctraceviewer): merge per-rank/per-stream traces into one trace.db,
render the depth-over-time view at two zoom levels, and summarize
intervals (Summary tab, idleness/blame over time, top kernels).

    PYTHONPATH=src python examples/trace_timeline.py

Two "ranks" each run a two-stream pipeline with a CPU-side stall in the
middle; the zoomed view and the blame-over-time bins both point at it.
"""
import itertools
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate
from repro.core.profiler import Profiler

clock_src = itertools.count(0, 500_000)   # deterministic 0.5 ms ticks


def run_rank(out, rank, clock):
    f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
    x = jnp.ones((128, 128))
    compiled = f.lower(x).compile()
    prof = Profiler(os.path.join(out, f"rank{rank}"), tracing=True,
                    rank=rank, rng_seed=rank, clock=clock, unwind=False)
    mid = prof.register_module("train_step", compiled.as_text())
    with prof:
        for i in range(8):
            with prof.dispatch("kernel", "train_step", stream=i % 2,
                               module_id=mid, duration_ns=3_000_000):
                compiled(x)
            if i == 4:
                with prof.cpu_region("jit_recompile_stall"):
                    for _ in range(40):   # the culprit: a long CPU stall
                        next(clock_src)
            with prof.cpu_region("host_preprocessing"):
                next(clock_src)
    return prof.write()


def main():
    out = tempfile.mkdtemp(prefix="repro_timeline_")
    paths = {}
    for rank in range(2):
        paths[rank] = run_rank(out, rank, lambda: next(clock_src))

    profiles = [v for p in paths.values() for k, v in p.items()
                if "trace" not in k]
    traces = [v for p in paths.values() for k, v in p.items()
              if "trace" in k]
    db = aggregate(profiles, os.path.join(out, "db"), n_ranks=2,
                   n_threads=2, trace_paths=traces)

    from repro.traceview import (TraceDB, blame_over_time, render_view,
                                 top_kernels)
    tdb = TraceDB(db.trace_db_path())
    print(f"trace.db: {len(tdb.lines)} lines, {tdb.n_events} events, "
          f"[{tdb.t_min}, {tdb.t_max}) ns\n")
    lines = tdb.line_views()

    print("=== full run, depth 1 ===")
    print(render_view(lines, db, width=100, height=12, depth=1, top=5))

    t0, t1 = tdb.time_range()
    zt0 = t0 + (t1 - t0) * 2 // 5          # zoom into the middle fifth
    zt1 = t0 + (t1 - t0) * 3 // 5
    print("\n=== zoomed x2.5, depth 2 ===")
    print(render_view(lines, db, t0=zt0, t1=zt1, width=100, height=12,
                      depth=2, top=5))

    print("\n=== idleness / blame over time (8 bins) ===")
    for rank, d in blame_over_time(lines, t0, t1, 8).items():
        frac = " ".join(f"{v:4.0%}" for v in d["streams_idle_frac"])
        print(f"rank {rank} streams idle: {frac}")
        worst = sorted(d["blame"].items(), key=lambda kv: -kv[1].sum())[:2]
        for ctx, per_bin in worst:
            name = db.frames[ctx].pretty() if ctx < len(db.frames) \
                else f"ctx{ctx}"
            print(f"         blame {per_bin.sum() / 1e6:6.1f} ms  {name}")

    print("\n=== top kernels in the zoom window ===")
    for name, ns in top_kernels(lines, db, t0=zt0, t1=zt1, k=3):
        print(f"  {ns / 1e6:6.1f} ms  {name}")


if __name__ == "__main__":
    main()
