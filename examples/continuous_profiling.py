"""Continuous profiling with incremental & sharded database merge
(ISSUE 4; "Preparing for Performance Analysis at Exascale" motivates the
composable reduction).

    PYTHONPATH=src python examples/continuous_profiling.py

Two production shapes on one measured workload:

1. **Rank shards.**  Each rank's measurement directory is aggregated
   *independently* (in production: separate processes, no shared GIL),
   then ``merge_databases`` folds the shard databases into one.  The
   result is byte-identical to a one-shot ``aggregate()`` over all
   profiles — verified below.
2. **Epoch increments.**  A long-running job profiles epoch 2 while the
   epoch-1 database already serves queries; ``aggregate(...,
   base_db=...)`` extends the database in place, again landing on the
   same bytes a from-scratch aggregation of both epochs would produce.
"""
import itertools
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core.aggregate import aggregate
from repro.core.merge import merge_databases, summarize
from repro.core.profiler import Profiler
from repro.core import viewer

clock_src = itertools.count(0, 250_000)    # deterministic 0.25 ms ticks


def run_rank(out, rank, epoch, n_steps=6):
    """One rank's measurement for one epoch."""
    f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
    x = jnp.ones((96, 96))
    compiled = f.lower(x).compile()
    prof = Profiler(os.path.join(out, f"epoch{epoch}_rank{rank}"),
                    tracing=True, rank=rank, rng_seed=rank,
                    clock=lambda: next(clock_src), unwind=False,
                    tag=f"epoch{epoch}")   # keeps epochs distinct (ISSUE 4)
    mid = prof.register_module("train_step", compiled.as_text())
    with prof:
        for i in range(n_steps):
            with prof.dispatch("kernel", "train_step", stream=0,
                               module_id=mid, duration_ns=2_000_000):
                compiled(x)
            with prof.cpu_region(f"host_epoch{epoch}"):
                next(clock_src)
    written = prof.write()
    profiles = [v for k, v in written.items() if "trace" not in k]
    traces = [v for k, v in written.items() if "trace" in k]
    return profiles, traces


def db_fingerprint(d):
    return {fn: open(os.path.join(d, fn), "rb").read()
            for fn in ("stats.npz", "metrics.cms", "metrics.pms",
                       "trace.db")}


def main():
    out = tempfile.mkdtemp(prefix="repro_continuous_")

    # ---- epoch 1, two ranks, measured separately --------------------------
    measurements = {r: run_rank(out, r, epoch=1) for r in range(2)}

    # shape 1: per-rank shard databases, then one merge
    shard_dirs = []
    for r, (profiles, traces) in measurements.items():
        d = os.path.join(out, f"shard_rank{r}")
        aggregate(profiles, d, n_ranks=1, n_threads=2, trace_paths=traces)
        shard_dirs.append(d)
    merged = os.path.join(out, "db_epoch1")
    db_epoch1 = merge_databases(shard_dirs, merged)
    print(summarize(db_epoch1, shard_dirs))

    # the check the whole subsystem is built around: shard-then-merge ==
    # one-shot, byte for byte
    all_profiles = [p for pr, _ in measurements.values() for p in pr]
    all_traces = [t for _, tr in measurements.values() for t in tr]
    one_shot = os.path.join(out, "db_one_shot")
    aggregate(all_profiles, one_shot, trace_paths=all_traces)
    assert db_fingerprint(merged) == db_fingerprint(one_shot), \
        "shard-then-merge diverged from one-shot aggregate()"
    print("\nshard-then-merge is byte-identical to one-shot: OK")

    # ---- epoch 2 arrives: extend the database in place --------------------
    ep2 = {r: run_rank(out, r, epoch=2) for r in range(2)}
    ep2_profiles = [p for pr, _ in ep2.values() for p in pr]
    ep2_traces = [t for _, tr in ep2.values() for t in tr]
    db = aggregate(ep2_profiles, merged, base_db=merged,
                   trace_paths=ep2_traces)
    print(f"\nafter epoch 2 increment: {len(db.profile_ids)} profiles, "
          f"{len(db.frames)} contexts")

    both = os.path.join(out, "db_both_epochs")
    aggregate(all_profiles + ep2_profiles, both,
              trace_paths=all_traces + ep2_traces)
    assert db_fingerprint(merged) == db_fingerprint(both), \
        "incremental epoch extension diverged from one-shot aggregate()"
    print("incremental epoch extension is byte-identical to one-shot: OK")

    print("\n" + viewer.top_down(db, "gpu_kernel/time_ns", max_depth=3))


if __name__ == "__main__":
    main()
