"""Traceview throughput (paper §4.4/§7; "Preparing for Performance
Analysis at Exascale" motivates the merged trace.db).

Synthesizes an 8-rank x 4-stream measurement (1M events by default),
then measures the post-mortem stages the subsystem must keep fast:

- **merge**: N per-identity ``.rtrc`` files -> one seekable ``trace.db``
  (events/sec) — the sort-on-read flag is consumed here, once;
- **pyramid**: building the ``trace.pyr`` tile pyramid from the merged
  database (repro.traceview.pyramid) — the one-time cost O(tile)
  zoom/pan buys;
- **raster**: sampling the merged database into a 200x64 depth-over-time
  view straight from the event arrays — must stay O(width log events)
  per line with no per-event Python loop;
- **zoompan**: an interactive session (zoom ladder + pans, raster +
  occupancy per view) answered twice — per-event re-scan vs pyramid
  tiles.  The acceptance bar is a >= ``ZOOMPAN_BUDGET_MIN_X`` speedup
  for the tile path, whose occupancy answers are asserted bitwise-equal
  to the per-event scan (the exactness contract, docs/traceview.md) and
  whose wall-clock is additionally held under a calibration-normalized
  budget;
- **summary / request_spans**: the tile-backed Summary view (asserted
  equal to the per-event one) and the vectorized per-request span
  envelopes over serving window frames.

All ``*_under_budget`` gates are ratios against the calibration probe
(benchmarks/calibrate.py), not absolute wall-clock.  A small-subset
cross-check asserts the vectorized Summary equals the per-event
reference ``viewer.trace_statistic``.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.cct import Frame
from repro.core.trace import TraceWriter

from benchmarks.calibrate import probe

# budgets as multiples of the calibration probe (benchmarks/calibrate.py)
# — RASTER_BUDGET_X is the old absolute 1.0 s ISSUE 2 bar at the seed
# container's ~0.067 s probe
RASTER_BUDGET_X = 15.0        # full 200x64 view @ 1M events
PYRAMID_QUERY_BUDGET_X = 3.0  # the whole tile-backed zoompan session
ZOOMPAN_BUDGET_MIN_X = 10.0   # tile path vs per-event re-scan (ISSUE 9)
# at --small (100k events) the per-event scan is cheap enough that the
# tile path's fixed per-view cost dominates; the speedup bar only has to
# show the tile path is never slower
ZOOMPAN_BUDGET_MIN_X_SMALL = 1.2

N_REQUESTS = 16               # serving windows in the synthetic tree


def synth_tree(rng, n_ctx: int = 2000, max_depth: int = 8,
               n_requests: int = N_REQUESTS):
    """Random CCT: parents precede children, depth capped.  The first
    ``2 * n_requests`` nodes under the root are serving window frames
    (``request:<id>`` -> ``phase:<p>``, repro.serving.window) so the
    request-attribution stages group over real labels; the rest of the
    tree hangs beneath them."""
    parents = np.full(n_ctx, -1, np.int64)
    depth = np.zeros(n_ctx, np.int64)
    frames = [Frame("root", "<program root>")]
    for r in range(n_requests):
        i = 1 + 2 * r
        parents[i], depth[i] = 0, 1
        frames.append(Frame("host", f"request:r{r:03d}", "<serving>", 0))
        parents[i + 1], depth[i + 1] = i, 2
        frames.append(Frame("host",
                            "phase:" + ("decode" if r % 2 else "prefill"),
                            "<serving>", 0))
    for i in range(1 + 2 * n_requests, n_ctx):
        p = int(rng.integers(1, i))
        if depth[p] >= max_depth:
            p = int(parents[p])
        parents[i] = p
        depth[i] = depth[p] + 1
        d = depth[i]
        frames.append(Frame("host" if d <= 2 else "placeholder",
                            f"fn{i}", "app.py", int(d)))
    return frames, parents


class _SynthDB:
    """Just enough of aggregate.Database for raster/stats/render."""

    def __init__(self, frames, parents):
        self.frames = frames
        self.parents = parents


def synth_measurement(tmp: str, n_events: int, n_ranks: int = 8,
                      n_streams: int = 4, n_ctx: int = 2000):
    rng = np.random.default_rng(7)
    frames, parents = synth_tree(rng, n_ctx)
    n_lines = n_ranks * n_streams
    per_line = n_events // n_lines
    paths = []
    for rank in range(n_ranks):
        for stream in range(n_streams):
            gaps = rng.integers(0, 2000, per_line)
            durs = rng.integers(100, 5000, per_line)
            starts = np.cumsum(gaps + durs) - durs
            ends = starts + durs
            ctx = rng.integers(1, n_ctx, per_line)
            tw = TraceWriter(
                os.path.join(tmp, f"trace_r{rank}_s{stream}.rtrc"),
                {"rank": rank, "stream": stream, "type": "gpu"})
            tw.append_many(starts, ends, ctx)
            tw.close()
            paths.append(tw.path)
    return paths, _SynthDB(frames, parents)


def _zoompan_views(t0: int, t1: int, n_zoom: int = 5, n_pan: int = 5):
    """The interactive session: zoom in by halves around the center,
    then pan the deepest zoom across the range."""
    span = t1 - t0
    views = []
    for k in range(n_zoom):
        w = max(span >> k, 1)
        a = t0 + span // 2 - w // 2
        views.append((a, a + w))
    w = max(span >> (n_zoom - 1), 1)
    for j in range(n_pan):
        a = t0 + (span - w) * j // max(n_pan - 1, 1)
        views.append((a, a + w))
    return views


def run(n_events: int = 1_000_000, width: int = 200, height: int = 64,
        occ_bins: int = 64, zoompan_min_x: float = ZOOMPAN_BUDGET_MIN_X):
    from repro.core import viewer
    from repro.core.trace import TraceData
    from repro.traceview import (build_db, build_pyramid, rasterize,
                                 render, stats, summary)

    tmp = tempfile.mkdtemp(prefix="repro_traceview_")
    paths, db = synth_measurement(tmp, n_events)

    t0 = time.perf_counter()
    tdb = build_db(paths, os.path.join(tmp, "trace.db"))
    merge_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    pyr = build_pyramid(tdb.path, db.parents)
    pyramid_build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    lines = tdb.line_views()
    raster = rasterize(lines, db.parents, width=width, height=height,
                       depth=2)
    text = render(raster, db)
    raster_s = time.perf_counter() - t0

    # -- zoompan: the same view sequence answered per-event vs tiles ----
    # (depths precomputed once for both paths, as an interactive viewer
    # caches them across renders)
    from repro.core.cct import tree_depths
    depths = tree_depths(db.parents)
    views = _zoompan_views(tdb.t_min, tdb.t_max)
    # prime both paths once: warms the OS page cache over the event
    # arrays (per-event path) and the pyramid's per-line cumsum /
    # refinement-index caches (tile path) — an interactive session pays
    # those on its first render, not per zoom/pan
    a, b = views[0]
    rasterize(lines, db.parents, t0=a, t1=b, width=width, height=height,
              depth=2, depths=depths)
    stats.occupancy(lines, a, b, occ_bins)
    pyr.rasterize(db.parents, t0=a, t1=b, width=width, height=height,
                  depth=2, depths=depths, mode="auto")
    pyr.occupancy(a, b, occ_bins)
    t0 = time.perf_counter()
    ev_occ = []
    for a, b in views:
        rasterize(lines, db.parents, t0=a, t1=b, width=width,
                  height=height, depth=2, depths=depths)
        ev_occ.append(stats.occupancy(lines, a, b, occ_bins))
    zoompan_events_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    tile_occ = []
    for a, b in views:
        pyr.rasterize(db.parents, t0=a, t1=b, width=width, height=height,
                      depth=2, depths=depths, mode="auto")
        tile_occ.append(pyr.occupancy(a, b, occ_bins))
    zoompan_tiles_s = time.perf_counter() - t0

    # exactness contract: tile occupancy is bitwise-equal per view, and
    # an exact-mode tile raster matches the per-event raster pixels
    for (a, b), eo, to in zip(views, ev_occ, tile_occ):
        assert np.array_equal(eo, to), f"occupancy diverged on [{a},{b})"
    a, b = views[len(views) // 2]
    ref_px = rasterize(lines, db.parents, t0=a, t1=b, width=width,
                       height=height, depth=2).pixels
    got_px = pyr.rasterize(db.parents, t0=a, t1=b, width=width,
                           height=height, depth=2, mode="exact").pixels
    assert np.array_equal(ref_px, got_px), "exact tile raster diverged"

    # -- summary: per-event vs tile-backed, equal rows ------------------
    t0 = time.perf_counter()
    rows = summary(lines, db, depth=2, top=10)
    summary_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows_tiles = summary(lines, db, depth=2, top=10, pyramid=pyr)
    summary_tiles_s = time.perf_counter() - t0
    assert rows == rows_tiles, "tile-backed summary diverged"

    # -- request spans over the serving window frames -------------------
    t0 = time.perf_counter()
    spans = stats.request_spans(lines, db)
    request_spans_s = time.perf_counter() - t0
    assert len(spans) > 0, "synthetic tree lost its serving windows"

    # cross-check the vectorized Summary against the per-event reference
    # on a 2-line subset (trace_statistic loops in Python)
    sub = [TraceData(td.identity, np.asarray(td.starts)[:5000],
                     np.asarray(td.ends)[:5000], np.asarray(td.ctx)[:5000])
           for td in lines[:2]]
    ref = dict(viewer.trace_statistic(sub, db, depth=2, top=10**9))
    got = dict(summary(sub, db, depth=2, top=10**9))
    for name, frac in ref.items():
        assert abs(got.get(name, 0.0) - frac) < 1e-12, \
            f"summary mismatch at {name}: {got.get(name)} vs {frac}"

    cal = probe()
    n_pixels = raster.pixels.size
    zoompan_speedup_x = zoompan_events_s / zoompan_tiles_s
    out = {
        "n_events": tdb.n_events,
        "n_lines": len(tdb.lines),
        "db_bytes": os.path.getsize(tdb.path),
        "pyr_bytes": os.path.getsize(pyr.path),
        "merge_s": merge_s,
        "merge_events_per_s": tdb.n_events / merge_s,
        "pyramid_build_s": pyramid_build_s,
        "raster_s": raster_s,
        "raster_pixels": n_pixels,
        "raster_pixels_per_s": n_pixels / raster_s,
        "raster_under_budget": bool(raster_s < RASTER_BUDGET_X * cal),
        "raster_budget_x": RASTER_BUDGET_X,
        "raster_budget_probe_s": cal,
        "zoompan_views": len(views),
        "zoompan_events_s": zoompan_events_s,
        "zoompan_tiles_s": zoompan_tiles_s,
        "zoompan_speedup_x": zoompan_speedup_x,
        "zoompan_under_budget": bool(zoompan_speedup_x >= zoompan_min_x),
        "zoompan_budget_min_x": zoompan_min_x,
        "pyramid_query_s": zoompan_tiles_s,
        "pyramid_query_under_budget": bool(
            zoompan_tiles_s < PYRAMID_QUERY_BUDGET_X * cal),
        "pyramid_query_budget_x": PYRAMID_QUERY_BUDGET_X,
        "summary_s": summary_s,
        "summary_tiles_s": summary_tiles_s,
        "summary_tiles_equal": True,          # asserted above
        "request_spans_s": request_spans_s,
        "request_span_groups": len(spans),
        "summary_matches_trace_statistic": True,
        "render_chars": len(text),
    }
    pyr.close()
    tdb.close()
    return out


def main(small: bool = False):
    r = run(n_events=100_000, zoompan_min_x=ZOOMPAN_BUDGET_MIN_X_SMALL) \
        if small else run()
    for k, v in r.items():
        print(f"bench_traceview,{k},{v}")
    return r


if __name__ == "__main__":
    main()
