"""Traceview throughput (paper §4.4/§7; "Preparing for Performance
Analysis at Exascale" motivates the merged trace.db).

Synthesizes an 8-rank x 4-stream measurement (1M events by default),
then measures the two post-mortem stages the subsystem must keep fast:

- **merge**: N per-identity ``.rtrc`` files -> one seekable ``trace.db``
  (events/sec) — the sort-on-read flag is consumed here, once;
- **raster**: sampling the merged database into a 200x64 depth-over-time
  view (pixels/sec) — the acceptance bar is < 1 s for the full view, which
  only holds if sampling stays O(width log events) per line with no
  per-event Python loop.

A small-subset cross-check asserts the vectorized Summary view equals the
per-event reference ``viewer.trace_statistic`` on the same lines.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.cct import Frame
from repro.core.trace import TraceWriter

RASTER_BUDGET_S = 1.0      # ISSUE 2 acceptance bar (200x64 @ 1M events)


def synth_tree(rng, n_ctx: int = 2000, max_depth: int = 8):
    """Random CCT: parents precede children, depth capped."""
    parents = np.full(n_ctx, -1, np.int64)
    depth = np.zeros(n_ctx, np.int64)
    for i in range(1, n_ctx):
        p = int(rng.integers(0, i))
        if depth[p] >= max_depth:
            p = int(parents[p])
        parents[i] = p
        depth[i] = depth[p] + 1
    frames = [Frame("root", "<program root>")] + [
        Frame("host" if d <= 2 else "placeholder", f"fn{i}", "app.py", int(d))
        for i, d in enumerate(depth[1:], start=1)]
    return frames, parents


class _SynthDB:
    """Just enough of aggregate.Database for raster/stats/render."""

    def __init__(self, frames, parents):
        self.frames = frames
        self.parents = parents


def synth_measurement(tmp: str, n_events: int, n_ranks: int = 8,
                      n_streams: int = 4, n_ctx: int = 2000):
    rng = np.random.default_rng(7)
    frames, parents = synth_tree(rng, n_ctx)
    n_lines = n_ranks * n_streams
    per_line = n_events // n_lines
    paths = []
    for rank in range(n_ranks):
        for stream in range(n_streams):
            gaps = rng.integers(0, 2000, per_line)
            durs = rng.integers(100, 5000, per_line)
            starts = np.cumsum(gaps + durs) - durs
            ends = starts + durs
            ctx = rng.integers(1, n_ctx, per_line)
            tw = TraceWriter(
                os.path.join(tmp, f"trace_r{rank}_s{stream}.rtrc"),
                {"rank": rank, "stream": stream, "type": "gpu"})
            tw.append_many(starts, ends, ctx)
            tw.close()
            paths.append(tw.path)
    return paths, _SynthDB(frames, parents)


def run(n_events: int = 1_000_000, width: int = 200, height: int = 64):
    from repro.core import viewer
    from repro.core.trace import TraceData
    from repro.traceview import TraceDB, build_db, rasterize, render, summary

    tmp = tempfile.mkdtemp(prefix="repro_traceview_")
    paths, db = synth_measurement(tmp, n_events)

    t0 = time.perf_counter()
    tdb = build_db(paths, os.path.join(tmp, "trace.db"))
    merge_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    lines = tdb.line_views()
    raster = rasterize(lines, db.parents, width=width, height=height,
                       depth=2)
    text = render(raster, db)
    raster_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    rows = summary(lines, db, depth=2, top=10)
    summary_s = time.perf_counter() - t0

    # cross-check the vectorized Summary against the per-event reference
    # on a 2-line subset (trace_statistic loops in Python)
    sub = [TraceData(td.identity, np.asarray(td.starts)[:5000],
                     np.asarray(td.ends)[:5000], np.asarray(td.ctx)[:5000])
           for td in lines[:2]]
    ref = dict(viewer.trace_statistic(sub, db, depth=2, top=10**9))
    got = dict(summary(sub, db, depth=2, top=10**9))
    for name, frac in ref.items():
        assert abs(got.get(name, 0.0) - frac) < 1e-12, \
            f"summary mismatch at {name}: {got.get(name)} vs {frac}"

    n_pixels = raster.pixels.size
    return {
        "n_events": tdb.n_events,
        "n_lines": len(tdb.lines),
        "db_bytes": os.path.getsize(tdb.path),
        "merge_s": merge_s,
        "merge_events_per_s": tdb.n_events / merge_s,
        "raster_s": raster_s,
        "raster_pixels": n_pixels,
        "raster_pixels_per_s": n_pixels / raster_s,
        "raster_under_budget": bool(raster_s < RASTER_BUDGET_S),
        "raster_budget_s": RASTER_BUDGET_S,
        "summary_s": summary_s,
        "summary_matches_trace_statistic": True,
        "render_chars": len(text),
    }


def main(small: bool = False):
    r = run(n_events=100_000) if small else run()
    for k, v in r.items():
        print(f"bench_traceview,{k},{v}")
    return r


if __name__ == "__main__":
    main()
