"""Sharded aggregation + database merge vs one-shot (ISSUE 4).

The continuous-profiling pitch: shards of a measurement directory are
aggregated *independently* (separate processes in production — no shared
GIL), then ``merge_databases`` folds the shard databases.  The fold must
be (a) byte-identical to the one-shot database over the union — asserted
here on stats/cms/pms, the merge contract — and (b) cheap relative to
re-aggregating from scratch, since an incremental epoch pays one shard
aggregation plus one merge instead of a full recompute.

Reported numbers:

- ``one_shot_s``      — ``aggregate()`` over all P profiles;
- ``shard_total_s``   — sum of the S per-shard aggregations (an MPI/
  multi-process deployment pays ``max``, not ``sum``; both reported);
- ``merge_s``         — folding the S shard databases (budgeted);
- ``incremental_s``   — extending an existing database with one shard via
  ``aggregate(..., base_db=...)`` — the steady-state epoch cost.

``SEED_BASELINE`` pins the first measurement of this subsystem (this
container, best of ``repeats``) so the cross-PR trajectory is visible in
``BENCH_merge.json``.
"""
from __future__ import annotations

import os
import tempfile
import time

from repro.core.aggregate import aggregate
from repro.core.merge import merge_databases

from benchmarks.bench_aggregation import make_inputs
from benchmarks.calibrate import probe

# budget as a multiple of the calibration probe (benchmarks/calibrate.py)
# — the old absolute 2.0 s bar at the seed container's ~0.067 s probe
MERGE_BUDGET_X = 30.0       # 4-shard fold @ 16 profiles (x150-host CCTs)

# First measurement of the merge subsystem (PR 4, this container, best
# of 3): 16 profiles, 4 shards.
SEED_BASELINE = {
    "n_profiles": 16,
    "one_shot_s": 0.76,
    "merge_s": 0.35,
}


def _db_bytes(d: str):
    return {fn: open(os.path.join(d, fn), "rb").read()
            for fn in ("stats.npz", "metrics.cms", "metrics.pms")}


def run(n_profiles: int = 16, n_shards: int = 4, repeats: int = 3):
    tmp = tempfile.mkdtemp(prefix="repro_merge_")
    paths = make_inputs(n_profiles, tmp)
    shards = [paths[i::n_shards] for i in range(n_shards)]

    best = None
    for rep in range(max(1, repeats)):
        r = {}
        t0 = time.perf_counter()
        one = os.path.join(tmp, f"one_{rep}")
        aggregate(paths, one)
        r["one_shot_s"] = time.perf_counter() - t0

        shard_dirs, shard_times = [], []
        for s, sp in enumerate(shards):
            d = os.path.join(tmp, f"shard_{rep}_{s}")
            t0 = time.perf_counter()
            aggregate(sp, d)
            shard_times.append(time.perf_counter() - t0)
            shard_dirs.append(d)
        r["shard_total_s"] = sum(shard_times)
        r["shard_max_s"] = max(shard_times)

        t0 = time.perf_counter()
        merged = os.path.join(tmp, f"merged_{rep}")
        merge_databases(shard_dirs, merged)
        r["merge_s"] = time.perf_counter() - t0

        # the contract this whole subsystem exists for
        assert _db_bytes(merged) == _db_bytes(one), \
            "shard-then-merge diverged from one-shot aggregate()"

        # steady-state epoch: extend the first (n_shards-1) shards'
        # database with the last shard's profiles
        base = os.path.join(tmp, f"base_{rep}")
        merge_databases(shard_dirs[:-1], base)
        t0 = time.perf_counter()
        aggregate(shards[-1], base, base_db=base)
        r["incremental_s"] = time.perf_counter() - t0
        assert _db_bytes(base) == _db_bytes(one), \
            "incremental extension diverged from one-shot aggregate()"

        if best is None or r["merge_s"] < best["merge_s"]:
            best = r

    out = {
        "n_profiles": n_profiles,
        "n_shards": n_shards,
        **best,
        "byte_identical": True,     # asserted above, every repeat
        "merge_vs_one_shot_x": best["one_shot_s"] / best["merge_s"],
        "modeled_multiprocess_s": best["shard_max_s"] + best["merge_s"],
        "merge_under_budget": bool(best["merge_s"] < MERGE_BUDGET_X
                                   * probe()),
        "merge_budget_x": MERGE_BUDGET_X,
        "merge_budget_probe_s": probe(),
    }
    if n_profiles == SEED_BASELINE["n_profiles"]:
        out["seed_one_shot_s"] = SEED_BASELINE["one_shot_s"]
        out["seed_merge_s"] = SEED_BASELINE["merge_s"]
        out["merge_vs_seed_x"] = SEED_BASELINE["merge_s"] / best["merge_s"]
    return out


def main(small: bool = False):
    r = run(n_profiles=6, n_shards=3, repeats=1) if small else run()
    for k, v in r.items():
        print(f"bench_merge,{k},{v}")
    return r


if __name__ == "__main__":
    main()
