"""Streaming-aggregation scaling (paper §8.2: thread-level parallelism +
streaming made hpcprof-mpi 3.6x faster at equal core count; 85 GB from
1002 GPUs in 91 s on 48x42 cores).

We aggregate P profiles with (1 rank x 1 thread) vs (R ranks x T threads)
and report wall-clock speedup plus the *work-scaling* decomposition
(unify vs stats phases).  On this container the workers are threads (GIL
caveat discussed in docs/aggregation.md): numpy-heavy phases release the
GIL, pure-python ones do not, so we report both phases separately — the
*algorithmic* split (profiles are independent tasks; reduction tree depth
log_t(R)) is what transfers to MPI ranks.

The perf trajectory across PRs is tracked against ``SEED_BASELINE``
(measured on the seed implementation, same container, best of 3); the
acceptance bar for ISSUE 1 is >=2x on the parallel configuration at 16
profiles with byte-identical outputs (tests/test_aggregate_equiv.py).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.aggregate import aggregate
from repro.core.metrics import default_registry
from repro.core.profmt import write_profile
from benchmarks.bench_sparse import synth_cct

# Seed implementation (commit 839be6d), 16 profiles, best of 3, this
# container: dense per-profile matrices + python reverse sweep + one
# global accumulator lock + per-context CMS fill loop.
SEED_BASELINE = {
    "n_profiles": 16,
    "serial_wall_s": 0.898,
    "parallel_wall_s": 2.097,
}


def make_inputs(n_profiles: int, tmp: str):
    rng = np.random.default_rng(1)
    reg = default_registry()
    paths = []
    for p in range(n_profiles):
        cct = synth_cct(rng, reg, n_host=150, n_kernels=12, n_ops=30)
        path = os.path.join(tmp, f"p{p}.rpro")
        write_profile(path, cct, reg, {"rank": p, "type": "cpu"}, [])
        paths.append(path)
    return paths


def _critical_path(task_times, n_workers: int, reduce_cost: float) -> float:
    """LPT-schedule the measured per-profile task times onto n_workers and
    add a log_t(n_workers)-deep reduction: the wall-clock an MPI deployment
    of the same algorithm would see (communication-free phases)."""
    import heapq
    import math
    loads = [0.0] * n_workers
    heapq.heapify(loads)
    for t in sorted(task_times, reverse=True):   # LPT greedy
        heapq.heapreplace(loads, loads[0] + t)
    depth = max(1, math.ceil(math.log(max(n_workers, 2), 4)))
    return max(loads) + depth * reduce_cost


def run(n_profiles: int = 16, repeats: int = 3):
    tmp = tempfile.mkdtemp(prefix="repro_agg_")
    paths = make_inputs(n_profiles, tmp)
    results = {}
    for label, ranks, threads in (("serial", 1, 1), ("parallel", 4, 4)):
        best = None
        for rep in range(max(1, repeats)):
            timing = {}
            t0 = time.perf_counter()
            aggregate(paths, os.path.join(tmp, f"db_{label}_{rep}"),
                      n_ranks=ranks, n_threads=threads, timing=timing)
            wall = time.perf_counter() - t0
            if best is None or wall < best["wall_s"]:
                best = {"wall_s": wall, **timing}
        results[label] = best
    speedup = results["serial"]["wall_s"] / results["parallel"]["wall_s"]

    # --- work / critical-path scaling from measured per-profile times ----
    # (this container has ONE core, so wall-clock 'parallel' cannot beat
    # serial; the transferable number is the schedule of the *measured*
    # independent task times over R x T workers, which is exactly how the
    # hpcprof-mpi deployment parallelizes — docs/aggregation.md.)
    per_task = []
    for p in paths:
        t0 = time.perf_counter()
        aggregate([p], os.path.join(tmp, "db_single"), n_ranks=1,
                  n_threads=1)
        per_task.append(time.perf_counter() - t0)
    total_work = sum(per_task)
    reduce_cost = max(per_task) * 0.1   # tree-merge step ~10% of a task
    modeled_16 = _critical_path(per_task, 16, reduce_cost)
    out = {
        "n_profiles": n_profiles,
        "serial_wall_s": results["serial"]["wall_s"],
        "parallel_wall_s": results["parallel"]["wall_s"],
        "unify_s": results["parallel"]["unify_s"],
        "stats_s": results["parallel"]["stats_s"],
        "wall_speedup_x_1core": speedup,
        "total_work_s": total_work,
        "modeled_speedup_16workers_x": total_work / modeled_16,
        "paper_speedup_x": 3.6,
        "note": "1-core container: wall ~1x; modeled = LPT schedule of "
                "measured task times + reduction tree "
                "(docs/aggregation.md)",
    }
    # a 48-worker schedule is only meaningful with >= 48 independent tasks
    if n_profiles >= 48:
        out["modeled_speedup_48workers_x"] = \
            total_work / _critical_path(per_task, 48, reduce_cost)
    if n_profiles == SEED_BASELINE["n_profiles"]:
        out["seed_serial_wall_s"] = SEED_BASELINE["serial_wall_s"]
        out["seed_parallel_wall_s"] = SEED_BASELINE["parallel_wall_s"]
        out["speedup_vs_seed_serial_x"] = \
            SEED_BASELINE["serial_wall_s"] / out["serial_wall_s"]
        out["speedup_vs_seed_parallel_x"] = \
            SEED_BASELINE["parallel_wall_s"] / out["parallel_wall_s"]
    return out


def main(small: bool = False):
    r = run(n_profiles=4, repeats=1) if small else run()
    for k, v in r.items():
        print(f"bench_aggregation,{k},{v}")
    return r


if __name__ == "__main__":
    main()
