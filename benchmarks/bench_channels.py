"""Wait-free channel throughput (paper §4.1).

Measures SPSC ring throughput single-threaded and across a producer/
consumer thread pair, against a locked deque baseline — the design point
(no locks, no CAS retries on the hot path) should show up as a visibly
higher items/s.  The batched ``try_push_many``/``try_pop_many`` path
(ISSUE 1) amortizes the per-item Python call overhead and is reported
separately; ``SEED_BASELINE`` tracks the trajectory across PRs.
"""
from __future__ import annotations

import collections
import threading
import time

from repro.core.channels import EMPTY, SpscQueue

N = 200_000

# Seed implementation (commit 839be6d), this container: scalar-only API.
SEED_BASELINE = {
    "spsc_single_thread_items_per_s": 975_108.0,
    "spsc_two_thread_items_per_s": 319_750.0,
    "locked_two_thread_items_per_s": 17_885.0,
}


def spsc_pair(n: int = N) -> float:
    q = SpscQueue(4096)
    done = []

    def producer():
        i = 0
        while i < n:
            if q.try_push(i):
                i += 1

    def consumer():
        c = 0
        while c < n:
            if q.try_pop() is not EMPTY:
                c += 1
        done.append(c)

    t0 = time.perf_counter()
    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start(); tp.join(); tc.join()
    return n / (time.perf_counter() - t0)


def spsc_pair_batched(n: int = N, batch: int = 256) -> float:
    """Producer/consumer pair using the batch API: one publish per batch."""
    q = SpscQueue(4096)
    done = []

    def producer():
        i = 0
        while i < n:
            i += q.try_push_many(list(range(i, min(i + batch, n))))

    def consumer():
        c = 0
        while c < n:
            c += len(q.try_pop_many(batch))
        done.append(c)

    t0 = time.perf_counter()
    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start(); tp.join(); tc.join()
    return n / (time.perf_counter() - t0)


def locked_pair(n: int = N) -> float:
    q = collections.deque()
    lock = threading.Lock()
    done = []

    def producer():
        i = 0
        while i < n:
            with lock:
                if len(q) < 4096:
                    q.append(i)
                    i += 1

    def consumer():
        c = 0
        while c < n:
            with lock:
                if q:
                    q.popleft()
                    c += 1
        done.append(c)

    t0 = time.perf_counter()
    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start(); tp.join(); tc.join()
    return n / (time.perf_counter() - t0)


def single_thread(n: int = N) -> float:
    q = SpscQueue(4096)
    t0 = time.perf_counter()
    for i in range(n):
        q.try_push(i)
        q.try_pop()
    return n / (time.perf_counter() - t0)


def single_thread_batched(n: int = N, batch: int = 256) -> float:
    q = SpscQueue(4096)
    items = list(range(batch))
    t0 = time.perf_counter()
    for _ in range(n // batch):
        q.try_push_many(items)
        q.try_pop_many(batch)
    return (n // batch) * batch / (time.perf_counter() - t0)


def run(n: int = N):
    two_thread = spsc_pair(n)
    locked = locked_pair(n)
    out = {
        "spsc_single_thread_items_per_s": single_thread(n),
        "spsc_single_thread_batched_items_per_s": single_thread_batched(n),
        "spsc_two_thread_items_per_s": two_thread,
        "spsc_two_thread_batched_items_per_s": spsc_pair_batched(n),
        "locked_two_thread_items_per_s": locked,
        "speedup_vs_locked_x": two_thread / locked,
    }
    out["batched_speedup_two_thread_x"] = (
        out["spsc_two_thread_batched_items_per_s"] / two_thread)
    out["speedup_vs_seed_two_thread_x"] = (
        out["spsc_two_thread_batched_items_per_s"]
        / SEED_BASELINE["spsc_two_thread_items_per_s"])
    return out


def main(small: bool = False):
    r = run(20_000 if small else N)
    for k, v in r.items():
        print(f"bench_channels,{k},{v}")
    return r


if __name__ == "__main__":
    main()
