"""Wait-free channel throughput (paper §4.1).

Measures SPSC ring throughput single-threaded and across a producer/
consumer thread pair, against a locked deque baseline — the design point
(no locks, no CAS retries on the hot path) should show up as a visibly
higher items/s.
"""
from __future__ import annotations

import collections
import threading
import time

from repro.core.channels import EMPTY, SpscQueue

N = 200_000


def spsc_pair() -> float:
    q = SpscQueue(4096)
    done = []

    def producer():
        i = 0
        while i < N:
            if q.try_push(i):
                i += 1

    def consumer():
        c = 0
        while c < N:
            if q.try_pop() is not EMPTY:
                c += 1
        done.append(c)

    t0 = time.perf_counter()
    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start(); tp.join(); tc.join()
    return N / (time.perf_counter() - t0)


def locked_pair() -> float:
    q = collections.deque()
    lock = threading.Lock()
    done = []

    def producer():
        i = 0
        while i < N:
            with lock:
                if len(q) < 4096:
                    q.append(i)
                    i += 1

    def consumer():
        c = 0
        while c < N:
            with lock:
                if q:
                    q.popleft()
                    c += 1
        done.append(c)

    t0 = time.perf_counter()
    tp = threading.Thread(target=producer)
    tc = threading.Thread(target=consumer)
    tp.start(); tc.start(); tp.join(); tc.join()
    return N / (time.perf_counter() - t0)


def single_thread() -> float:
    q = SpscQueue(4096)
    t0 = time.perf_counter()
    for i in range(N):
        q.try_push(i)
        q.try_pop()
    return N / (time.perf_counter() - t0)


def run():
    return {
        "spsc_single_thread_items_per_s": single_thread(),
        "spsc_two_thread_items_per_s": spsc_pair(),
        "locked_two_thread_items_per_s": locked_pair(),
        "speedup_vs_locked_x": spsc_pair() / locked_pair(),
    }


def main():
    r = run()
    for k, v in r.items():
        print(f"bench_channels,{k},{v}")
    return r


if __name__ == "__main__":
    main()
