"""Fleet ingest throughput + crash-recovery replay time (ISSUE 6).

Two numbers the aggregation daemon must keep honest:

- ``ingest_s`` — the full admit+fold pipeline for a batch of delivered
  envelopes (verify SHA-256, unpack, journal, one merge commit), the
  steady-state cost of a fleet poll (budgeted, throughput reported as
  ``shards_per_s``);
- ``recovery_s`` — a restart after a crash *between the fold commit and
  spool cleanup* (the worst replay window: the journal already records
  every shard, so recovery must dedup the entire spool and touch the
  database not at all), budgeted well below the ingest cost since a
  crash-looping daemon pays it on every relaunch.

Byte-identity against the one-shot ``aggregate()`` over the same
profiles is asserted every repeat — the throughput is meaningless if
the bytes drift.

``SEED_BASELINE`` pins the first measurement of this subsystem (this
container, best of ``repeats``) so the cross-PR trajectory is visible
in ``BENCH_fleet.json``.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core.aggregate import aggregate
from repro.fleet import DirectoryTransport, FleetDaemon, ShardProducer
from repro.fleet.daemon import FP_FOLD_POST_COMMIT
from repro.ft import inject

from benchmarks.bench_aggregation import make_inputs
from benchmarks.calibrate import probe

# budgets as multiples of the calibration probe (benchmarks/calibrate.py)
# — the old absolute bars (3.0 s, 0.5 s) at the seed container's
# ~0.067 s probe
INGEST_BUDGET_X = 45.0      # 4-envelope admit+fold @ 16 profiles
RECOVERY_BUDGET_X = 7.5     # journal replay must be ~free vs the fold

# First measurement of the fleet subsystem (PR 6, this container, best
# of 3): 16 profiles across 4 producer envelopes.
SEED_BASELINE = {
    "n_profiles": 16,
    "ingest_s": 0.40,
    "recovery_s": 0.005,
}


def _db_bytes(d: str):
    return {fn: open(os.path.join(d, fn), "rb").read()
            for fn in ("stats.npz", "metrics.cms", "metrics.pms")}


def run(n_profiles: int = 16, n_shards: int = 4, repeats: int = 3):
    tmp = tempfile.mkdtemp(prefix="repro_fleet_")
    paths = make_inputs(n_profiles, tmp)
    shard_dirs = []
    for s in range(n_shards):
        d = os.path.join(tmp, f"shard_{s}")
        aggregate(paths[s::n_shards], d)
        shard_dirs.append(d)
    one = os.path.join(tmp, "one_shot")
    aggregate(paths, one)

    best = None
    for rep in range(max(1, repeats)):
        r = {}
        db = os.path.join(tmp, f"fleet_{rep}")
        spool = os.path.join(tmp, f"spool_{rep}")
        daemon = FleetDaemon(db, spool, n_workers=1)
        producer = ShardProducer(
            os.path.join(tmp, f"outbox_{rep}"),
            DirectoryTransport(daemon.incoming_dir),
            producer="bench", sleep=lambda s: None)
        for i, sd in enumerate(shard_dirs):
            producer.stage(sd, epoch=i)
        producer.deliver()

        t0 = time.perf_counter()
        report = daemon.poll_once()
        r["ingest_s"] = time.perf_counter() - t0
        assert len(report.applied) == n_shards
        assert _db_bytes(db) == _db_bytes(one), \
            "fleet fold diverged from one-shot aggregate()"
        r["shards_per_s"] = n_shards / r["ingest_s"]

        # recovery replay: redeliver everything, crash after the fold
        # commit (pending spool full, journal complete), restart
        for i, sd in enumerate(shard_dirs):
            producer.stage(sd, epoch=i)
        producer.deliver()
        shutil.rmtree(db)
        with inject.injected(FP_FOLD_POST_COMMIT):
            try:
                FleetDaemon(db, spool, n_workers=1).poll_once()
            except inject.InjectedCrash:
                pass
        t0 = time.perf_counter()
        recovered = FleetDaemon(db, spool, n_workers=1).poll_once()
        r["recovery_s"] = time.perf_counter() - t0
        assert not recovered.applied \
            and len(recovered.replay_cleaned) == n_shards
        assert _db_bytes(db) == _db_bytes(one)

        if best is None or r["ingest_s"] < best["ingest_s"]:
            best = r

    out = {
        "n_profiles": n_profiles,
        "n_shards": n_shards,
        **best,
        "byte_identical": True,     # asserted above, every repeat
        "ingest_under_budget": bool(best["ingest_s"] < INGEST_BUDGET_X
                                    * probe()),
        "ingest_budget_x": INGEST_BUDGET_X,
        "ingest_budget_probe_s": probe(),
        "recovery_under_budget": bool(
            best["recovery_s"] < RECOVERY_BUDGET_X * probe()),
        "recovery_budget_x": RECOVERY_BUDGET_X,
    }
    if n_profiles == SEED_BASELINE["n_profiles"]:
        out["seed_ingest_s"] = SEED_BASELINE["ingest_s"]
        out["seed_recovery_s"] = SEED_BASELINE["recovery_s"]
        out["ingest_vs_seed_x"] = \
            SEED_BASELINE["ingest_s"] / best["ingest_s"]
    return out


def main(small: bool = False):
    r = run(n_profiles=6, n_shards=3, repeats=1) if small else run()
    for k, v in r.items():
        print(f"bench_fleet,{k},{v}")
    return r


if __name__ == "__main__":
    main()
