"""Shard-driver scaling: ``aggregate(..., workers=N)`` vs serial
(ISSUE 5 tentpole).

The staged pipeline's process driver partitions the profiles into
shards, runs phases 1-4 per shard in worker processes (no shared GIL
for the Python-heavy unification loop), and folds the in-memory shard
results through ``merge_databases`` — byte-identical to the serial
one-shot **by construction**, asserted here on stats/cms/pms/coverage
every repeat.

The fixture is the SPMD continuous-profiling shape: every profile has
the *same* tree (every rank runs the same program; values differ), so
per-profile unification + statistics dominate and the union graft the
fold pays stays small — the regime the driver is built for.  The
acceptance bar (ISSUE 5) is **>= 1.8x wall-clock at 16 profiles with 4
workers**; the sweep fails loudly if a regression drops below it
(``speedup_under_budget``).

Reported numbers:

- ``serial_wall_s``        — one-shot ``aggregate()`` (the serial driver,
  best of ``repeats``);
- ``process{N}_wall_s``    — process driver at N workers (best of
  ``repeats``, pool pre-warmed);
- ``speedup_4w_x``         — best PAIRED serial/process4 ratio (the runs
  alternate back-to-back so both sides sample the same host-noise
  regime; this container's wall-clock swings +-30%); budgeted >= 1.8;
- ``byte_identical``       — asserted every repeat, every worker count.

``SEED_BASELINE`` pins the first measurement of this subsystem (this
container, best of ``repeats``) so the cross-PR trajectory is visible
in ``BENCH_pipeline.json``.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER
from repro.core.metrics import default_registry
from repro.core.profmt import write_profile

SPEEDUP_BUDGET_MIN_X = 1.8      # ISSUE 5 acceptance: 16 profiles, 4 workers

# First measurement of the shard driver (PR 5, this container, best of
# repeats): 16 identical-shape profiles, ~250 deep paths each.
SEED_BASELINE = {
    "n_profiles": 16,
    "serial_wall_s": 8.61,
    "process4_wall_s": 3.10,
    "speedup_4w_x": 3.33,
}


def make_inputs(n_profiles: int, tmp: str, n_paths: int = 250,
                depth_lo: int = 30, depth_hi: int = 70):
    """SPMD-shaped profiles: one tree shape (seeded RNG shared by every
    profile), per-profile values — the union tree equals a single
    profile's tree, like N ranks running the same program."""
    reg = default_registry()
    cpu, gk = reg.kind("cpu"), reg.kind("gpu_kernel")
    paths = []
    for p in range(n_profiles):
        shape = np.random.default_rng(5)           # same shape every profile
        vals = np.random.default_rng(100 + p)      # per-profile values
        cct = CCT()
        for _ in range(n_paths):
            depth = depth_lo + int(shape.integers(depth_hi - depth_lo))
            frames = [Frame(HOST, f"fn{shape.integers(40)}",
                            f"file{shape.integers(6)}.py",
                            int(shape.integers(60)))
                      for _ in range(depth)]
            node = cct.insert_path(frames)
            node.metrics.add(cpu, "time_ns", float(vals.integers(1, 10_000)))
            ph = cct.get_or_insert(
                node, Frame(PLACEHOLDER, f"kernel:k{shape.integers(8)}",
                            "0", 0))
            ph.metrics.add(gk, "time_ns", float(vals.integers(1, 50_000)))
            ph.metrics.add(gk, "invocations", float(vals.integers(1, 9)))
        path = os.path.join(tmp, f"p{p}.rpro")
        write_profile(path, cct, reg, {"rank": p, "type": "cpu"}, [])
        paths.append(path)
    return paths


def _db_bytes(d: str):
    return {fn: open(os.path.join(d, fn), "rb").read()
            for fn in ("stats.npz", "metrics.cms", "metrics.pms",
                       "coverage.npz")}


def run(n_profiles: int = 16, worker_counts=(1, 2, 4), repeats: int = 3,
        enforce_budget: bool = True):
    tmp = tempfile.mkdtemp(prefix="repro_pipeline_")
    paths = make_inputs(n_profiles, tmp)

    # pre-warm the process pool so startup is not billed to the driver
    aggregate(paths[:2], os.path.join(tmp, "warm"), driver="process",
              workers=max(worker_counts))

    # serial and parallel runs are PAIRED per repeat (back-to-back, so
    # both sides sample the same host-noise regime — this container's
    # wall-clock swings +-30%) and the speedup is the best paired ratio
    out = {"n_profiles": n_profiles}
    want = None
    serial_walls = []
    process_walls = {w: [] for w in worker_counts if w > 1}
    ratios = {w: [] for w in worker_counts if w > 1}
    for rep in range(max(1, repeats)):
        t0 = time.perf_counter()
        aggregate(paths, os.path.join(tmp, f"serial_{rep}"),
                  driver="serial")
        serial = time.perf_counter() - t0
        serial_walls.append(serial)
        if want is None:
            want = _db_bytes(os.path.join(tmp, "serial_0"))
        for w in ratios:
            d = os.path.join(tmp, f"process{w}_{rep}")
            t0 = time.perf_counter()
            aggregate(paths, d, driver="process", workers=w)
            wall = time.perf_counter() - t0
            # the contract this whole subsystem exists for
            assert _db_bytes(d) == want, \
                f"process driver (w={w}) diverged from serial bytes"
            process_walls[w].append(wall)
            ratios[w].append(serial / wall)
    out["serial_wall_s"] = min(serial_walls)
    for w in ratios:
        out[f"process{w}_wall_s"] = min(process_walls[w])
        out[f"speedup_{w}w_x"] = max(ratios[w])
    out["byte_identical"] = True      # asserted above, every repeat

    if enforce_budget and max(worker_counts) >= 4:
        out["n_cores"] = os.cpu_count() or 1
        out["speedup_budget_min_x"] = SPEEDUP_BUDGET_MIN_X
        if out["n_cores"] >= 2:
            out["speedup_under_budget"] = \
                bool(out["speedup_4w_x"] >= SPEEDUP_BUDGET_MIN_X)
        else:
            # no parallel hardware: a process driver cannot beat serial
            # on one core, so pass/fail would be vacuous — record the
            # waiver loudly (byte-identity above still ran every repeat)
            out["speedup_budget_waived_single_core"] = True
    if n_profiles == SEED_BASELINE["n_profiles"]:
        out["seed_serial_wall_s"] = SEED_BASELINE["serial_wall_s"]
        out["seed_process4_wall_s"] = SEED_BASELINE["process4_wall_s"]
        out["process4_vs_seed_x"] = \
            SEED_BASELINE["process4_wall_s"] / out["process4_wall_s"]
    return out


def main(small: bool = False):
    # --small keeps byte-identity coverage but no speedup bar: shard
    # work cannot dominate the fold at toy sizes on a 2-core box
    r = run(n_profiles=6, worker_counts=(1, 2), repeats=1,
            enforce_budget=False) if small else run()
    for k, v in r.items():
        print(f"bench_pipeline,{k},{v}")
    return r


if __name__ == "__main__":
    main()
