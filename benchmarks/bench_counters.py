"""Hardware-counter subsystem throughput (paper §6; repro.counters).

Two stages the subsystem must keep fast, each with an explicit budget
(enforced by benchmarks/run.py, tracked in BENCH_counters.json):

- **schedule**: packing requested counter sets into compatible multiplex
  groups.  Scheduling happens once per ``enable_counters`` call, but the
  tool-facing contract is that it is never a bottleneck even when a
  driver re-plans per kernel family — budget: >= 20k schedules/s.
- **merge**: aggregating profiles whose CCT nodes carry the dense
  12-column ``gpu_counter`` kind, i.e. the counter contribution to
  phase-4 statistic generation.  Counter kinds ride the standard sparse
  path; the run asserts the 4-rank merge is bitwise deterministic
  (stats equal across two aggregations) and holds a wall-clock budget.
"""
from __future__ import annotations

import itertools
import os
import tempfile
import time

import numpy as np

from repro.core.aggregate import aggregate
from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER
from repro.core.metrics import GPU_COUNTER_METRICS, default_registry
from repro.core.profmt import write_profile
from repro.counters import ALL_COUNTERS, build_schedule, optimal_passes

from benchmarks.calibrate import probe

# budgets as multiples of the calibration probe (benchmarks/calibrate.py)
# — the old absolute bars (20k/s, 8.0 s, 4.0 s) at the seed container's
# ~0.067 s probe
SCHEDULE_BUDGET_PER_PROBE = 1_300  # schedules per probe-second
MERGE_BUDGET_X = 120.0             # 16-profile x 2k-kernel counter merge
MERGE_BUDGET_X_SMALL = 60.0


def bench_schedule(n: int) -> dict:
    # every non-empty prefix + suffix of the catalog, cycled — exercises
    # 1..N-counter requests and multi-group packing
    requests = [ALL_COUNTERS[:k] for k in range(1, len(ALL_COUNTERS) + 1)]
    requests += [ALL_COUNTERS[k:] for k in range(len(ALL_COUNTERS) - 1)]
    it = itertools.cycle(requests)
    t0 = time.perf_counter()
    for _ in range(n):
        build_schedule(next(it))
    dt = time.perf_counter() - t0
    # correctness spot check rides along: first-fit meets the bound
    for req in requests:
        assert len(build_schedule(req).groups) <= optimal_passes(req)
    return {"n_schedules": n, "schedule_s": dt,
            "schedules_per_s": n / dt,
            "schedule_under_budget": bool(
                (n / dt) * probe() >= SCHEDULE_BUDGET_PER_PROBE),
            "schedule_budget_per_probe": SCHEDULE_BUDGET_PER_PROBE,
            "schedule_budget_probe_s": probe()}


def synth_counter_profiles(tmp: str, n_profiles: int, n_kernels: int):
    """Profiles whose placeholders carry dense gpu_counter vectors."""
    reg = default_registry()
    ckind = reg.kind("gpu_counter")
    kkind = reg.kind("gpu_kernel")
    rng = np.random.default_rng(3)
    base = rng.uniform(1.0, 1e9, (n_kernels, len(GPU_COUNTER_METRICS)))
    paths = []
    for r in range(n_profiles):
        cct = CCT()
        main = cct.insert_path([Frame(HOST, "main", "app.py", 1)])
        for k in range(n_kernels):
            step = cct.insert_path(
                [Frame(HOST, f"step{k % 37}", "app.py", 10 + k % 37)],
                parent=main)
            ph = cct.get_or_insert(
                step, Frame(PLACEHOLDER, f"kernel:k{k}", "0", 0))
            ph.metrics.add(kkind, "invocations", 1)
            ph.metrics.add(kkind, "time_ns", 100.0 + k)
            ph.metrics.add_vec(ckind, base[k] * (r + 1))
        p = os.path.join(tmp, f"profile_r{r}_t0.rpro")
        write_profile(p, cct, reg, {"rank": r, "thread": 0, "type": "cpu"},
                      [])
        paths.append(p)
    return paths


def bench_merge(n_profiles: int, n_kernels: int, budget_x: float) -> dict:
    tmp = tempfile.mkdtemp(prefix="repro_counters_bench_")
    paths = synth_counter_profiles(tmp, n_profiles, n_kernels)
    t0 = time.perf_counter()
    db = aggregate(paths, os.path.join(tmp, "db"), n_ranks=4, n_threads=4)
    merge_s = time.perf_counter() - t0
    db2 = aggregate(paths, os.path.join(tmp, "db2"), n_ranks=4, n_threads=4)
    deterministic = all(
        np.array_equal(db.stats[s], db2.stats[s]) for s in db.stats)
    n_values = n_profiles * n_kernels * len(GPU_COUNTER_METRICS)
    return {"n_profiles": n_profiles, "n_kernels": n_kernels,
            "counter_values": n_values,
            "merge_s": merge_s,
            "counter_values_per_s": n_values / merge_s,
            "merge_deterministic": bool(deterministic),
            "merge_under_budget": bool(merge_s < budget_x * probe()),
            "merge_budget_x": budget_x,
            "merge_budget_probe_s": probe()}


def main(small: bool = False):
    r = bench_schedule(2_000 if small else 20_000)
    r.update(bench_merge(
        8 if small else 16, 500 if small else 2_000,
        MERGE_BUDGET_X_SMALL if small else MERGE_BUDGET_X))
    assert r["merge_deterministic"], "counter merge must be bitwise stable"
    for k, v in r.items():
        print(f"bench_counters,{k},{v}")
    return r


if __name__ == "__main__":
    main()
