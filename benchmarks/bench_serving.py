"""Always-on serving profiler (ISSUE 7): cost of the full serving
stack on a synthetic, jax-free load — TRACKED as BENCH_serving.json.

The load is a busy-wait "model" (handcrafted HLO module, so PC-sample
attribution has real ops to land on) served request-by-request:
prefill + ``gen_len`` decode steps per request, through a
``ServingProfiler`` with per-request windows and the overhead governor.

Stages (paired-repeat ratios, same policy as bench_pipeline):

- ``serve_bare_s`` / ``serve_governed_s`` — the loop without any
  measurement vs through the governed serving profiler;
  ``governed_overhead_x`` is the best paired ratio.
- ``governed_measured_frac`` — the profiler's own steady-state
  dispatch-path accounting (tool ns / app ns, second half of the run);
  gated against ``governed_budget_frac`` via ``governed_under_budget``
  (benchmarks.run fails the sweep on False).
- ``attribution_s`` — aggregate the governed run (profiles + traces)
  and answer the tentpole question: per-request GPU attribution and
  phase latency percentiles out of the database.
- ``telemetry_s`` — export ``epochs`` snapshots as epoch-tagged shards
  through a ShardProducer into a FleetDaemon and read the series back
  (exactly-once: row count must equal the epoch count).
"""
from __future__ import annotations

import os
import shutil
import time

PREFILL_NS = 2_000_000
DECODE_NS = 1_000_000
# The dispatch path has a fixed cost (~0.1-0.2ms: channel round-trip,
# trace append, context insert) that the fidelity ladder cannot remove
# — against the 1-2ms synthetic kernels here that is ~10-15% floor
# overhead, where production GPU kernels (10-100x longer) would see
# ~1%.  The gate budget is set with ~1.8x headroom over the expected
# steady state so it catches dispatch-path cost regressions, not
# scheduler noise; BUDGET_DEMO is deliberately unreachable so the
# controller demonstrably walks the whole ladder to the floor.
BUDGET = 0.25
BUDGET_DEMO = 0.02

# regex-parseable HLO (repro.core.structure.parse_hlo) so PC samples
# attribute to ops without touching jax
SYNTH_HLO = """ENTRY %serve (p0: f32[256,256]) -> f32[256,256] {
  %p0 = f32[256,256] parameter(0)
  %dot.1 = f32[256,256] dot(%p0, %p0)
  %add.2 = f32[256,256] add(%dot.1, %p0)
  %dot.3 = f32[256,256] dot(%add.2, %p0)
  %mul.4 = f32[256,256] multiply(%dot.3, %add.2)
  %dot.5 = f32[256,256] dot(%mul.4, %p0)
  %exp.6 = f32[256,256] exponential(%dot.5)
  %dot.7 = f32[256,256] dot(%exp.6, %p0)
  ROOT %tanh.8 = f32[256,256] tanh(%dot.7)
}
"""


def _spin(ns: int) -> None:
    end = time.perf_counter_ns() + ns
    while time.perf_counter_ns() < end:
        pass


def _serve_loop(n_requests: int, gen_len: int, sp=None, mid=None,
                first_id: int = 0) -> float:
    from repro.serving.window import DECODE, PREFILL
    t0 = time.perf_counter()
    for i in range(first_id, first_id + n_requests):
        if sp is None:
            _spin(PREFILL_NS)
            for _ in range(gen_len):
                _spin(DECODE_NS)
            continue
        with sp.request(f"r{i}", PREFILL, tokens=32):
            with sp.profiler.dispatch("kernel", "prefill", stream=0,
                                      module_id=mid):
                _spin(PREFILL_NS)
        for _ in range(gen_len):
            with sp.request(f"r{i}", DECODE, tokens=1):
                with sp.profiler.dispatch("kernel", "decode_step",
                                          stream=0, module_id=mid):
                    _spin(DECODE_NS)
    return time.perf_counter() - t0


def run(n_requests: int = 24, gen_len: int = 8, repeats: int = 3,
        epochs: int = 6, out_dir: str = "/tmp/repro_bench_serving"):
    from repro.core.aggregate import aggregate
    from repro.fleet.client import DirectoryTransport, ShardProducer
    from repro.fleet.daemon import FleetDaemon
    from repro.serving.governor import GovernorConfig
    from repro.serving.live import ServingProfiler
    from repro.serving.telemetry import TelemetryExporter, read_telemetry
    from repro.traceview.stats import (request_attribution,
                                       request_latency_percentiles)
    from repro.traceview.tracedb import TraceDB

    shutil.rmtree(out_dir, ignore_errors=True)
    os.makedirs(out_dir, exist_ok=True)
    best = {"serve_bare_s": float("inf"),
            "serve_governed_s": float("inf")}
    ratios = []
    fracs = []
    final = {}
    paths = None
    for rep in range(max(1, repeats)):
        t_bare = _serve_loop(2 * n_requests, gen_len)
        sp = ServingProfiler(
            os.path.join(out_dir, f"prof{rep}"),
            governor=GovernorConfig(budget=BUDGET, interval=8,
                                    patience=5),
            sample_rate_hz=1e6)
        mid = sp.profiler.register_module("serve_step", SYNTH_HLO)
        sp.start()
        # settle phase: the controller starts at full fidelity and needs
        # a few control windows to walk down the ladder — the
        # steady-state accounting window opens only after it
        t_g0 = _serve_loop(n_requests, gen_len, sp, mid)
        mid_counters = dict(sp.profiler.overhead_counters())
        t_g1 = _serve_loop(n_requests, gen_len, sp, mid,
                           first_id=n_requests)
        t_governed = t_g0 + t_g1
        end = sp.profiler.overhead_counters()
        fracs.append((end["tool_ns"] - mid_counters["tool_ns"])
                     / max(end["app_ns"] - mid_counters["app_ns"], 1))
        sp.profiler.flush()
        rep_paths = sp.write()
        status = sp.status()
        governor = sp.governor.state()
        sp.stop()
        if t_governed < best["serve_governed_s"]:
            paths = rep_paths
            final = {"status": status, "governor": governor}
        best["serve_bare_s"] = min(best["serve_bare_s"], t_bare)
        best["serve_governed_s"] = min(best["serve_governed_s"],
                                       t_governed)
        ratios.append(t_governed / t_bare)

    # -- attribution out of the aggregated database -------------------------
    t0 = time.perf_counter()
    profs = [v for k, v in sorted(paths.items()) if "trace" not in k]
    traces = [v for k, v in sorted(paths.items()) if "trace" in k]
    db = aggregate(profs, os.path.join(out_dir, "db"), n_ranks=1,
                   n_threads=1, trace_paths=traces)
    lines = TraceDB(db.trace_db_path()).line_views()
    attribution = request_attribution(lines, db)
    percentiles = request_latency_percentiles(lines, db)
    attribution_s = time.perf_counter() - t0
    assert len(attribution) == 2 * n_requests, \
        f"expected {2 * n_requests} attributed requests, " \
        f"got {len(attribution)}"
    assert "prefill" in percentiles and "decode" in percentiles

    # -- telemetry round trip ----------------------------------------------
    t0 = time.perf_counter()
    daemon = FleetDaemon(os.path.join(out_dir, "fleet_db"),
                         os.path.join(out_dir, "spool"))
    producer = ShardProducer(os.path.join(out_dir, "outbox"),
                             DirectoryTransport(daemon.incoming_dir),
                             daemon_spool_soft=64)
    exporter = TelemetryExporter(producer, host="bench", rank=0)
    for e in range(epochs):
        exporter.export(dict(final["status"], tok_s=float(e)))
    daemon.poll_once()
    rows = read_telemetry(daemon.database())
    telemetry_s = time.perf_counter() - t0
    assert len(rows) == epochs, f"expected {epochs} rows, got {len(rows)}"

    # -- throttle demo: an unreachable budget must walk the controller
    # all the way down the ladder (convergence itself is pinned in
    # tests/test_serving.py; this seeds the trajectory numbers)
    sp_demo = ServingProfiler(
        os.path.join(out_dir, "prof_demo"),
        governor=GovernorConfig(budget=BUDGET_DEMO, interval=8),
        sample_rate_hz=1e6)
    mid_demo = sp_demo.profiler.register_module("serve_step", SYNTH_HLO)
    sp_demo.start()
    _serve_loop(n_requests, gen_len, sp_demo, mid_demo)
    demo = sp_demo.governor.state()
    sp_demo.stop()

    frac = min(fracs)
    st = final["status"]
    return {
        **best,
        "governed_overhead_x": min(ratios),
        "governed_measured_frac": frac,
        "governed_budget_frac": BUDGET,
        "governed_under_budget": frac <= BUDGET,
        "governor_final_level": final["governor"]["level"],
        "governor_throttle_downs": final["governor"]["throttle_downs"],
        "demo_budget_frac": BUDGET_DEMO,
        "demo_final_level": demo["level"],
        "demo_throttle_downs": demo["throttle_downs"],
        "samples_kept": st["samples_kept"],
        "samples_dropped": st["samples_dropped"],
        "attribution_s": attribution_s,
        "attributed_requests": len(attribution),
        "decode_p50_ms": st["decode_p50_ms"],
        "prefill_p50_ms": st["prefill_p50_ms"],
        "telemetry_s": telemetry_s,
        "telemetry_epochs": len(rows),
    }


def main(small: bool = False):
    if small:
        r = run(n_requests=10, gen_len=4, repeats=2, epochs=3)
    else:
        r = run()
    for k, v in r.items():
        print(f"bench_serving,{k},{v}")
    return r


if __name__ == "__main__":
    main()
