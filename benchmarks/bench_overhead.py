"""Measurement overhead (paper §8.1, Table: 1.85x-2.24x for nvprof/
HPCToolkit-class tools) — paired-repeat ratios, the governed budget, and
the per-rung dispatch-path floor (ISSUE 10).

Four modes of the same reduced training loop, run back-to-back inside
each repeat so the ratios are paired (CI wall-clock swings +-30%; a
paired ratio cancels most of it, same policy as bench_pipeline):

- **bare**     — no measurement;
- **coarse**   — dispatch timing only (sample_rate_hz=0);
- **fine**     — full fidelity: PC-sample analogue + tracing, the
  paper's comparable 1.85x-2.24x configuration;
- **governed** — fine-grained start, but an ``OverheadGovernor``
  throttles fidelity to ``budget`` (ISSUE 7).  The budget gate is the
  profiler's *own* steady-state accounting (tool ns / app ns over the
  second half of the loop), not the wall ratio — that is the quantity
  the governor controls, and it is stable on a noisy 2-core runner.

The **dispatch floor** section measures the fixed per-dispatch cost the
fidelity ladder cannot remove: a back-to-back empty-body dispatch loop
against a module-bound kernel, per governor rung, min-of-repeats.  It
isolates the *on-path* (producer-side) cost — the quantity the ISSUE 10
ring/deferral redesign shrank, and what the pinned legacy figure
measured when the draw/attribution/trace work was inline — by raising
the GIL switch interval across the timed window so the monitor's
concurrent deferred work does not steal unpredictable slices mid-loop
(see ``_dispatch_floor``; the deferred cost is reported alongside, not
hidden).  The ISSUE 10 acceptance gate — ``dispatch_floor_under_budget``
— holds the probe-normalized full-rung floor against the pre-ISSUE-10
inline path's pinned figure (``LEGACY_FULL_FLOOR_US`` at
``LEGACY_PROBE_S``, same loop, same machine class) and requires a
>= ``DISPATCH_REDUCTION_X`` reduction; normalizing both sides by the
calibration probe — the new side paired per repeat — makes the gate a
machine-speed-free ratio.

Reported ratios are the best paired ratio over ``repeats``.
``governed_under_budget`` and ``dispatch_floor_under_budget`` ride the
benchmark-budget contract (benchmarks.run fails the sweep on False).
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.optim import adamw

# -- the ISSUE 10 dispatch-floor gate ---------------------------------------
# Pinned legacy reference: the inline dispatch path (PC-sample draw,
# metric attribution, and per-event trace append all on the dispatching
# thread) measured 67.0us/dispatch at the full rung with the exact
# _dispatch_floor loop below, on a machine whose calibration probe ran
# 0.0631s.  The gate compares probe-normalized ratios, so the constant
# stays valid across machine speeds.
LEGACY_FULL_FLOOR_US = 67.0
LEGACY_PROBE_S = 0.0631
DISPATCH_REDUCTION_X = 4.0       # acceptance: >= 4x per-dispatch reduction
FULL_FLOOR_TARGET_US = 30.0      # informational absolute target

# a small dense module: enough ops that the deferred draw does real
# weighted work, small enough that op_weights caching dominates (the
# per-dispatch regime the floor isolates)
_FLOOR_HLO = """
HloModule bench
ENTRY main {
  p0 = f32[4096,4096] parameter(0)
  p1 = f32[4096,4096] parameter(1)
  d = f32[4096,4096] dot(p0, p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  a = f32[4096,4096] add(d, p1)
  ROOT t = f32[4096,4096] tanh(a)
}
"""


def _loop(n_steps, params, opt_state, batch, jit_step, prof=None, mid=None,
          governor=None):
    t0 = time.perf_counter()
    for _ in range(n_steps):
        if prof is not None:
            with prof.dispatch("kernel", "train_step", stream=0,
                               module_id=mid):
                params, opt_state, m = jit_step(params, opt_state, batch)
                jax.block_until_ready(m["loss"])
            if governor is not None:
                governor.observe()
        else:
            params, opt_state, m = jit_step(params, opt_state, batch)
            jax.block_until_ready(m["loss"])
    return time.perf_counter() - t0


def _dispatch_floor(scale, cap, depth, n, repeats):
    """Per-rung on-path cost: min-of-repeats us/dispatch for the timed
    dispatch loop, each repeat paired with a fresh calibration probe
    measured seconds earlier in the same machine state (returned as the
    min probe-normalized ratio — transient host slowness inflates both
    sides of a pair and cancels, bench_pipeline's paired-repeat idea).

    The timed window runs with the GIL switch interval raised so the
    monitor thread's concurrent deferred work does not steal slices
    mid-loop: what is measured is the *dispatch-path* (producer-side)
    cost — the quantity ISSUE 10 moved work off of, and exactly what
    the pinned legacy figure measured when that work was inline.  The
    ring (capacity 32768/thread) absorbs the whole loop without
    backpressure, and the deferred cost is not hidden: it is reported
    per dispatch (``floor_full_deferred_ns``, the governor's visibility
    signal) and in the sustained figure (loop + flush wall), which
    includes every drain, draw, attribution, and trace append."""
    import sys

    from benchmarks.calibrate import calibration_probe
    from repro.core.profiler import Profiler

    best = best_ratio = sustained = float("inf")
    tool_ns = deferred_ns = 0.0
    for _ in range(max(1, repeats)):
        out = tempfile.mkdtemp(prefix="repro_floor_")
        prof = Profiler(out, tracing=True, rng_seed=0)
        mid = prof.register_module("bench", _FLOOR_HLO)
        prof.sample_scale, prof.sample_cap, prof.unwind_depth = \
            scale, cap, depth
        prof.start()
        for _ in range(200):             # warm every memo/cache on the path
            with prof.dispatch("kernel", "bench", stream=0, module_id=mid):
                pass
        cal = calibration_probe(repeats=1)       # the repeat's pair
        switch = sys.getswitchinterval()
        sys.setswitchinterval(0.05)
        try:
            t0 = time.perf_counter_ns()
            for _ in range(n):
                with prof.dispatch("kernel", "bench", stream=0,
                                   module_id=mid):
                    pass
            t1 = time.perf_counter_ns()
        finally:
            sys.setswitchinterval(switch)
        prof.flush()
        t2 = time.perf_counter_ns()
        c = prof.overhead_counters()
        prof.stop()
        us = (t1 - t0) / n / 1e3
        best = min(best, us)
        sustained = min(sustained, (t2 - t0) / n / 1e3)
        if us * 1e-6 / cal < best_ratio:
            best_ratio = us * 1e-6 / cal
            d = max(c["dispatches"], 1)
            tool_ns = c["tool_ns"] / d
            deferred_ns = c["deferred_ns"] / d
    return best, best_ratio, sustained, tool_ns, deferred_ns


def run_floors(n: int = 10_000, repeats: int = 3) -> dict:
    """The per-rung dispatch floors + the ISSUE 10 reduction gate."""
    from benchmarks.calibrate import probe
    from repro.serving.governor import LEVELS

    probe()                 # warm the process-level probe (recorded by run.py)
    out = {}
    full_us = None
    new_ratio = None
    for lv in LEVELS:
        us, ratio, sustained, tool_ns, deferred_ns = _dispatch_floor(
            lv.sample_scale, lv.sample_cap, lv.unwind_depth, n, repeats)
        key = lv.name.replace("-", "_").replace("/", "_")
        out[f"floor_{key}_us"] = us
        if lv.name == "full":
            full_us = us
            new_ratio = ratio
            out["floor_full_sustained_us"] = sustained
            out["floor_full_tool_ns"] = tool_ns
            out["floor_full_deferred_ns"] = deferred_ns
    # the gate: probe-normalized full-rung floor vs the pinned legacy
    # inline path — both sides are (floor seconds / probe seconds), the
    # new side paired per repeat inside _dispatch_floor
    legacy_ratio = (LEGACY_FULL_FLOOR_US * 1e-6) / LEGACY_PROBE_S
    out["dispatch_floor_s"] = full_us * 1e-6     # rides --compare
    out["dispatch_floor_reduction_x"] = legacy_ratio / new_ratio
    out["dispatch_floor_budget_reduction_x"] = DISPATCH_REDUCTION_X
    out["dispatch_floor_budget_legacy_us"] = LEGACY_FULL_FLOOR_US
    out["dispatch_floor_budget_legacy_probe_s"] = LEGACY_PROBE_S
    out["dispatch_floor_under_budget"] = \
        legacy_ratio / new_ratio >= DISPATCH_REDUCTION_X
    out["full_floor_target_us"] = FULL_FLOOR_TARGET_US
    out["full_floor_within_target"] = full_us <= FULL_FLOOR_TARGET_US
    return out


def run(n_steps: int = 30, out_dir: str = "/tmp/repro_bench_overhead",
        batch_shape=(4, 128), repeats: int = 3, budget: float = 0.25):
    # budget calibration (same rationale as bench_serving): the dispatch
    # path has a fixed per-dispatch cost the fidelity ladder cannot
    # remove, and reduced-config CPU steps are short enough that the
    # floor sits near 10-16%.  0.25 keeps ~1.6x headroom over the
    # observed steady state so the gate catches dispatch-path cost
    # regressions without tripping on scheduler noise.
    from repro.core.profiler import Profiler
    from repro.serving.governor import GovernorConfig, OverheadGovernor

    cfg = get_config("qwen2-1.5b").reduced()
    opts = T.ModelOptions(q_chunk=32, kv_chunk=32, loss_chunk=32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    B, S = batch_shape
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    jit_step = jax.jit(steps_mod.make_train_step(cfg, None, opts,
                                                 adamw.OptConfig()))
    # warmup/compile
    jit_step(params, opt_state, batch)
    hlo = jit_step.lower(params, opt_state, batch).compile().as_text()

    best = {"bare_s": float("inf"), "coarse_s": float("inf"),
            "fine_s": float("inf"), "governed_s": float("inf")}
    ratios = {"coarse": [], "fine": [], "governed": []}
    governed_frac = []
    final_level = 0
    for rep in range(max(1, repeats)):
        t_bare = _loop(n_steps, params, opt_state, batch, jit_step)

        prof = Profiler(f"{out_dir}/coarse{rep}", tracing=True, rng_seed=0,
                        sample_rate_hz=0)      # no samples: coarse only
        with prof:
            t_coarse = _loop(n_steps, params, opt_state, batch, jit_step,
                             prof, None)

        prof2 = Profiler(f"{out_dir}/fine{rep}", tracing=True, rng_seed=0,
                         sample_rate_hz=1e6)
        mid = prof2.register_module("train_step", hlo)
        with prof2:
            t_fine = _loop(n_steps, params, opt_state, batch, jit_step,
                           prof2, mid)

        prof3 = Profiler(f"{out_dir}/governed{rep}", tracing=True,
                         rng_seed=0, sample_rate_hz=1e6)
        mid3 = prof3.register_module("train_step", hlo)
        gov = OverheadGovernor(prof3, GovernorConfig(
            budget=budget, interval=max(2, n_steps // 8)))
        with prof3:
            half = max(1, n_steps // 2)
            t_g0 = _loop(half, params, opt_state, batch, jit_step,
                         prof3, mid3, gov)
            mid_counters = dict(prof3.overhead_counters())
            t_g1 = _loop(n_steps - half, params, opt_state, batch,
                         jit_step, prof3, mid3, gov)
        t_governed = t_g0 + t_g1
        end = prof3.overhead_counters()
        tool = end["tool_ns"] - mid_counters["tool_ns"]
        app = end["app_ns"] - mid_counters["app_ns"]
        governed_frac.append(tool / max(app, 1))
        final_level = gov.level

        best["bare_s"] = min(best["bare_s"], t_bare)
        best["coarse_s"] = min(best["coarse_s"], t_coarse)
        best["fine_s"] = min(best["fine_s"], t_fine)
        best["governed_s"] = min(best["governed_s"], t_governed)
        ratios["coarse"].append(t_coarse / t_bare)
        ratios["fine"].append(t_fine / t_bare)
        ratios["governed"].append(t_governed / t_bare)

    frac = min(governed_frac)
    return {
        **best,
        "coarse_overhead_x": min(ratios["coarse"]),
        "fine_overhead_x": min(ratios["fine"]),
        "governed_overhead_x": min(ratios["governed"]),
        "governed_measured_frac": frac,
        "governed_budget_frac": budget,
        "governed_under_budget": frac <= budget,
        "governor_final_level": final_level,
        "paper_claim_x": "1.85-2.24",
    }


def main(small: bool = False):
    out = {}
    # the dispatch floors are cheap and the gate is the ISSUE 10
    # acceptance pin, so they run in both modes (--small shrinks the
    # loop, min-of-repeats still controls scheduler noise)
    floors = run_floors(n=2_000, repeats=2) if small else run_floors()
    for k, v in floors.items():
        print(f"bench_overhead,{k},{v}")
        out[k] = v
    # overhead amortizes with kernel duration (the paper's kernels are much
    # longer than a reduced-config CPU step): report two step sizes
    # (--small keeps only the quick config with fewer steps: CI smoke)
    configs = (("small", (4, 128), 10, 2),) if small else \
        (("small", (4, 128), 30, 3), ("large", (8, 512), 8, 2))
    for label, shape, steps, reps in configs:
        r = run(n_steps=steps, batch_shape=shape, repeats=reps)
        for k, v in r.items():
            print(f"bench_overhead,{label}_{k},{v}")
            out[f"{label}_{k}"] = v
    return out


if __name__ == "__main__":
    main()
