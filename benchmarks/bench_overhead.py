"""Measurement overhead (paper §8.1, Table: 1.85x-2.24x for nvprof/
HPCToolkit-class tools).

Runs the same reduced training loop bare, with coarse profiling (dispatch
timing only), and with fine-grained profiling (PC-sample analogue +
tracing), and reports the overhead ratios.  The paper's comparable numbers:
2.24x (PeleC, PC sampling), 1.85x (Nyx trace, 128 ranks).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.optim import adamw


def _loop(n_steps, params, opt_state, batch, jit_step, prof=None, mid=None):
    t0 = time.perf_counter()
    for _ in range(n_steps):
        if prof is not None:
            with prof.dispatch("kernel", "train_step", stream=0,
                               module_id=mid):
                params, opt_state, m = jit_step(params, opt_state, batch)
                jax.block_until_ready(m["loss"])
        else:
            params, opt_state, m = jit_step(params, opt_state, batch)
            jax.block_until_ready(m["loss"])
    return time.perf_counter() - t0


def run(n_steps: int = 30, out_dir: str = "/tmp/repro_bench_overhead",
        batch_shape=(4, 128)):
    cfg = get_config("qwen2-1.5b").reduced()
    opts = T.ModelOptions(q_chunk=32, kv_chunk=32, loss_chunk=32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    B, S = batch_shape
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    jit_step = jax.jit(steps_mod.make_train_step(cfg, None, opts,
                                                 adamw.OptConfig()))
    # warmup/compile
    p, o, _ = jit_step(params, opt_state, batch)
    hlo = jit_step.lower(params, opt_state, batch).compile().as_text()

    t_bare = _loop(n_steps, params, opt_state, batch, jit_step)

    from repro.core.profiler import Profiler
    prof = Profiler(out_dir + "/coarse", tracing=True, rng_seed=0,
                    sample_rate_hz=0)          # no samples: coarse only
    with prof:
        t_coarse = _loop(n_steps, params, opt_state, batch, jit_step,
                         prof, None)
    prof.write()

    prof2 = Profiler(out_dir + "/fine", tracing=True, rng_seed=0,
                     sample_rate_hz=1e6)
    mid = prof2.register_module("train_step", hlo)
    with prof2:
        t_fine = _loop(n_steps, params, opt_state, batch, jit_step,
                       prof2, mid)
    prof2.write()

    return {
        "bare_s": t_bare,
        "coarse_s": t_coarse,
        "fine_s": t_fine,
        "coarse_overhead_x": t_coarse / t_bare,
        "fine_overhead_x": t_fine / t_bare,
        "paper_claim_x": "1.85-2.24",
    }


def main(small: bool = False):
    out = {}
    # overhead amortizes with kernel duration (the paper's kernels are much
    # longer than a reduced-config CPU step): report two step sizes
    # (--small keeps only the quick config with fewer steps: CI smoke)
    configs = (("small", (4, 128), 10),) if small else \
        (("small", (4, 128), 30), ("large", (8, 512), 8))
    for label, shape, steps in configs:
        r = run(n_steps=steps, batch_shape=shape)
        for k, v in r.items():
            print(f"bench_overhead,{label}_{k},{v}")
        out[label] = r
    return out


if __name__ == "__main__":
    main()
