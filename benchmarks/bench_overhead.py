"""Measurement overhead (paper §8.1, Table: 1.85x-2.24x for nvprof/
HPCToolkit-class tools) — paired-repeat ratios + the governed budget.

Four modes of the same reduced training loop, run back-to-back inside
each repeat so the ratios are paired (CI wall-clock swings +-30%; a
paired ratio cancels most of it, same policy as bench_pipeline):

- **bare**     — no measurement;
- **coarse**   — dispatch timing only (sample_rate_hz=0);
- **fine**     — full fidelity: PC-sample analogue + tracing, the
  paper's comparable 1.85x-2.24x configuration;
- **governed** — fine-grained start, but an ``OverheadGovernor``
  throttles fidelity to ``budget`` (ISSUE 7).  The budget gate is the
  profiler's *own* steady-state accounting (tool ns / app ns over the
  second half of the loop), not the wall ratio — that is the quantity
  the governor controls, and it is stable on a noisy 2-core runner.

Reported ratios are the best paired ratio over ``repeats``.
``governed_under_budget`` rides the benchmark-budget contract
(benchmarks.run fails the sweep when it is False).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.optim import adamw


def _loop(n_steps, params, opt_state, batch, jit_step, prof=None, mid=None,
          governor=None):
    t0 = time.perf_counter()
    for _ in range(n_steps):
        if prof is not None:
            with prof.dispatch("kernel", "train_step", stream=0,
                               module_id=mid):
                params, opt_state, m = jit_step(params, opt_state, batch)
                jax.block_until_ready(m["loss"])
            if governor is not None:
                governor.observe()
        else:
            params, opt_state, m = jit_step(params, opt_state, batch)
            jax.block_until_ready(m["loss"])
    return time.perf_counter() - t0


def run(n_steps: int = 30, out_dir: str = "/tmp/repro_bench_overhead",
        batch_shape=(4, 128), repeats: int = 3, budget: float = 0.25):
    # budget calibration (same rationale as bench_serving): the dispatch
    # path has a fixed per-dispatch cost the fidelity ladder cannot
    # remove, and reduced-config CPU steps are short enough that the
    # floor sits near 10-16%.  0.25 keeps ~1.6x headroom over the
    # observed steady state so the gate catches dispatch-path cost
    # regressions without tripping on scheduler noise.
    from repro.core.profiler import Profiler
    from repro.serving.governor import GovernorConfig, OverheadGovernor

    cfg = get_config("qwen2-1.5b").reduced()
    opts = T.ModelOptions(q_chunk=32, kv_chunk=32, loss_chunk=32)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params)
    B, S = batch_shape
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    jit_step = jax.jit(steps_mod.make_train_step(cfg, None, opts,
                                                 adamw.OptConfig()))
    # warmup/compile
    jit_step(params, opt_state, batch)
    hlo = jit_step.lower(params, opt_state, batch).compile().as_text()

    best = {"bare_s": float("inf"), "coarse_s": float("inf"),
            "fine_s": float("inf"), "governed_s": float("inf")}
    ratios = {"coarse": [], "fine": [], "governed": []}
    governed_frac = []
    final_level = 0
    for rep in range(max(1, repeats)):
        t_bare = _loop(n_steps, params, opt_state, batch, jit_step)

        prof = Profiler(f"{out_dir}/coarse{rep}", tracing=True, rng_seed=0,
                        sample_rate_hz=0)      # no samples: coarse only
        with prof:
            t_coarse = _loop(n_steps, params, opt_state, batch, jit_step,
                             prof, None)

        prof2 = Profiler(f"{out_dir}/fine{rep}", tracing=True, rng_seed=0,
                         sample_rate_hz=1e6)
        mid = prof2.register_module("train_step", hlo)
        with prof2:
            t_fine = _loop(n_steps, params, opt_state, batch, jit_step,
                           prof2, mid)

        prof3 = Profiler(f"{out_dir}/governed{rep}", tracing=True,
                         rng_seed=0, sample_rate_hz=1e6)
        mid3 = prof3.register_module("train_step", hlo)
        gov = OverheadGovernor(prof3, GovernorConfig(
            budget=budget, interval=max(2, n_steps // 8)))
        with prof3:
            half = max(1, n_steps // 2)
            t_g0 = _loop(half, params, opt_state, batch, jit_step,
                         prof3, mid3, gov)
            mid_counters = dict(prof3.overhead_counters())
            t_g1 = _loop(n_steps - half, params, opt_state, batch,
                         jit_step, prof3, mid3, gov)
        t_governed = t_g0 + t_g1
        end = prof3.overhead_counters()
        tool = end["tool_ns"] - mid_counters["tool_ns"]
        app = end["app_ns"] - mid_counters["app_ns"]
        governed_frac.append(tool / max(app, 1))
        final_level = gov.level

        best["bare_s"] = min(best["bare_s"], t_bare)
        best["coarse_s"] = min(best["coarse_s"], t_coarse)
        best["fine_s"] = min(best["fine_s"], t_fine)
        best["governed_s"] = min(best["governed_s"], t_governed)
        ratios["coarse"].append(t_coarse / t_bare)
        ratios["fine"].append(t_fine / t_bare)
        ratios["governed"].append(t_governed / t_bare)

    frac = min(governed_frac)
    return {
        **best,
        "coarse_overhead_x": min(ratios["coarse"]),
        "fine_overhead_x": min(ratios["fine"]),
        "governed_overhead_x": min(ratios["governed"]),
        "governed_measured_frac": frac,
        "governed_budget_frac": budget,
        "governed_under_budget": frac <= budget,
        "governor_final_level": final_level,
        "paper_claim_x": "1.85-2.24",
    }


def main(small: bool = False):
    out = {}
    # overhead amortizes with kernel duration (the paper's kernels are much
    # longer than a reduced-config CPU step): report two step sizes
    # (--small keeps only the quick config with fewer steps: CI smoke)
    configs = (("small", (4, 128), 10, 2),) if small else \
        (("small", (4, 128), 30, 3), ("large", (8, 512), 8, 2))
    for label, shape, steps, reps in configs:
        r = run(n_steps=steps, batch_shape=shape, repeats=reps)
        for k, v in r.items():
            print(f"bench_overhead,{label}_{k},{v}")
            out[f"{label}_{k}"] = v
    return out


if __name__ == "__main__":
    main()
