"""GPU calling-context-tree reconstruction (paper §6.3, Fig. 5).

Correctness on the paper's own example + reconstruction throughput on
RAJA-perf-shaped inputs (the paper's motivation: a templated dot product
expands to 25 GPU functions; large kernels produce call graphs of hundreds
of functions)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.callgraph import CallGraph, reconstruct


def fig5() -> dict:
    nodes = ["A", "B", "C", "D", "E"]
    edges = {("A", "B"): 0.0, ("A", "C"): 1.0, ("B", "D"): 1.0,
             ("C", "D"): 3.0, ("D", "E"): 2.0, ("E", "D"): 2.0}
    samples = {"A": 10.0, "B": 4.0, "C": 6.0, "D": 8.0, "E": 4.0}
    g = CallGraph(nodes, edges, samples)
    root = reconstruct(g, roots=["A"])
    return {
        "fig5_total_conserved": abs(root.total()
                                    - sum(samples.values())) < 1e-9,
        "fig5_scc_found": root.find("SCC{D,E}") is not None,
    }


def synthetic(n_funcs: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    nodes = [f"f{i}" for i in range(n_funcs)]
    edges = {}
    for i in range(n_funcs):
        for _ in range(int(rng.integers(1, 4))):
            j = int(rng.integers(i + 1, n_funcs + 1))
            if j < n_funcs:
                edges[(nodes[i], nodes[j])] = float(rng.integers(0, 8))
    # sprinkle recursion (SCCs)
    for _ in range(n_funcs // 20):
        i = int(rng.integers(1, n_funcs))
        j = int(rng.integers(0, i))
        edges[(nodes[i], nodes[j])] = float(rng.integers(1, 4))
    samples = {n: float(rng.integers(0, 100)) for n in nodes}
    return CallGraph(nodes, edges, samples)


def run():
    out = fig5()
    for n in (100, 500):
        g = synthetic(n)
        t0 = time.perf_counter()
        root = reconstruct(g)
        dt = time.perf_counter() - t0
        out[f"n{n}_seconds"] = dt
        out[f"n{n}_funcs_per_s"] = n / dt
    return out


def main():
    r = run()
    for k, v in r.items():
        print(f"bench_reconstruction,{k},{v}")
    return r


if __name__ == "__main__":
    main()
