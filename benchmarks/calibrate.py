"""Machine-speed calibration probe — the reference every benchmark
budget is expressed against.

Absolute wall-clock budgets (the old ``RASTER_BUDGET_S = 1.0`` and
siblings) encode the speed of the machine that picked them: a slower CI
container trips them spuriously, a faster one lets real regressions
hide.  Every ``<stage>_under_budget`` gate is therefore a **ratio**
against the probe — ``stage_s < STAGE_BUDGET_X * probe()`` — where the
probe is a fixed, deterministic numpy workload measured in the same
process right before the gated stage.  Uniform machine noise inflates
stage and probe alike, so the ratio is stable across hosts; that is the
``bench_pipeline`` paired-repeat idea applied across processes.  The
``--compare`` sweep (benchmarks/run.py) normalizes the same way against
the ``calibration_s`` recorded in each committed ``BENCH_<name>.json``.
"""
from __future__ import annotations

import time

_PROBE_S = None


def calibration_probe(repeats: int = 3) -> float:
    """Seconds for a fixed, deterministic CPU workload (best of
    ``repeats``).  The mix mirrors what the benchmarks spend time on:
    medium matmuls, Python-level sorting, and many tiny-array numpy
    calls (the benches are dominated by numpy call overhead on small
    arrays, so the probe must be too)."""
    import numpy as np
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        rng = np.random.default_rng(0)
        a = rng.standard_normal((256, 256))
        small = rng.standard_normal(128)
        acc = 0.0
        for _ in range(60):
            a = a @ a.T / 256.0
            acc += float(np.abs(a).sum())
            sorted(float(x) for x in a.ravel()[:4096])
            for _ in range(20):
                acc += float(np.floor(small * 3.0).sum())
        best = min(best, time.perf_counter() - t0)
    return best


def probe() -> float:
    """The probe time, measured once per process and cached — every
    budget gate in a sweep normalizes against the same measurement,
    and ``benchmarks.run`` records it as ``calibration_s``."""
    global _PROBE_S
    if _PROBE_S is None:
        _PROBE_S = calibration_probe()
    return _PROBE_S
