"""Benchmark driver: one benchmark per paper table/figure
(docs/aggregation.md discusses the aggregation/channel ones).

``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--small]
[--json-dir DIR]`` prints ``bench,metric,value`` CSV rows for every
benchmark, writes ``BENCH_<name>.json`` result files (the cross-PR perf
trajectory), and exits non-zero if any benchmark raises.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_aggregation, bench_channels, bench_counters,
                        bench_fleet, bench_kstruct, bench_merge,
                        bench_overhead, bench_pipeline, bench_reconstruction,
                        bench_roofline, bench_serving, bench_sparse,
                        bench_traceview)

ALL = {
    "channels": bench_channels,        # §4.1 wait-free channels
    "sparse": bench_sparse,            # §8.2 sparse vs dense sizes
    "aggregation": bench_aggregation,  # §8.2 / §6.1 streaming aggregation
    "reconstruction": bench_reconstruction,  # §6.3 Fig. 5
    "overhead": bench_overhead,        # §8.1 measurement overhead
    "roofline": bench_roofline,        # deliverable (g)
    "traceview": bench_traceview,      # §4.4/§7 trace.db merge + raster
    "counters": bench_counters,        # §6 counter schedule + merge
    "merge": bench_merge,              # ISSUE 4 sharded/incremental merge
    "pipeline": bench_pipeline,        # ISSUE 5 shard-driver scaling
    "fleet": bench_fleet,              # ISSUE 6 daemon ingest + recovery
    "serving": bench_serving,          # ISSUE 7 always-on serving profiler
    "kstruct": bench_kstruct,          # ISSUE 8 kernel-interior sampling
}

# benchmarks whose results are persisted as BENCH_<name>.json
TRACKED = ("aggregation", "channels", "traceview", "counters", "merge",
           "pipeline", "fleet", "serving", "kstruct", "overhead")

# --compare: a tracked stage time growing more than this fraction over
# its committed BENCH_<name>.json baseline fails the sweep
COMPARE_TOLERANCE = 0.25


# the probe lives in benchmarks.calibrate (every bench's budget gate
# normalizes against it in-process); re-exported here for the sweep and
# for existing importers
from benchmarks.calibrate import calibration_probe, probe  # noqa: F401,E402


def budget_regressions(name: str, results: dict) -> list:
    """Budget contract: a benchmark that tracks a budget reports a
    ``<stage>_under_budget`` bool (with its ``<stage>_budget_*`` bound
    riding along).  Any False is a perf regression the sweep must fail
    loudly on, naming the benchmark and stage."""
    out = []
    for key, ok in results.items():
        if key.endswith("_under_budget") and not ok:
            stage = key[: -len("_under_budget")]
            bound = {k: v for k, v in results.items()
                     if k.startswith(stage + "_budget")}
            out.append(f"{name}: {stage} exceeded its budget {bound}")
    return out


def baseline_regressions(name: str, results: dict, baseline: dict,
                         small: bool,
                         tol: float = COMPARE_TOLERANCE,
                         calibration: float = 0.0) -> list:
    """``--compare`` contract: every measured stage time (``*_s`` keys,
    lower is better) is held against the committed ``BENCH_<name>.json``
    baseline; growing more than ``tol`` (default 25%) is a regression
    the sweep must fail loudly on, naming the benchmark, stage, and
    both numbers.  Budget bounds (``*_budget*``) and pinned seed
    numbers (``seed_*``) are constants, not measurements, and are
    skipped; so is a baseline recorded at a different problem size
    (``small`` mismatch).

    When both this run's ``calibration`` probe time and the baseline's
    recorded ``calibration_s`` are available, the gate is the
    machine-normalized *ratio* ``stage_s / calibration_s`` on each side
    (bench_pipeline's paired-repeat idea across processes): a slow CI
    host inflates stage and probe alike, so uniform machine noise
    cancels and only genuine per-stage regressions trip the gate.
    Without a probe on either side it falls back to absolute seconds."""
    if not baseline or baseline.get("small", False) != small:
        return []
    base = baseline.get("results", {})
    base_cal = float(baseline.get("calibration_s", 0.0) or 0.0)
    paired = calibration > 0.0 and base_cal > 0.0
    out = []
    for key, new in results.items():
        if not key.endswith("_s") or key.endswith("_per_s") \
                or "_budget" in key or key.startswith("seed_"):
            continue
        old = base.get(key)
        if not isinstance(old, (int, float)) \
                or not isinstance(new, (int, float)) or old <= 0:
            continue
        if paired:
            old_r, new_r = old / base_cal, new / calibration
            if new_r > old_r * (1 + tol):
                out.append(
                    f"{name}: {key} regressed {old_r:.2f}x -> {new_r:.2f}x "
                    f"calibration (+{(new_r / old_r - 1):.0%}, tolerance "
                    f"{tol:.0%}; raw {old:.3f}s -> {new:.3f}s, probe "
                    f"{base_cal:.3f}s -> {calibration:.3f}s)")
        elif new > old * (1 + tol):
            out.append(f"{name}: {key} regressed {old:.3f}s -> {new:.3f}s "
                       f"(+{(new / old - 1):.0%}, tolerance {tol:.0%})")
    return out


def load_baseline(baseline_dir: str, name: str) -> dict:
    path = os.path.join(baseline_dir, f"BENCH_{name}.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(ALL))
    ap.add_argument("--small", action="store_true",
                    help="reduced problem sizes (CI smoke)")
    ap.add_argument("--json-dir", default=".",
                    help="where BENCH_<name>.json files land")
    ap.add_argument("--compare", action="store_true",
                    help="fail the sweep when a tracked stage time "
                         f"regresses >{COMPARE_TOLERANCE:.0%} against its "
                         "committed BENCH_<name>.json baseline")
    ap.add_argument("--baseline-dir",
                    default=os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__))),
                    help="where the committed baselines live "
                         "(default: repo root)")
    args = ap.parse_args(argv)
    failures = 0
    regressions = []
    cal = probe()
    print(f"# calibration probe: {cal:.3f}s", flush=True)
    for name, mod in ALL.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            kwargs = {}
            if "small" in inspect.signature(mod.main).parameters:
                kwargs["small"] = args.small
            elif args.small:
                print(f"# note: {name} has no --small mode; "
                      "running full size", flush=True)
            results = mod.main(**kwargs)
            if isinstance(results, dict):
                regressions += budget_regressions(name, results)
                if args.compare and name in TRACKED:
                    regressions += baseline_regressions(
                        name, results,
                        load_baseline(args.baseline_dir, name), args.small,
                        calibration=cal)
            if name in TRACKED and isinstance(results, dict):
                os.makedirs(args.json_dir, exist_ok=True)
                path = os.path.join(args.json_dir, f"BENCH_{name}.json")
                with open(path, "w") as f:
                    json.dump({"bench": name, "small": args.small,
                               "calibration_s": cal,
                               "results": results,
                               "took_s": time.perf_counter() - t0},
                              f, indent=1)
                print(f"# wrote {path}", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    for msg in regressions:
        print(f"# PERF REGRESSION: {msg}", file=sys.stderr, flush=True)
    return failures + len(regressions)


if __name__ == "__main__":
    sys.exit(main())
