"""Benchmark driver: one benchmark per paper table/figure (DESIGN.md §7).

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
prints ``bench,metric,value`` CSV rows for every benchmark.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_aggregation, bench_channels, bench_overhead,
                        bench_reconstruction, bench_roofline, bench_sparse)

ALL = {
    "channels": bench_channels,        # §4.1 wait-free channels
    "sparse": bench_sparse,            # §8.2 sparse vs dense sizes
    "aggregation": bench_aggregation,  # §8.2 / §6.1 streaming aggregation
    "reconstruction": bench_reconstruction,  # §6.3 Fig. 5
    "overhead": bench_overhead,        # §8.1 measurement overhead
    "roofline": bench_roofline,        # deliverable (g)
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(ALL))
    args = ap.parse_args(argv)
    failures = 0
    for name, mod in ALL.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name} took {time.perf_counter() - t0:.1f}s", flush=True)
    return failures


if __name__ == "__main__":
    sys.exit(main())
