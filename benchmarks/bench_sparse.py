"""Sparse vs dense format sizes (paper §8.2: measurement 22x smaller,
analysis results 3701x smaller than dense).

Synthesizes a GPU-accelerated-run-shaped workload: P profiles (threads +
streams), a CCT of C contexts, M metrics where each context carries only
its kind's metrics (the sparsity source the paper describes: CPU nodes have
no GPU metrics and vice versa) — then compares:

- measurement: .rpro sparse profile bytes vs dense (nodes x metrics x 8);
- analysis:    CMS+PMS cube bytes vs dense (profiles x contexts x metrics).
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core.cct import CCT, Frame, HOST, PLACEHOLDER, GPU_OP
from repro.core.metrics import MetricRegistry, default_registry
from repro.core.profmt import dense_profile_nbytes, write_profile
from repro.core.sparse import (ProfileValues, dense_cube_nbytes, write_cms,
                               write_pms)


def paper_scale_registry() -> MetricRegistry:
    """HPCToolkit measures 'well over 100 metrics' (§4.6): the default
    kinds plus per-stall-reason, per-copy-kind, per-counter families."""
    reg = default_registry()
    reg.register_kind("gpu_stall_detail", tuple(
        f"stall_{r}" for r in ("ifetch", "exec_dep", "mem_dep", "texture",
                               "sync", "const_mem", "pipe_busy", "mem_throt",
                               "not_sel", "other", "sleep", "dispatch")))
    reg.register_kind("gpu_copy_detail", tuple(
        f"{d}_{m}" for d in ("h2d", "d2h", "d2d", "p2p")
        for m in ("count", "bytes", "time_ns")))
    reg.register_kind("gpu_counters", tuple(
        f"ctr_{i}" for i in range(40)))
    reg.register_kind("cpu_counters", tuple(
        f"perf_{e}" for e in ("cycles", "insts", "l1_miss", "l2_miss",
                              "llc_miss", "br_miss", "tlb_miss", "stalls")))
    reg.register_kind("gpu_occupancy", tuple(
        f"occ_{i}" for i in range(12)))
    return reg


def synth_cct(rng, registry, n_host=200, n_kernels=20, n_ops=40):
    """Host tree -> kernel placeholders -> GPU op nodes, paper-shaped
    metric kinds."""
    cct = CCT()
    cpu = registry.kind("cpu")
    gk = registry.kind("gpu_kernel")
    gi = registry.kind("gpu_inst")
    hosts = []
    for i in range(n_host):
        depth = 1 + int(rng.integers(6))
        frames = [Frame(HOST, f"fn{rng.integers(64)}",
                        f"file{rng.integers(8)}.py", int(rng.integers(400)))
                  for _ in range(depth)]
        node = cct.insert_path(frames)
        node.metrics.add(cpu, "time_ns", float(rng.integers(1, 10_000)))
        hosts.append(node)
    for k in range(n_kernels):
        host = hosts[int(rng.integers(len(hosts)))]
        ph = cct.get_or_insert(host, Frame(PLACEHOLDER, f"kernel:k{k}",
                                           "0", 0))
        ph.metrics.add(gk, "invocations", float(rng.integers(1, 20)))
        ph.metrics.add(gk, "time_ns", float(rng.integers(1, 100_000)))
        for o in range(int(rng.integers(5, n_ops))):
            op = cct.insert_path([Frame(GPU_OP, f"op{o}", f"mod{k}", o)],
                                 parent=ph)
            op.metrics.add(gi, "samples", float(rng.integers(1, 500)))
            op.metrics.add(gi, "stall_memory", float(rng.integers(200)))
    return cct


def run(n_profiles: int = 32):
    rng = np.random.default_rng(0)
    reg = paper_scale_registry()
    tmp = tempfile.mkdtemp(prefix="repro_sparse_")
    sparse_meas = 0
    dense_meas = 0
    pvals = []
    # the analysis cube is indexed by GLOBAL contexts: the union of every
    # profile's calling contexts after unification — each profile touches
    # only a small slice of it, which is where the paper's 3701x lives.
    global_ctx: dict = {}
    for p in range(n_profiles):
        cct = synth_cct(rng, reg)
        path = os.path.join(tmp, f"p{p}.rpro")
        write_profile(path, cct, reg, {"rank": p}, [])
        sparse_meas += os.path.getsize(path)
        dense_meas += dense_profile_nbytes(cct.n_nodes, reg.n_metrics)
        # per-profile sparse values against global ctx ids
        ctx, met, val = [], [], []
        for node in cct.nodes():
            items = list(node.metrics.nonzero_items(reg))
            if not items:
                continue
            key = (p, node.node_id)   # unification keeps ~per-profile paths
            gid_ctx = global_ctx.setdefault(key, len(global_ctx))
            for gid, v in items:
                ctx.append(gid_ctx)
                met.append(gid)
                val.append(v)
        order = np.argsort(np.asarray(ctx))
        pvals.append(ProfileValues(
            p, np.asarray(ctx, np.uint32)[order],
            np.asarray(met, np.uint32)[order], np.asarray(val)[order]))

    cms = write_cms(os.path.join(tmp, "m.cms"), pvals)
    pms = write_pms(os.path.join(tmp, "m.pms"), pvals)
    sparse_analysis = cms["bytes"] + pms["bytes"]
    dense_analysis = 2 * dense_cube_nbytes(n_profiles, len(global_ctx),
                                           reg.n_metrics)
    return {
        "measurement_sparse_bytes": sparse_meas,
        "measurement_dense_bytes": dense_meas,
        "measurement_ratio_x": dense_meas / sparse_meas,
        "paper_measurement_ratio_x": 22.0,
        "analysis_sparse_bytes": sparse_analysis,
        "analysis_dense_bytes": dense_analysis,
        "analysis_ratio_x": dense_analysis / sparse_analysis,
        "paper_analysis_ratio_x": 3701.0,
    }


def main():
    r = run()
    for k, v in r.items():
        print(f"bench_sparse,{k},{v}")
    return r


if __name__ == "__main__":
    main()
