"""Kernel-interior attribution cost (ISSUE 8 tentpole;
repro.core.kstruct).

The two-level PC-sample draw runs on the *dispatch path*: every kernel
dispatch of a module with bound ``KernelStructure``s descends the op
samples into interior leaves, and attribution splices the leaf frame
chains under the kernel's GPU_OP context.  That must stay cheap — the
always-on serving profiler (ISSUE 7) dispatches thousands of times per
second under the governor's cap.

Reported numbers (fixture: synthetic module, 4 bound custom-call
kernels with 24-leaf interiors + 64 plain ops — no jax needed, so the
benchmark is deterministic and CI-cheap):

- ``plain_sampling_s`` / ``bound_sampling_s`` — N deterministic
  ``pc_samples`` draws without/with bound structures (best of repeats);
- ``descent_overhead_x`` — best PAIRED bound/plain ratio (runs
  alternate back-to-back; this container's wall-clock swings +-30%);
  budgeted <= ``DESCENT_OVERHEAD_BUDGET_X``;
- ``attrib_dispatches_per_s`` — full ``Profiler.dispatch`` loop with
  interior attribution (caps at the governor's serving rung, cap=32);
- ``recovery_s`` — full mode only: tracing + recovering all three real
  Pallas kernel structures (jax import + 3 ``make_jaxpr`` traces).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

# the descent adds one apportionment per bound op that drew samples; a
# paired slowdown beyond this bound means the dispatch path regressed
DESCENT_OVERHEAD_BUDGET_X = 4.0

# First measurement of this subsystem (PR 8, this container, best of
# repeats): 4 bound kernels x 24 leaves, 64 plain ops, 2000 draws.
SEED_BASELINE = {
    "n_draws": 2000,
    "plain_sampling_s": 0.030,
    "bound_sampling_s": 0.030,
    "descent_overhead_x": 0.92,
}


def module_text(n_kernels: int = 4, n_other: int = 64) -> str:
    """Synthetic HLO with ``n_kernels`` custom-call kernels (to bind)
    plus ``n_other`` plain elementwise ops."""
    lines = ["HloModule bench_kstruct", "",
             "ENTRY %main (p0: f32[256,256]) -> f32[256,256] {",
             "  %p0 = f32[256,256] parameter(0)"]
    prev = "p0"
    for i in range(n_kernels):
        lines.append(
            f'  %kern{i} = f32[256,256] custom-call(%{prev}), '
            f'custom_call_target="tpu_custom_call", '
            f'metadata={{op_name="jit(step)/kernel{i}"}}')
        prev = f"kern{i}"
    for i in range(n_other):
        lines.append(f"  %op{i} = f32[256,256] multiply(%{prev}, %p0)")
        prev = f"op{i}"
    lines.append(f"  ROOT %out = f32[256,256] add(%{prev}, %p0)")
    lines.append("}")
    return "\n".join(lines)


def make_structure(name: str, n_leaves: int = 24):
    """Hand-built interior (deterministic; shaped like the recovered
    flash-attention tree: one grid loop, three scopes, weighted leaves)."""
    from repro.core.cct import Frame, GPU_FUNC, GPU_LOOP, GPU_OP
    from repro.core.kstruct import KernelLeaf, KernelStructure
    loop = Frame(GPU_LOOP, "grid:kv_blocks", f"{name}.py", 36)
    scopes = [Frame(GPU_FUNC, s, f"{name}.py", 40 + 20 * i)
              for i, s in enumerate(("_init", "_block", "_finish"))]
    rng = np.random.default_rng(8)
    leaves = []
    for i in range(n_leaves):
        sc = scopes[min(i * 3 // n_leaves, 2)]
        fl = float(rng.integers(1, 1 << 20))
        leaves.append(KernelLeaf(
            frames=(loop, sc, Frame(GPU_OP, f"op{i}", f"{name}.py",
                                    50 + i)),
            weight=fl / 197e12, stall="compute" if i % 3 else "memory",
            flops=fl, bytes=float(rng.integers(0, 1 << 16))))
    return KernelStructure(name, f"{name}.py", 36, leaves)


def run(n_draws: int = 2000, repeats: int = 5, enforce_budget: bool = True):
    from repro.core import sampling
    from repro.core.profiler import Profiler
    from repro.core.structure import parse_hlo

    text = module_text()
    plain = parse_hlo(text)
    bound = parse_hlo(text)
    for i in range(4):
        assert bound.bind_kernel_structure(
            make_structure(f"kernel{i}"), match=f"kernel{i}") == 1

    out = {"n_draws": n_draws}
    plain_walls, bound_walls, ratios = [], [], []
    for _ in range(max(1, repeats)):
        # PAIRED: plain and bound draws alternate back-to-back so both
        # sides sample the same host-noise regime
        t0 = time.perf_counter()
        for d in range(n_draws):
            sampling.pc_samples(plain, 1e-4 + d * 1e-9, cap=32)
        tp = time.perf_counter() - t0
        t0 = time.perf_counter()
        for d in range(n_draws):
            sampling.pc_samples(bound, 1e-4 + d * 1e-9, cap=32)
        tb = time.perf_counter() - t0
        plain_walls.append(tp)
        bound_walls.append(tb)
        ratios.append(tb / tp)
    out["plain_sampling_s"] = min(plain_walls)
    out["bound_sampling_s"] = min(bound_walls)
    out["descent_overhead_x"] = min(ratios)

    # full dispatch loop with interior attribution at the serving cap
    tmp = tempfile.mkdtemp(prefix="repro_kstruct_")
    prof = Profiler(os.path.join(tmp, "m"), tracing=False, unwind=False)
    mid = prof.register_module("step", text)
    prof.register_kernel_structures(
        mid, [make_structure(f"kernel{i}") for i in range(4)])
    prof.sample_cap = 32
    n_disp = max(200, n_draws // 4)
    disp_walls = []
    with prof:
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            for _ in range(n_disp):
                with prof.dispatch("kernel", "step", module_id=mid):
                    pass
            disp_walls.append(time.perf_counter() - t0)
    out["attrib_dispatch_s"] = min(disp_walls)
    out["attrib_dispatches_per_s"] = n_disp / out["attrib_dispatch_s"]

    if enforce_budget:
        out["descent_under_budget"] = \
            bool(out["descent_overhead_x"] <= DESCENT_OVERHEAD_BUDGET_X)
        out["descent_budget_max_x"] = DESCENT_OVERHEAD_BUDGET_X
    if n_draws == SEED_BASELINE["n_draws"]:
        out["seed_bound_sampling_s"] = SEED_BASELINE["bound_sampling_s"]
    return out


def recovery_timing() -> dict:
    """Trace + recover the three real Pallas kernels (full mode only:
    pays the jax import)."""
    try:
        t0 = time.perf_counter()
        from repro.kernels import kernel_structures
        structures = kernel_structures()
        return {"recovery_s": time.perf_counter() - t0,
                "recovered_kernels": len(structures),
                "recovered_leaves": sum(len(ks.leaves)
                                        for ks in structures)}
    except ImportError:
        return {"recovered_kernels": 0}


def main(small: bool = False):
    r = run(n_draws=300, repeats=2) if small else run()
    if not small:
        r.update(recovery_timing())
    for k, v in r.items():
        print(f"bench_kstruct,{k},{v}")
    return r


if __name__ == "__main__":
    main()
