"""Roofline table (deliverable (g)): reads dryrun_results/*.json (produced
by ``python -m repro.launch.dryrun``) and emits the per-(arch x shape x
mesh) three-term roofline table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load(out_dir: str = "dryrun_results", mesh: str = None,
         tag: str = "") -> List[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        if r.get("tag", "") != tag:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(r)
    return rows


def markdown(rows: List[dict]) -> str:
    cols = ["arch", "shape", "mesh", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "useful_ratio", "mfu_model",
            "fits_hbm"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       + " | ".join(["SKIP"] * 6) + " | - |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       + " | ".join(["ERROR"] * 6) + " | - |")
            continue
        ro = r["roofline"]
        vals = [r["arch"], r["shape"], r["mesh"],
                f"{ro['t_compute_s']:.3e}", f"{ro['t_memory_s']:.3e}",
                f"{ro['t_collective_s']:.3e}", ro["dominant"],
                f"{ro['useful_ratio']:.3f}", f"{ro['mfu_model']:.3f}",
                str(r["memory"]["fits_hbm"])]
        out.append("| " + " | ".join(vals) + " |")
    return "\n".join(out)


def run(out_dir: str = "dryrun_results"):
    rows = load(out_dir)
    ok = [r for r in rows if r["status"] == "ok"]
    if not ok:
        return {"error": f"no dry-run records in {out_dir}; run "
                "python -m repro.launch.dryrun --all first"}
    doms = {}
    fits = 0
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
        fits += r["memory"]["fits_hbm"]
    return {
        "cells_ok": len(ok),
        "cells_skipped": sum(r["status"] == "skipped" for r in rows),
        "cells_error": sum(r["status"] == "error" for r in rows),
        "fits_hbm": fits,
        **{f"dominant_{k}": v for k, v in doms.items()},
        "mean_mfu_model": sum(r["roofline"]["mfu_model"] for r in ok)
        / len(ok),
    }


def main():
    r = run()
    for k, v in r.items():
        print(f"bench_roofline,{k},{v}")
    rows = load()
    if rows:
        print()
        print(markdown(rows))
    return r


if __name__ == "__main__":
    main()
