"""Property tests for the ``core.derived`` formula evaluator.

The evaluator is the user-programmable surface of the viewer (§4.5/§7.1
spreadsheet formulas), so its contract must be *total*: any well-formed
formula over any finite/NaN metric columns evaluates without raising,
division by zero yields 0 (the hpcviewer convention), and the usual
algebraic identities hold on the sparse columns.

Strategies build random well-formed formula trees from the grammar the
evaluator accepts (names, constants, + - * /, unary minus, whitelisted
calls, comparisons, conditional expressions) together with matching
random columns.  Guarded via tests/hypothesis_compat.py: without
hypothesis installed these are reported as skips, never errors.
"""
import math

import numpy as np
import pytest
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.derived import DerivedMetric, sanitize

NAMES = ("a", "b", "c")


def _exprs():
    """Random well-formed formula strings over NAMES."""
    atoms = st.one_of(
        st.sampled_from(NAMES),
        st.floats(-1e6, 1e6, allow_nan=False,
                  allow_infinity=False).map(lambda v: repr(round(v, 3))),
    )

    def compound(inner):
        bins = st.tuples(inner, st.sampled_from([" + ", " - ", " * ",
                                                 " / "]), inner) \
            .map(lambda t: f"({t[0]}{t[1]}{t[2]})")
        neg = inner.map(lambda e: f"(-{e})")
        calls = st.tuples(st.sampled_from(["abs", "sqrt", "log", "exp"]),
                          inner).map(lambda t: f"{t[0]}({t[1]})")
        two = st.tuples(st.sampled_from(["min", "max"]), inner, inner) \
            .map(lambda t: f"{t[0]}({t[1]}, {t[2]})")
        cond = st.tuples(inner, st.sampled_from([" > ", " <= ", " == "]),
                         inner, inner, inner) \
            .map(lambda t: f"({t[3]} if {t[0]}{t[1]}{t[2]} else {t[4]})")
        return st.one_of(bins, neg, calls, two, cond)

    return st.recursive(atoms, compound, max_leaves=12)


def _columns():
    vals = st.floats(-1e9, 1e9, allow_nan=True, allow_infinity=False,
                     width=64)
    return st.integers(1, 6).flatmap(
        lambda n: st.fixed_dictionaries(
            {name: st.lists(vals, min_size=n, max_size=n).map(np.array)
             for name in NAMES}))


@given(_exprs(), _columns())
@settings(max_examples=150, deadline=None)
def test_evaluation_is_total(expr, cols):
    """Any well-formed formula evaluates on any columns: no exception,
    result broadcastable to the column shape."""
    m = DerivedMetric("p", expr)
    with np.errstate(all="ignore"):
        out = np.asarray(m.evaluate(cols), dtype=np.float64)
    n = len(next(iter(cols.values())))
    assert out.shape in ((), (n,))


@given(_columns())
@settings(max_examples=100, deadline=None)
def test_zero_division_policy_total(cols):
    """x / 0 == 0 elementwise — including 0/0 — and never raises."""
    a = np.nan_to_num(cols["a"])
    b = np.nan_to_num(cols["b"])
    out = DerivedMetric("q", "a / b").evaluate({"a": a, "b": b})
    expect = np.where(b != 0, np.divide(a, np.where(b != 0, b, 1)), 0.0)
    np.testing.assert_array_equal(out, expect)
    # the zero-divisor lanes specifically are exactly 0, not inf/NaN
    assert (np.asarray(out)[b == 0] == 0.0).all()


@given(_columns())
@settings(max_examples=100, deadline=None)
def test_algebraic_identities(cols):
    """Commutativity holds exactly (FP + and * are commutative), and
    a - a is identically 0 on finite columns."""
    finite = {k: np.nan_to_num(v) for k, v in cols.items()}
    with np.errstate(all="ignore"):
        ab = DerivedMetric("x", "a + b").evaluate(finite)
        ba = DerivedMetric("x", "b + a").evaluate(finite)
        np.testing.assert_array_equal(ab, ba)
        mul_ab = DerivedMetric("x", "a * b").evaluate(finite)
        mul_ba = DerivedMetric("x", "b * a").evaluate(finite)
        np.testing.assert_array_equal(mul_ab, mul_ba)
        zero = DerivedMetric("x", "a - a").evaluate(finite)
    np.testing.assert_array_equal(zero, np.zeros_like(finite["a"]))


@given(_exprs())
@settings(max_examples=100, deadline=None)
def test_roundtrip_reparse(expr):
    """Accepted formulas stay accepted (the validator is stable) and
    evaluate identically when re-parsed."""
    m1 = DerivedMetric("r", expr)
    m2 = DerivedMetric("r", m1.formula)
    cols = {n: np.array([1.5, -2.0, 0.0]) for n in NAMES}
    with np.errstate(all="ignore"):
        np.testing.assert_array_equal(
            np.asarray(m1.evaluate(cols), np.float64),
            np.asarray(m2.evaluate(cols), np.float64))


def test_sanitize_is_injective_on_metric_names():
    """Sanitized names of all default metrics stay distinct (a collision
    would silently alias two columns in every formula)."""
    from repro.core.metrics import default_registry
    names = default_registry().metric_names
    out = [sanitize(n) for n in names]
    assert len(set(out)) == len(names)


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="hypothesis not installed (see pyproject [test])")
def test_property_suite_is_active():
    """Guard: when hypothesis IS available the property tests above must
    actually run (they skip silently otherwise by design)."""
    assert HAVE_HYPOTHESIS
