"""Staged aggregation pipeline + pluggable shard driver (ISSUE 5
tentpole).

Acceptance contract pinned here:

- ``aggregate(..., workers=N)`` under every driver (serial / thread /
  process) produces a database — stats, cms, pms, coverage, trace.db,
  converted traces, meta — byte-identical to the serial one-shot;
- the driver honours the ``REPRO_AGG_DRIVER`` environment (CI runs the
  whole tier-1 suite under ``process``);
- GPU-stream traces written by ``Profiler.write()`` convert through the
  *dispatching thread's* gmap (the former ``ctx_unmapped`` ROADMAP item)
  and land on real database contexts;
- the ``repro.core.aggregate`` façade keeps its full public surface and
  stays a thin re-export (< 200 lines);
- ``python -m repro.core.aggregate`` aggregates a measurement directory.
"""
import itertools
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core.aggregate import aggregate
from repro.core.pipeline.acquire import acquire, expand_inputs
from repro.core.pipeline.contracts import ProfileEntry, ShardResult
from repro.core.pipeline.database import ancestor_closure, load_coverage
from repro.core.pipeline.driver import (plan_shards, resolve_driver,
                                        run_shard_stages)
from repro.core.pipeline.stats import generate_stats
from repro.core.pipeline.traceconv import required_profiles
from repro.core.pipeline.unify import unify
from repro.core.profiler import Profiler
from repro.core.trace import (DISPATCH_CTX_SHIFT, read_trace,
                              read_trace_header)
from test_aggregate_equiv import synth_inputs
from test_merge import db_bytes, meta_of

DB_AND_COVERAGE = ("stats.npz", "metrics.cms", "metrics.pms", "trace.db",
                   "coverage.npz")


def assert_identical_outputs(got, want, traces=()):
    assert db_bytes(got, DB_AND_COVERAGE) == \
        db_bytes(want, DB_AND_COVERAGE)
    assert meta_of(got) == meta_of(want)
    for t in traces:
        b = os.path.basename(t)
        assert open(os.path.join(got, b), "rb").read() == \
            open(os.path.join(want, b), "rb").read(), f"{b} diverged"


# ---------------------------------------------------------------------------
# Driver byte-identity (the tentpole acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("driver,workers",
                         [("thread", 2), ("process", 2), ("process", 4)])
def test_driver_byte_identical_to_serial(tmp_path, driver, workers):
    paths, traces = synth_inputs(tmp_path, seed=60, n_profiles=9)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    out = str(tmp_path / f"{driver}{workers}")
    db = aggregate(paths, out, trace_paths=traces, workers=workers,
                   driver=driver)
    assert_identical_outputs(out, one, traces)
    assert len(db.profile_ids) == 9


def test_process_driver_on_profiler_measurement(tmp_path):
    """The pinned multi-rank fixture: real Profiler output (CPU threads +
    GPU streams + dispatch-encoded stream traces), 4 workers."""
    profiles, traces = _measure_ranks(tmp_path, n_ranks=3)
    one = str(tmp_path / "one")
    aggregate(profiles, one, trace_paths=traces)
    out = str(tmp_path / "par")
    timing = {}
    aggregate(profiles, out, trace_paths=traces, workers=4,
              driver="process", timing=timing)
    assert_identical_outputs(out, one, traces)
    assert timing["driver"] == "process" and timing["workers"] == 4
    assert timing["n_shards"] >= 2


def test_driver_env_var_is_honoured(tmp_path, monkeypatch):
    paths, traces = synth_inputs(tmp_path, seed=61, n_profiles=5)
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    monkeypatch.setenv("REPRO_AGG_DRIVER", "process")
    monkeypatch.setenv("REPRO_AGG_WORKERS", "3")
    timing = {}
    out = str(tmp_path / "env")
    aggregate(paths, out, trace_paths=traces, timing=timing)
    assert timing["driver"] == "process" and timing["workers"] == 3
    assert_identical_outputs(out, one, traces)


def test_resolve_driver_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_AGG_DRIVER", raising=False)
    monkeypatch.delenv("REPRO_AGG_WORKERS", raising=False)
    assert resolve_driver(None, None) == ("serial", 1)
    assert resolve_driver(None, 4) == ("process", 4)
    assert resolve_driver("thread", None) == ("thread", 4)
    # a worker count from the environment alone implies process, same
    # as the workers= argument alone
    monkeypatch.setenv("REPRO_AGG_WORKERS", "3")
    assert resolve_driver(None, None) == ("process", 3)
    monkeypatch.setenv("REPRO_AGG_DRIVER", "thread")
    monkeypatch.setenv("REPRO_AGG_WORKERS", "2")
    assert resolve_driver(None, None) == ("thread", 2)
    assert resolve_driver("serial", 8) == ("serial", 8)  # args win
    with pytest.raises(ValueError, match="unknown aggregation driver"):
        resolve_driver("mpi", None)


def test_process_driver_falls_back_serially_on_unpicklable(tmp_path):
    """Infrastructure failures must degrade, not corrupt: unpicklable
    structures make the process pool unusable, the driver warns and
    re-runs the shards serially — output unaffected."""
    class Unpicklable:
        def __reduce__(self):
            raise TypeError("not picklable")

    paths, _ = synth_inputs(tmp_path, seed=62, n_profiles=4,
                            with_traces=False)
    structures = {"no_such_module": Unpicklable()}
    one = str(tmp_path / "one")
    aggregate(paths, one, structures=structures)
    out = str(tmp_path / "fb")
    with pytest.warns(RuntimeWarning, match="retrying the shards"):
        aggregate(paths, out, structures=structures, workers=2,
                  driver="process")
    assert db_bytes(out, DB_AND_COVERAGE) == db_bytes(one, DB_AND_COVERAGE)


def test_plan_shards_round_robin():
    assert plan_shards(["a", "b", "c", "d", "e"], 2) == \
        [["a", "c", "e"], ["b", "d"]]
    assert plan_shards(["a"], 4) == [["a"]]
    assert plan_shards([], 4) == []


# ---------------------------------------------------------------------------
# Stage contracts
# ---------------------------------------------------------------------------
def test_acquire_round_robin_and_expand_inputs(tmp_path):
    acq = acquire(["p0", "p1", "p2", "p3", "p4"], 2)
    assert acq.rank_paths == [["p0", "p2", "p4"], ["p1", "p3"]]
    assert acq.n_profiles == 5
    paths, traces = synth_inputs(tmp_path, seed=63, n_profiles=2)
    profs, trcs = expand_inputs([str(tmp_path)])
    assert sorted(profs) == sorted(paths)
    assert sorted(trcs) == sorted(traces)
    profs2, trcs2 = expand_inputs([paths[0], traces[1]])
    assert profs2 == [paths[0]] and trcs2 == [traces[1]]


def test_stats_stage_records_exact_coverage(tmp_path):
    """ProfileEntry.coverage must be exactly the canonical ids the
    profile's CCT nodes mapped into (what retention rebuilds trees
    from), and land in coverage.npz in canonical profile order."""
    paths, _ = synth_inputs(tmp_path, seed=64, n_profiles=3,
                            with_traces=False)
    uni = unify(acquire(paths, 2), n_threads=2)
    entries = generate_stats(uni, n_workers=2)
    for up, e in zip(uni.profiles, entries):
        want = np.unique(up.gmap[up.prof.node_ids])
        assert np.array_equal(e.coverage, want)
        # nonzero ctxs are always covered
        assert np.isin(e.ctx, e.coverage).all()
    out = str(tmp_path / "db")
    db = aggregate(paths, out)
    cov = load_coverage(out)
    assert cov is not None and len(cov) == 3
    via_db = db.coverage()
    assert set(cov) == set(via_db)
    for k in cov:
        assert np.array_equal(cov[k], via_db[k])
        assert cov[k][0] == 0 and (np.diff(cov[k]) > 0).all()


def test_run_shard_stages_matches_merge_contract(tmp_path):
    paths, _ = synth_inputs(tmp_path, seed=65, n_profiles=3,
                            with_traces=False)
    res = run_shard_stages(paths)
    assert isinstance(res, ShardResult)
    assert sorted(res.identities) == [0, 1, 2]
    assert {int(pv.profile_id) for pv in res.pvals} == {0, 1, 2}
    assert set(res.gmaps) == set(paths)
    # duck-types what merge_databases folds
    from repro.core.merge import merge_databases
    out = str(tmp_path / "merged")
    merge_databases([res], out)
    one = str(tmp_path / "one")
    aggregate(paths, one)
    assert db_bytes(out, DB_AND_COVERAGE)["stats.npz"] == \
        db_bytes(one, DB_AND_COVERAGE)["stats.npz"]


def test_ancestor_closure():
    parents = np.array([-1, 0, 1, 1, 0, 4])
    assert list(ancestor_closure(np.array([3]), parents)) == [0, 1, 3]
    assert list(ancestor_closure(np.array([5, 2]), parents)) \
        == [0, 1, 2, 4, 5]
    assert list(ancestor_closure(np.zeros(0, np.int64), parents)) == [0]


def test_write_database_accepts_legacy_tuples(tmp_path):
    """Callers handing bare 4-tuples (no coverage) get the ancestor
    closure of their nonzero ctxs — the pre-coverage behavior."""
    from repro.core.aggregate import _write_database
    from repro.core.cct import Frame
    import time
    frames = [Frame("root", "<program root>"), Frame("host", "a", "f", 1)]
    parents = np.array([-1, 0])
    db = _write_database(
        str(tmp_path / "db"), frames, parents, ["m/x"],
        [({"rank": 0}, np.array([1]), np.array([0]), np.array([2.0]))],
        n_workers=1, t0=time.monotonic())
    assert db.stats["sum"][1, 0] == 2.0
    cov = load_coverage(db.out_dir)
    assert list(cov[0]) == [0, 1]


# ---------------------------------------------------------------------------
# The ctx_unmapped root-cause fix (ROADMAP item)
# ---------------------------------------------------------------------------
def _measure_ranks(tmp_path, n_ranks=2, tag=None):
    """Real Profiler measurements: per rank, one app thread dispatching
    kernels on two GPU streams (deterministic clock)."""
    ticks = itertools.count(0, 1000)
    profiles, traces = [], []
    for r in range(n_ranks):
        prof = Profiler(str(tmp_path / f"rank{r}"), tracing=True,
                        unwind=False, rank=r, tag=tag,
                        clock=lambda: next(ticks))
        with prof:
            for i in range(4):
                with prof.dispatch("kernel", f"k{i % 2}", stream=i % 2,
                                   duration_ns=5000):
                    pass
                with prof.cpu_region("host_work"):
                    next(ticks)
            assert prof.flush(timeout=30)
        paths = prof.write()
        profiles += [v for k, v in paths.items() if "trace" not in k]
        traces += [v for k, v in paths.items() if "trace" in k]
    return profiles, traces


def test_profiler_gpu_traces_convert_through_dispatcher(tmp_path):
    """No ``ctx_unmapped: true`` identities from Profiler.write() output
    anymore: every gpu-stream event lands on the dispatching thread's
    placeholder context."""
    from repro.traceview.tracedb import TraceDB
    profiles, traces = _measure_ranks(tmp_path)
    gpu_traces = [t for t in traces
                  if os.path.basename(t).startswith("trace_")]
    assert gpu_traces, "profiler must emit gpu-stream traces"
    for t in gpu_traces:
        ident = read_trace_header(t)["identity"]
        assert ident["dispatch_profiles"] == {"0": ident_profile(t)}
    db = aggregate(profiles, str(tmp_path / "db"), trace_paths=traces)
    tdb = TraceDB(db.trace_db_path())
    assert not any(ln.identity.get("ctx_unmapped") for ln in tdb.lines)
    assert not any(ln.identity.get("dispatch_profiles")
                   for ln in tdb.lines)
    for i, ln in enumerate(tdb.lines):
        if ln.identity["type"] != "gpu":
            continue
        ctx = tdb.ctx(i)
        assert (0 <= ctx).all() and (ctx < len(db.frames)).all()
        assert {db.frames[int(c)].kind for c in ctx} == {"placeholder"}


def ident_profile(tpath):
    base = os.path.basename(tpath)           # trace_[tag_]rR_sS.rtrc
    stem = base[len("trace_"):-len(".rtrc")]
    return f"profile_{stem.rsplit('_s', 1)[0]}_t0.rpro"


def test_dispatch_required_profiles_resolution(tmp_path):
    profiles, traces = _measure_ranks(tmp_path, n_ranks=1)
    gpu = [t for t in traces if "trace_" in os.path.basename(t)][0]
    cpu = [t for t in traces if "profile_" in os.path.basename(t)][0]
    pset = set(profiles)
    assert required_profiles(cpu, None, pset) \
        == [cpu.replace(".rtrc", ".rpro")]
    req = required_profiles(gpu, None, pset)
    assert req and all(r in pset for r in req)
    assert required_profiles(gpu, None, set()) == []


def test_dispatch_trace_without_profiles_stays_unmapped(tmp_path):
    """Aggregating a gpu-stream trace *without* its thread profiles
    falls back to the verbatim ctx_unmapped path (merge copies it
    unchanged), exactly like any other orphan trace."""
    from repro.traceview.tracedb import TraceDB
    profiles, traces = _measure_ranks(tmp_path, n_ranks=1)
    gpu = [t for t in traces if os.path.basename(t).startswith("trace_")]
    db = aggregate([], str(tmp_path / "db"), trace_paths=gpu)
    tdb = TraceDB(db.trace_db_path())
    assert len(tdb) == len(gpu)
    assert all(ln.identity.get("ctx_unmapped") for ln in tdb.lines)
    # raw node ids survive (decoded from the dispatch encoding)
    raw = read_trace(gpu[0])
    assert list(tdb.ctx(0)) == \
        list(np.asarray(raw.ctx) & ((1 << DISPATCH_CTX_SHIFT) - 1))


def test_multithreaded_dispatchers_convert_per_event(tmp_path):
    """Two app threads dispatching into ONE stream: each event converts
    through its own dispatcher's gmap."""
    from repro.traceview.tracedb import TraceDB
    ticks = itertools.count(0, 1000)
    prof = Profiler(str(tmp_path / "m"), tracing=True, unwind=False,
                    clock=lambda: next(ticks))
    barrier = threading.Barrier(2)

    def worker(i):
        barrier.wait()
        for _ in range(8):
            with prof.dispatch("kernel", f"k_thread{i}", stream=0,
                               duration_ns=2000):
                pass
        barrier.wait()

    with prof:
        ts = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert prof.flush(timeout=30)
    paths = prof.write()
    profiles = [v for k, v in paths.items() if "trace" not in k]
    traces = [v for k, v in paths.items() if "trace" in k]
    gpu = paths["gpu_trace_0"]
    ident = read_trace_header(gpu)["identity"]
    if len(ident["dispatch_profiles"]) < 2:
        # thread idents were reused (threads too short-lived on this
        # box): the per-event mapping is still exercised, just through
        # one merged thread profile
        assert ident["dispatch_profiles"]
    db = aggregate(profiles, str(tmp_path / "db"), trace_paths=traces)
    tdb = TraceDB(db.trace_db_path())
    assert not any(ln.identity.get("ctx_unmapped") for ln in tdb.lines)
    gpu_i = [i for i, ln in enumerate(tdb.lines)
             if ln.identity["type"] == "gpu"][0]
    names = {db.frames[int(c)].name for c in tdb.ctx(gpu_i)}
    assert names == {"kernel:k_thread0", "kernel:k_thread1"}


def test_shard_merge_byte_identity_with_profiler_gpu_traces(tmp_path):
    """Rank-sharded aggregation of real measurements (each shard holds
    its rank's thread profiles, so its gpu traces convert) merges to the
    one-shot bytes — the dispatch fix composes through merge."""
    from repro.core.merge import merge_databases
    profiles, traces = _measure_ranks(tmp_path, n_ranks=2)
    one = str(tmp_path / "one")
    aggregate(profiles, one, trace_paths=traces)
    dirs = []
    for r in range(2):
        rp = [p for p in profiles if f"rank{r}" in p]
        rt = [t for t in traces if f"rank{r}" in t]
        d = str(tmp_path / f"shard{r}")
        aggregate(rp, d, trace_paths=rt, n_ranks=r + 1)
        dirs.append(d)
    merged = str(tmp_path / "merged")
    merge_databases(dirs, merged)
    assert db_bytes(merged) == db_bytes(one)
    assert meta_of(merged) == meta_of(one)


# ---------------------------------------------------------------------------
# Façade + CLI
# ---------------------------------------------------------------------------
def test_facade_public_surface_and_size():
    """Every pre-decomposition public name still imports from
    repro.core.aggregate, and the façade stays thin (< 200 lines)."""
    import importlib
    agg = importlib.import_module("repro.core.aggregate")
    for name in ("aggregate", "Database", "GlobalTree", "canonical_order",
                 "apply_order", "profile_sort_key", "make_expander",
                 "_write_database", "_group_sum_ordered",
                 "_profile_inclusive_sparse", "STATS"):
        assert hasattr(agg, name), f"façade lost {name}"
    n_lines = len(open(agg.__file__).read().splitlines())
    assert n_lines < 200, f"façade grew to {n_lines} lines"


def test_cli_aggregates_measurement_dir(tmp_path, capsys):
    from repro.core.pipeline.cli import main as cli_main
    (tmp_path / "m").mkdir()
    paths, traces = synth_inputs(tmp_path / "m", seed=66, n_profiles=4)
    out = str(tmp_path / "db")
    rc = cli_main([str(tmp_path / "m"), "-o", out, "--workers", "2",
                   "--driver", "thread"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "AGGREGATE  4 profile(s), 4 trace(s)" in text
    assert "profiles: 4" in text
    one = str(tmp_path / "one")
    aggregate(paths, one, trace_paths=traces)
    assert db_bytes(out, DB_AND_COVERAGE) == db_bytes(one, DB_AND_COVERAGE)


def test_cli_module_entrypoint(tmp_path):
    """``python -m repro.core.aggregate`` is wired up."""
    (tmp_path / "m").mkdir()
    paths, _ = synth_inputs(tmp_path / "m", seed=67, n_profiles=2,
                            with_traces=False)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.aggregate",
         str(tmp_path / "m"), "-o", str(tmp_path / "db")],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "AGGREGATE  2 profile(s)" in proc.stdout
    assert os.path.exists(tmp_path / "db" / "meta.json")
