"""Per-architecture smoke tests (deliverable (f)): reduced same-family
config, one forward/train step + prefill/decode on CPU, output shapes +
no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs
from repro.configs.base import shape_applicable
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.optim import adamw

ARCHS = list_configs()
OPTS = T.ModelOptions(q_chunk=16, kv_chunk=16, ssm_chunk=8, loss_chunk=16)


def make_batch(cfg, B=2, S=32, with_labels=True):
    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.full((B, S, cfg.d_model), 0.01, jnp.float32)
    elif cfg.frontend == "vlm" and cfg.frontend_tokens:
        F = min(cfg.frontend_tokens, S // 2)
        batch["embeds"] = jnp.full((B, F, cfg.d_model), 0.01, jnp.float32)
        batch["tokens"] = jnp.ones((B, S - F), jnp.int32)
    else:
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    if with_labels:
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    expected = {"xlstm-125m", "yi-6b", "qwen2-1.5b", "starcoder2-15b",
                "qwen3-32b", "llava-next-mistral-7b",
                "llama4-maverick-400b-a17b", "granite-moe-1b-a400m",
                "musicgen-large", "hymba-1.5b"}
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    step = jax.jit(steps_mod.make_train_step(cfg, None, OPTS,
                                             adamw.OptConfig()))
    p2, o2, m = step(params, adamw.init(params), batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["loss"]) > 0
    # params actually changed (unembed always receives gradient; the embed
    # table does not for audio archs whose inputs are frame embeddings)
    d0 = params["unembed"]
    d1 = p2["unembed"]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S, with_labels=False)
    opts = T.ModelOptions(q_chunk=8, kv_chunk=8, ssm_chunk=4, loss_chunk=8)
    logits, cache = T.prefill(params, cfg, batch.get("tokens"),
                              batch.get("embeds"), opts=opts)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    if cfg.frontend == "audio":
        lg2, c2 = T.decode_step(params, cfg, cache,
                                embed=jnp.full((B, 1, cfg.d_model), 0.01),
                                pos=jnp.int32(S), opts=opts)
    else:
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        lg2, c2 = T.decode_step(params, cfg, cache, token=tok,
                                pos=jnp.int32(S), opts=opts)
    assert lg2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg2)).all(), arch
    assert jax.tree.structure(cache) == jax.tree.structure(c2)


@pytest.mark.parametrize("arch", ARCHS)
def test_config_exact_dims(arch):
    """The registered (full) config matches the assignment table."""
    spec = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec, (arch, got, spec)
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 1
    if arch == "granite-moe-1b-a400m":
        assert cfg.moe.n_experts == 32 and cfg.moe.top_k == 8
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
    if arch == "qwen3-32b":
        assert cfg.qk_norm
    if arch == "qwen2-1.5b":
        assert cfg.qkv_bias


def test_long_500k_applicability():
    """long_500k runs only for sub-quadratic sequence mixing."""
    long = SHAPES["long_500k"]
    runs = {a for a in ARCHS if shape_applicable(get_config(a), long)[0]}
    assert runs == {"xlstm-125m", "hymba-1.5b"}


@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b",
                                  "granite-moe-1b-a400m"])
def test_moe_param_accounting(arch):
    cfg = get_config(arch)
    assert cfg.n_active_params() < cfg.n_params()


def test_param_count_plausible():
    """Sanity: full configs land near their nameplate sizes."""
    # note: every FFN in this framework is gated (swiglu, 3 matrices);
    # starcoder2's published 15B uses a plain 2-matrix MLP, so its
    # swiglu-equivalent lands at ~22B (DESIGN.md §Arch-applicability)
    for arch, lo, hi in [("qwen2-1.5b", 1.2e9, 2.2e9),
                         ("yi-6b", 5e9, 7.5e9),
                         ("qwen3-32b", 25e9, 40e9),
                         ("starcoder2-15b", 12e9, 23e9)]:
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)
