"""GPU calling-context-tree reconstruction (paper §6.3, Fig. 5)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core.callgraph import CallGraph, CCTOut, reconstruct


def fig5_graph():
    """The paper's Fig. 5: A calls B (no sampled call edge) and C; C and B
    call into an SCC {D, E}."""
    nodes = ["A", "B", "C", "D", "E"]
    #           A
    #         /   \
    #        B     C          (A->B weight 0: B sampled but no call sample)
    #        |     |
    #        D <-> E  (SCC)
    edges = {("A", "B"): 0.0, ("A", "C"): 1.0,
             ("B", "D"): 1.0, ("C", "D"): 3.0,
             ("D", "E"): 2.0, ("E", "D"): 2.0}
    samples = {"A": 10.0, "B": 4.0, "C": 6.0, "D": 8.0, "E": 4.0}
    return CallGraph(nodes, edges, samples)


def test_step2_zero_weight_edge_promoted():
    """B has samples but zero inbound weight -> its incoming edge gets 1."""
    root = reconstruct(fig5_graph(), roots=["A"])
    a = root.children[0]
    names = {c.name for c in a.children}
    assert any("B" == n for n in names), f"B missing under A: {names}"


def test_scc_collapsed_and_costed():
    root = reconstruct(fig5_graph(), roots=["A"])
    scc = root.find("SCC{D,E}")
    assert scc is not None, "D<->E must collapse into one SCC node"
    assert scc.members == ("D", "E")


def test_total_cost_conserved():
    """Splitting a call graph into a tree preserves total samples."""
    g = fig5_graph()
    root = reconstruct(g, roots=["A"])
    assert root.total() == pytest.approx(sum(g.samples.values()))


def test_gprof_apportioning():
    """D+E samples (12) split across call sites B (weight 1) and C
    (weight 3) as 1/4 : 3/4."""
    root = reconstruct(fig5_graph(), roots=["A"])
    a = root.children[0]
    b = next(c for c in a.children if c.name == "B")
    c = next(c for c in a.children if c.name == "C")
    scc_b = b.find("SCC{D,E}")
    scc_c = c.find("SCC{D,E}")
    assert scc_b.cost == pytest.approx(12 * 0.25)
    assert scc_c.cost == pytest.approx(12 * 0.75)


def test_self_loop_becomes_scc():
    g = CallGraph(["main", "rec"], {("main", "rec"): 1.0,
                                    ("rec", "rec"): 5.0},
                  {"main": 1.0, "rec": 9.0})
    root = reconstruct(g, roots=["main"])
    assert root.find("SCC{rec}") is not None
    assert root.total() == pytest.approx(10.0)


def test_exact_counts_mode():
    """sample_based=False skips step 2 (zero edges stay zero)."""
    g = fig5_graph()
    root = reconstruct(g, sample_based=False, roots=["A"])
    a = root.children[0]
    b = next((c for c in a.children if c.name == "B"), None)
    # B's only in-edge has weight 0 -> no cost flows through it
    if b is not None:
        assert b.cost == 0.0


@st.composite
def random_dag(draw):
    n = draw(st.integers(2, 10))
    nodes = [f"f{i}" for i in range(n)]
    edges = {}
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                edges[(nodes[i], nodes[j])] = float(draw(st.integers(0, 5)))
    samples = {nd: float(draw(st.integers(0, 20))) for nd in nodes}
    return CallGraph(nodes, edges, samples)


@given(random_dag())
@settings(max_examples=100, deadline=None)
def test_cost_conservation_on_random_dags(g):
    """Property: reconstruction conserves total cost for any DAG whose
    sampled nodes are reachable (step 2 guarantees reachability)."""
    root = reconstruct(g)
    # every sampled function must appear somewhere in the tree
    total = root.total()
    assert total == pytest.approx(sum(g.samples.values()), rel=1e-6)


def test_deep_chain_no_recursion_error():
    n = 5000
    nodes = [f"f{i}" for i in range(n)]
    edges = {(nodes[i], nodes[i + 1]): 1.0 for i in range(n - 1)}
    samples = {nd: 1.0 for nd in nodes}
    root = reconstruct(CallGraph(nodes, edges, samples), max_depth=n + 1)
    assert root.total() == pytest.approx(n)
